"""Shared test-problem generators + hypothesis strategies (ISSUE 5).

One home for the generators every test file used to hand-roll:

* ``make_problem`` / ``make_batched_problem`` — the paper §5 experimental
  procedure (``B, V ~ U[0,1]``, ``A = B^T B + I``), single or stacked.
* ``tol_for`` — the roundoff budget of a long hyperbolic recurrence.
* ``spd_stream`` / ``gauss_rows`` — signed rank-1 traffic for the stream
  layer; ``spd_stream`` keeps every sequential prefix SPD (each downdate
  removes half of a previously pushed update row), which is the
  precondition of the sign-schedule equivalence proof.
* hypothesis strategies (``spd_problems``, ``feasible_streams``) wrapping
  the generators for property-based tests. They degrade with
  ``tests.hypothesis_compat``: without hypothesis the strategy functions
  return ``None`` placeholders and the ``@given`` shim skips the test, so
  importing this module never requires hypothesis.

Mesh/device fakes (``FakeMesh``, the ``fake_device_kind`` fixture) live in
``tests/conftest.py`` — fixtures belong to conftest, data generators here.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from tests.hypothesis_compat import HAVE_HYPOTHESIS, st


# ---------------------------------------------------------------------------
# Deterministic generators (usable with or without hypothesis)
# ---------------------------------------------------------------------------


def make_problem(n, k, seed=0, dtype=np.float32, extra_pd=0.0):
    """Paper §5 experimental procedure: B, V ~ U[0,1], A = B^T B + I."""
    rng = np.random.default_rng(seed)
    B = rng.uniform(size=(n, n)).astype(dtype)
    V = rng.uniform(size=(n, k)).astype(dtype)
    A = B.T @ B + (1.0 + extra_pd) * np.eye(n, dtype=dtype)
    L = np.linalg.cholesky(A).T
    return jnp.asarray(L), jnp.asarray(V)


def make_batched_problem(B, n, k, seed=0, dtype=np.float32):
    """Stacked fleet of ``make_problem``s: ``(B, n, n)`` + ``(B, n, k)``."""
    Ls, Vs = zip(*[make_problem(n, k, seed=seed + 7 * b, dtype=dtype)
                   for b in range(B)])
    return jnp.stack(Ls), jnp.stack(Vs)


def make_banded_problem(nb, b, k, seed=0, dtype=np.float32):
    """Block-tridiagonal SPD problem with BLOCK-LOCAL modification columns.

    Builds a well-conditioned upper block-BIdiagonal factor U0 (diagonal
    dominance keeps every chain pivot far from zero), forms the
    block-tridiagonal A = U0^T U0 it induces, and draws V with each column
    supported inside one adjacent block-row pair — the structured kernel's
    contract (``repro.core.structure.assert_blocklocal``).

    Returns ``(Ad, Ao, V)``: (nb, b, b) diagonal blocks, (nb-1, b, b)
    super-diagonal blocks, and the (nb*b, k) modification.
    """
    rng = np.random.default_rng(seed)
    U0d = (np.triu(rng.uniform(0.2, 1.0, size=(nb, b, b)))
           + 2.0 * np.eye(b)).astype(dtype)
    U0o = (0.3 * rng.uniform(-1.0, 1.0, size=(max(nb - 1, 0), b, b))
           ).astype(dtype)
    mT = lambda x: np.swapaxes(x, -1, -2)
    Ad = mT(U0d) @ U0d
    if nb > 1:
        Ad[1:] += mT(U0o) @ U0o
        Ao = mT(U0d[:-1]) @ U0o
    else:
        Ao = np.zeros((0, b, b), dtype)
    n = nb * b
    V = np.zeros((n, k), dtype)
    for c in range(k):
        j = int(rng.integers(nb))       # anchor block row
        width = b if j == nb - 1 else 2 * b
        V[j * b:j * b + width, c] = 0.4 * rng.normal(size=width)
    return jnp.asarray(Ad), jnp.asarray(Ao), jnp.asarray(V)


def tol_for(dtype, n):
    # Long hyperbolic recurrences accumulate roundoff ~ sqrt(n) * eps * |A|.
    eps = jnp.finfo(dtype).eps
    return float(50 * eps * n)


def gauss_rows(n, m, seed, scale=0.3):
    """``m`` independent Gaussian rank-1 rows (stream-traffic fodder)."""
    rng = np.random.default_rng(seed)
    return [(scale * rng.normal(size=n)).astype(np.float32)
            for _ in range(m)]


def spd_stream(n, n_ops, seed):
    """Random interleaved ``(sign, row)`` stream that stays SPD under
    sequential application: every downdate removes HALF of a previously
    pushed update row, so each sequential prefix is >= the base matrix."""
    rng = np.random.default_rng(seed)
    stream, prior_ups = [], []
    for _ in range(n_ops):
        v = (0.4 * rng.normal(size=n)).astype(np.float32)
        stream.append((1, v))
        prior_ups.append(v)
        if prior_ups and rng.uniform() < 0.4:
            j = rng.integers(len(prior_ups))
            stream.append((-1, (0.5 * prior_ups[j]).astype(np.float32)))
    return stream


# ---------------------------------------------------------------------------
# Hypothesis strategies (placeholders without hypothesis — the @given shim
# in tests/hypothesis_compat.py skips before any strategy is drawn)
# ---------------------------------------------------------------------------

#: Problem-dimension strategies shared by the property tests.
dims = st.integers(min_value=4, max_value=48)
ranks = st.integers(min_value=1, max_value=6)
seeds = st.integers(min_value=0, max_value=2**31 - 1)
signs = st.sampled_from([1, -1]) if HAVE_HYPOTHESIS else None

if HAVE_HYPOTHESIS:

    @st.composite
    def spd_problems(draw, max_n=48, max_k=6):
        """Draw ``(L, V)`` from the paper's experimental distribution."""
        n = draw(st.integers(min_value=4, max_value=max_n))
        k = draw(st.integers(min_value=1, max_value=max_k))
        seed = draw(seeds)
        return make_problem(n, k, seed=seed)

    @st.composite
    def banded_spd_problems(draw, max_nb=6, max_b=8, max_k=4):
        """Draw ``(Ad, Ao, V)`` block-tridiagonal problems with block-local
        V columns (the structured-backend conformance distribution)."""
        nb = draw(st.integers(min_value=2, max_value=max_nb))
        b = draw(st.integers(min_value=2, max_value=max_b))
        k = draw(st.integers(min_value=1, max_value=max_k))
        seed = draw(seeds)
        return make_banded_problem(nb, b, k, seed=seed)

    @st.composite
    def feasible_streams(draw, max_n=24, max_ops=10):
        """Draw ``(n, stream)`` where every sequential prefix stays SPD —
        the feasibility-preserving up/down-date traffic of the coalescer's
        equivalence proof."""
        n = draw(st.integers(min_value=4, max_value=max_n))
        n_ops = draw(st.integers(min_value=1, max_value=max_ops))
        seed = draw(seeds)
        return n, spd_stream(n, n_ops, seed)

else:  # pragma: no cover - exercised only without hypothesis

    def spd_problems(max_n=48, max_k=6):
        return None

    def banded_spd_problems(max_nb=6, max_b=8, max_k=4):
        return None

    def feasible_streams(max_n=24, max_ops=10):
        return None
