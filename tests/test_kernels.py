"""Pallas kernel validation: interpret-mode vs pure-jnp oracle, shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import blocked, ref
from repro.kernels import cholupdate as K
from repro.kernels import ops

from tests.test_core_cholupdate import make_problem, tol_for


def make_panel_problem(P, k, w, seed=0, dtype=jnp.float32):
    """A coherent (R, vt, c, s, T) quintuple from a real diagonal pass."""
    rng = np.random.default_rng(seed)
    n = P + w
    B = rng.uniform(size=(n, n)).astype(np.float32)
    V = rng.uniform(size=(n, k)).astype(np.float32)
    A = B.T @ B + np.eye(n, dtype=np.float32)
    L = jnp.asarray(np.linalg.cholesky(A).T, dtype)
    vt = jnp.asarray(V.T, dtype)
    D, vtd = L[:P, :P], vt[:, :P]
    D_new, c, s, T = blocked.panel_diag(D, vtd, 1, with_transform=True)
    R = L[:P, P:]
    vtr = vt[:, P:]
    return R, vtr, c, s, T


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("P,k,w,block_w", [
    (8, 1, 16, 8),
    (16, 4, 64, 32),
    (32, 16, 96, 32),
    (32, 3, 70, 32),   # w not a multiple of block_w -> padding path
    (64, 8, 256, 128),
])
def test_panel_apply_paper_kernel(P, k, w, block_w, dtype):
    R, vt, c, s, _ = make_panel_problem(P, k, w, seed=P + k + w, dtype=dtype)
    R_ref, vt_ref = blocked.panel_apply_paper(R, vt, c, s, 1)
    R_pal, vt_pal = K.panel_apply_paper(
        R, vt, c, s, sigma=1, block_w=block_w, interpret=True
    )
    rtol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(R_pal, np.float32), np.asarray(R_ref, np.float32), rtol=rtol, atol=rtol
    )
    np.testing.assert_allclose(
        np.asarray(vt_pal, np.float32), np.asarray(vt_ref, np.float32), rtol=rtol, atol=rtol
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("P,k,w,block_w", [
    (16, 4, 64, 32),
    (32, 16, 100, 64),  # padding path
    (64, 8, 256, 128),
])
def test_panel_apply_gemm_kernel(P, k, w, block_w, dtype):
    R, vt, c, s, T = make_panel_problem(P, k, w, seed=2 * P + k, dtype=dtype)
    R_ref, vt_ref = blocked.panel_apply_gemm(R, vt, T)
    R_pal, vt_pal = K.panel_apply_gemm(R, vt, T, block_w=block_w, interpret=True)
    rtol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(R_pal, np.float32), np.asarray(R_ref, np.float32), rtol=rtol, atol=rtol
    )
    np.testing.assert_allclose(
        np.asarray(vt_pal, np.float32), np.asarray(vt_ref, np.float32), rtol=rtol, atol=rtol
    )


@pytest.mark.parametrize("sigma", [1, -1])
@pytest.mark.parametrize("P,k", [(8, 1), (16, 4), (32, 16)])
def test_diag_block_kernel(P, k, sigma):
    L, V = make_problem(P + 8, k, seed=P * k)
    if sigma == -1:
        A2 = L.T @ L + V @ V.T
        L = jnp.linalg.cholesky(A2).T
    D, vtd = L[:P, :P], V[:P].T
    D_ref, c_ref, s_ref, T_ref = blocked.panel_diag(D, vtd, sigma, with_transform=True)
    D_pal, c_pal, s_pal, T_pal = K.diag_block(D, vtd, sigma=sigma, interpret=True)
    np.testing.assert_allclose(D_pal, D_ref, atol=1e-5)
    np.testing.assert_allclose(c_pal, c_ref, atol=1e-6)
    np.testing.assert_allclose(s_pal, s_ref, atol=1e-6)
    np.testing.assert_allclose(T_pal, T_ref, atol=1e-5)


@pytest.mark.parametrize("strategy", ["paper", "gemm"])
@pytest.mark.parametrize("sigma", [1, -1])
def test_end_to_end_pallas_update(strategy, sigma):
    n, k = 256, 16
    L, V = make_problem(n, k, seed=99)
    if sigma == -1:
        A2 = L.T @ L + V @ V.T
        L = jnp.linalg.cholesky(A2).T
    L_ref = ref.chol_update_ref(L, V, sigma=sigma)
    L_pal = ops.chol_update_pallas(
        L, V, sigma=sigma, panel=64, strategy=strategy, block_w=64, interpret=True
    )
    np.testing.assert_allclose(L_pal, L_ref, atol=tol_for(jnp.float32, n))
    # Paper's own acceptance metric.
    assert float(ref.modify_error(L_pal, L, V, sigma=sigma)) < 1e-2


def test_transform_matrix_structure():
    """T is the product of unit-determinant 2x2 rotations: det(T) == 1."""
    _, _, _, _, T = make_panel_problem(16, 4, 32, seed=3)
    sign, logdet = jnp.linalg.slogdet(T)
    assert float(sign) == pytest.approx(1.0)
    assert float(logdet) == pytest.approx(0.0, abs=1e-4)
