"""Trace-free serving: AOT bucket-ladder warmup, slot admission, the
retrace guard, and the background flush worker (ISSUE 6).

The tentpole contract: after ``warmup()``, a scripted
admit/push/flush/evict/readmit/checkpoint/restore/flush sequence over
two ladder rungs triggers ZERO new traces — asserted by the retrace
guard (``assert_no_retrace``), whose counter every step-function body
bumps once per Python trace. Plus the satellite regression: a slot
recycled by evict→admit hands back a fresh ``init_scale * I`` factor
(no stale-slot bleed into padded batched mutations) on both dense and
sharded placements.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import chol_update_ref
from repro.stream import (
    FactorStore,
    LadderFullError,
    RetraceError,
    StreamService,
    assert_no_retrace,
    checkpoint_service,
    ladder_from,
    restore_service,
    warmup_store,
    watch_traces,
)
from repro.stream import store as store_mod
from tests.conftest import require_devices
from tests.strategies import gauss_rows as _rows, tol_for


def _ladder_store(n=8, *, ladder=(2, 4), width=3, backend="reference",
                  **kw):
    return FactorStore(n, capacity=ladder[0], ladder=ladder, width=width,
                      panel=4, backend=backend, **kw)


# ---------------------------------------------------------------------------
# The ladder + slot map
# ---------------------------------------------------------------------------


def test_derived_ladder_and_rung_snapping():
    st = FactorStore(4, capacity=3, panel=4, backend="reference")
    assert st.ladder == ladder_from(3) == (3, 6, 12, 24, 48, 96, 192, 384)
    assert st.capacity == 3
    # An explicit ladder snaps the requested capacity UP to a rung.
    st2 = FactorStore(4, capacity=3, ladder=(2, 4, 8), panel=4,
                      backend="reference")
    assert st2.capacity == 4
    with pytest.raises(ValueError):
        FactorStore(4, ladder=(4, 2), panel=4, backend="reference")
    with pytest.raises(LadderFullError):
        FactorStore(4, capacity=16, ladder=(2, 4), panel=4,
                    backend="reference")


def test_promotion_only_at_ladder_boundary_and_top_rung_refuses():
    st = _ladder_store(ladder=(2, 4))
    st.admit("a")
    st.admit("b")
    assert st.capacity == 2 and st.empty_slots == ()
    st.admit("c")                     # boundary: promote 2 -> 4
    assert st.capacity == 4
    assert st.slot_to_user == {0: "a", 1: "b", 2: "c"}
    assert st.empty_slots == (3,)
    st.admit("d")
    with pytest.raises(LadderFullError):
        st.admit("e")                 # top rung full: no silent growth
    st.evict("b")
    assert st.empty_slots == (1,)
    assert st.admit("e") == 1         # slot map recycles inside the rung


def test_compact_snaps_to_ladder_rung():
    st = _ladder_store(ladder=(2, 4, 8))
    for u in "abcde":
        st.admit(u)
    assert st.capacity == 8
    st.evict("d")
    st.evict("e")
    st.compact()
    assert st.capacity == 4           # smallest rung >= 3 active
    assert sorted(st.slot_to_user.values()) == ["a", "b", "c"]


def test_width_buckets_pick_smallest_padded_shape():
    st = _ladder_store(ladder=(4,), width=3)   # buckets (1, 3)
    assert st.widths == (1, 3)
    one = st.pad_block({0: np.ones((1, 8), np.float32)})
    assert one.shape == (4, 8, 1)
    two = st.pad_block({0: np.ones((2, 8), np.float32)})
    assert two.shape == (4, 8, 3)
    with pytest.raises(ValueError):
        st.pad_block({0: np.ones((4, 8), np.float32)})
    with pytest.raises(ValueError):
        FactorStore(8, width=4, widths=(1, 2), panel=4, backend="reference")


# ---------------------------------------------------------------------------
# Warmup + the retrace guard
# ---------------------------------------------------------------------------


def test_warmup_compiles_ladder_and_recaches_for_free():
    st = _ladder_store(ladder=(2, 4), width=2)  # buckets (1, 2)
    rep = warmup_store(st)
    # Per rung: up/down x2 widths + both x4 + scale + slot_set = 10;
    # two rungs + one promote boundary = 21 executables.
    assert rep.compiled + rep.cached == 21
    assert rep.rungs == (2, 4) and rep.widths == (1, 2)
    again = st.warmup()
    assert again.compiled == 0 and again.cached == 21
    assert st.steps.executables >= 21


def test_retrace_guard_fires_on_cold_signature():
    # Unique metadata (panel=5 appears nowhere else) => cold step set.
    st = FactorStore(6, capacity=2, width=2, panel=5, backend="reference")
    with pytest.raises(RetraceError):
        with assert_no_retrace("cold admit"):
            st.admit("u")
    # watch_traces is the no-fail twin for diagnostics.
    with watch_traces() as w:
        st.admit("v")
    assert w.traces == 0              # same signature: jit cache, no trace


def test_acceptance_trace_free_two_rung_serving_sequence(tmp_path):
    """ISSUE 6 acceptance: admit/push/flush/evict/readmit/checkpoint/
    restore/flush over TWO ladder rungs, zero traces after warmup()."""
    n, width = 8, 3
    st = _ladder_store(n, ladder=(2, 4), width=width)
    svc = StreamService(st, auto_flush=False)
    warmup_store(st)

    rows = {u: np.stack(_rows(n, width, seed=40 + i, scale=0.2))
            for i, u in enumerate("abcd")}
    with assert_no_retrace("two-rung serving sequence") as w:
        svc.admit("a")
        svc.admit("b")
        for u in ("a", "b"):
            for v in rows[u]:
                svc.push(u, v)
        svc.flush(force=True)
        svc.evict("b")
        svc.admit("c")                       # readmit into the freed slot
        svc.admit("d")                       # ladder boundary: 2 -> 4
        assert st.capacity == 4
        for u in ("c", "d"):
            for v in rows[u]:
                svc.push(u, v)
        svc.push("a", (0.5 * rows["a"][0]).astype(np.float32), sign=-1)
        svc.flush(force=True)
        svc.decay(0.9)
        checkpoint_service(svc, tmp_path, step=1)
        svc.push("c", rows["c"][0])          # WAL-only traffic
        survivor = restore_service(tmp_path, warm=True)
        r1 = svc.flush(force=True)
        r2 = survivor.flush(force=True)
    assert w.traces == 0
    assert r1.absorbed == r2.absorbed == {"c": 1}
    np.testing.assert_allclose(
        np.asarray(survivor.store.factor.data, np.float32),
        np.asarray(svc.store.factor.data, np.float32), atol=1e-6)


def test_warmup_bakes_portable_lowering_on_gpu_kind(fake_device_kind):
    """ISSUE 7 satellite: ``warmup_store`` under a (faked) GPU device kind
    compiles PORTABLE-lowering executables — the AOT ladder bakes the
    lowering the device would actually run — and the retrace guard still
    holds across a two-rung admit/flush/evict sequence, so the portable
    path introduces no fresh trace tier."""
    from repro.kernels import fused as fused_k

    fake_device_kind("gpu")
    n, width = 8, 2
    # panel=3 gives this store a unique StepSet signature: a warm cache
    # from another test (traced WITHOUT the fake kind) would have baked
    # the mosaic lowering and hidden the assertion below.
    st = FactorStore(n, capacity=2, ladder=(2, 4), width=width, panel=3,
                     backend="auto", interpret=True)
    svc = StreamService(st, auto_flush=False)
    before = fused_k.lowerings_traced()
    rep = warmup_store(st)
    after = fused_k.lowerings_traced()
    assert rep.lowering == "portable"
    assert after["portable"] > before["portable"]
    assert after["mosaic"] == before["mosaic"]

    rows = {u: np.stack(_rows(n, width, seed=140 + i, scale=0.2))
            for i, u in enumerate("abc")}
    with assert_no_retrace("gpu-kind two-rung serving sequence") as w:
        svc.admit("a")
        svc.admit("b")
        for u in ("a", "b"):
            for v in rows[u]:
                svc.push(u, v)
        svc.flush(force=True)
        svc.admit("c")                       # ladder boundary: 2 -> 4
        assert st.capacity == 4
        for v in rows["c"]:
            svc.push("c", v)
        svc.push("a", (0.5 * rows["a"][0]).astype(np.float32), sign=-1)
        svc.flush(force=True)
        svc.evict("b")
        svc.flush(force=True)
    assert w.traces == 0


def test_checkpoint_meta_records_ladder_and_slot_map(tmp_path):
    from repro import checkpoint as ckpt

    st = _ladder_store(ladder=(2, 4), width=2)
    svc = StreamService(st, auto_flush=False)
    svc.admit("a")
    svc.admit("b")
    svc.evict("a")
    checkpoint_service(svc, tmp_path, step=7)
    s = ckpt.read_meta(tmp_path, 7)["extra"]["stream"]
    assert s["ladder"] == [2, 4]
    assert s["widths"] == [1, 2]
    assert s["empty_slots"] == [0]
    assert s["slots"] == [["b", 1]]
    survivor = restore_service(tmp_path)
    assert survivor.store.ladder == (2, 4)
    assert survivor.store.widths == (1, 2)
    assert survivor.store.empty_slots == (0,)
    assert survivor.store.slot_to_user == {1: "b"}


# ---------------------------------------------------------------------------
# Satellite: evict -> admit slot recycling hands back a FRESH factor
# ---------------------------------------------------------------------------


def _assert_slot_reuse_is_fresh(st, *, atol):
    svc = StreamService(st, auto_flush=False)
    svc.admit("u1")
    for v in _rows(st.n, st.width, seed=50, scale=0.3):
        svc.push("u1", v)
    svc.flush(force=True)             # u1's slot now far from the warm start
    s1 = st.slot("u1")
    svc.evict("u1")
    svc.admit("u2")
    assert st.slot("u2") == s1        # LIFO free list recycles the slot
    np.testing.assert_allclose(
        np.asarray(st.factor_for("u2").data, np.float32),
        np.sqrt(st.init_scale) * np.eye(st.n, dtype=np.float32), atol=atol)
    # A padded batched mutation in which u2 contributes NOTHING must leave
    # the recycled slot exactly at the warm start (zero columns no-op).
    svc.admit("other")
    for v in _rows(st.n, st.width, seed=51, scale=0.3):
        svc.push("other", v)
    svc.flush(force=True)
    np.testing.assert_allclose(
        np.asarray(st.factor_for("u2").data, np.float32),
        np.sqrt(st.init_scale) * np.eye(st.n, dtype=np.float32), atol=atol)
    # ...and u2's own first flush lands on a fresh-start reference.
    rows2 = _rows(st.n, st.width, seed=52, scale=0.3)
    for v in rows2:
        svc.push("u2", v)
    svc.flush(force=True)
    ref = chol_update_ref(
        jnp.asarray(np.sqrt(st.init_scale) * np.eye(st.n), jnp.float32),
        jnp.asarray(np.stack(rows2, axis=1)), sigma=1)
    np.testing.assert_allclose(
        np.asarray(st.factor_for("u2").data, np.float32), np.asarray(ref),
        atol=atol)


def test_evict_readmit_recycled_slot_is_fresh_dense():
    st = FactorStore(10, capacity=4, width=4, panel=4, backend="reference",
                     init_scale=2.0)
    _assert_slot_reuse_is_fresh(st, atol=4 * tol_for(jnp.float32, 10))


def test_evict_readmit_recycled_slot_is_fresh_sharded():
    require_devices(2)
    from repro.runtime.compat import make_mesh_compat

    shards = 4 if jax.device_count() >= 4 else 2
    mesh = make_mesh_compat((shards,), ("model",),
                            devices=jax.devices()[:shards])
    st = FactorStore(8, capacity=2, width=2, panel=2, backend="sharded",
                     mesh=mesh, axis="model", init_scale=2.0)
    _assert_slot_reuse_is_fresh(st, atol=4 * tol_for(jnp.float32, 8))


def test_sharded_warmup_is_trace_free():
    """Sharded placement: warmup lowers against sharded avals, and the
    whole admit/push/flush/promote path dispatches AOT executables."""
    require_devices(2)
    from repro.runtime.compat import make_mesh_compat

    shards = 4 if jax.device_count() >= 4 else 2
    mesh = make_mesh_compat((shards,), ("model",),
                            devices=jax.devices()[:shards])
    st = FactorStore(8, capacity=2, width=2, ladder=(2, 4), panel=2,
                     backend="sharded", mesh=mesh, axis="model")
    svc = StreamService(st, auto_flush=False)
    warmup_store(st)
    with assert_no_retrace("sharded serving") as w:
        for i, u in enumerate("abc"):        # crosses the 2 -> 4 boundary
            svc.admit(u)
            for v in _rows(8, 2, seed=60 + i, scale=0.2):
                svc.push(u, v)
        svc.flush(force=True)
        svc.decay(0.95)
    assert w.traces == 0 and st.capacity == 4


# ---------------------------------------------------------------------------
# Background flush worker
# ---------------------------------------------------------------------------


def test_background_flush_matches_synchronous_twin():
    n, width, B, R = 8, 4, 3, 12
    rows = {u: _rows(n, R, seed=70 + u, scale=0.2) for u in range(B)}

    def drive(background):
        st = FactorStore(n, capacity=B, width=width, panel=4,
                        backend="reference")
        # Rings big enough for the whole trace: the bg producer can lap
        # the worker (width triggers coalesce), and an overflow here
        # would be backpressure kicking in, not a wrong answer.
        svc = StreamService(st, auto_flush=True, background=background,
                            capacity=R + width)
        for t in range(R):
            for u in range(B):
                svc.push(u, rows[u][t])
        if background:
            reports = svc.drain()
            svc.stop_background()
        else:
            reports = []
        svc.flush(force=True)         # absorb any sub-width tail
        return svc, reports

    sync_svc, _ = drive(False)
    bg_svc, reports = drive(True)
    assert not bg_svc.background_active
    assert all(r.reason in ("width", "deadline") for r in reports)
    for u in range(B):
        assert bg_svc.pending(u) == 0
    # Grouping may differ (the worker coalesces triggers), the absorbed
    # totals and the final fleet may not.
    np.testing.assert_allclose(
        np.asarray(bg_svc.store.factor.data, np.float32),
        np.asarray(sync_svc.store.factor.data, np.float32),
        atol=8 * tol_for(jnp.float32, n))


def test_background_worker_runs_flushes_off_thread():
    st = FactorStore(6, capacity=2, width=2, panel=4, backend="reference")
    svc = StreamService(st, auto_flush=True)
    svc.start_background()
    seen = {}
    orig = svc._run_flush

    def spy(selected, report):
        seen["thread"] = threading.current_thread().name
        return orig(selected, report)

    svc._run_flush = spy
    for v in _rows(6, 2, seed=80):
        svc.push("u", v)              # width trigger -> enqueued
    svc.drain()
    svc.stop_background()
    assert seen["thread"] == "stream-flush-worker"
    assert svc.pending("u") == 0


def test_background_worker_exception_surfaces_at_drain():
    st = FactorStore(6, capacity=2, width=2, panel=4, backend="reference")
    svc = StreamService(st, auto_flush=True, background=True)

    def boom(Vup=None, Vdn=None):
        raise RuntimeError("device on fire")

    st.apply = boom
    for v in _rows(6, 2, seed=81):
        svc.push("u", v)
    with pytest.raises(RuntimeError, match="device on fire"):
        svc.drain()
    svc.stop_background()             # already-reported: no re-raise


def test_background_service_checkpoint_restore_restarts_worker(tmp_path):
    st = FactorStore(6, capacity=2, width=2, panel=4, backend="reference")
    svc = StreamService(st, auto_flush=True, background=True)
    for v in _rows(6, 2, seed=82):
        svc.push("u", v)
    svc.drain()
    svc.push("u", _rows(6, 1, seed=83)[0])   # unflushed at checkpoint
    checkpoint_service(svc, tmp_path, step=1)
    svc.stop_background()

    survivor = restore_service(tmp_path)
    assert survivor.background_active        # the flag round-trips
    assert survivor.pending("u") == 1
    np.testing.assert_allclose(
        np.asarray(survivor.store.factor.data, np.float32),
        np.asarray(svc.store.factor.data, np.float32), atol=1e-6)
    survivor.stop_background()


def test_background_triggers_enqueue_and_worker_coalesces():
    """Every trigger enqueues (the bounded queue can actually fill —
    backpressure is real, not dead code) and the worker folds everything
    queued at wake-up into one flush pass."""
    st = FactorStore(6, capacity=4, width=2, panel=4, backend="reference")
    svc = StreamService(st, auto_flush=True, background=True, queue_size=8)
    orig = svc._flush_sync
    entered, release = threading.Event(), threading.Event()

    def gated(**kw):
        entered.set()
        release.wait(5)
        return orig(**kw)

    svc._flush_sync = gated
    for v in _rows(6, 2, seed=90):
        svc.push(0, v)                     # trigger 1 -> worker parks
    assert entered.wait(5)
    for u in (1, 2):
        for v in _rows(6, 2, seed=90 + u):
            svc.push(u, v)                 # triggers 2 and 3 queue up
    assert svc._worker.requests.qsize() == 2
    release.set()

    reports = svc.drain()
    svc.stop_background()
    # 3 requests, <= 2 flush passes: the parked pass plus one coalesced.
    assert len(reports) <= 2
    assert sum(sum(r.absorbed.values()) for r in reports) == 6
    for u in range(3):
        assert svc.pending(u) == 0


def test_drain_failure_clears_partial_reports():
    """A worker failure must not leave pre-failure reports behind to
    surface on a later unrelated drain; they ride on the exception."""
    st = FactorStore(6, capacity=2, width=2, panel=4, backend="reference")
    svc = StreamService(st, auto_flush=True, background=True)
    for v in _rows(6, 2, seed=84):
        svc.push("u", v)                   # good flush -> one report
    svc._worker.requests.join()

    def boom(Vup=None, Vdn=None):
        raise RuntimeError("device on fire")

    st.apply = boom
    for v in _rows(6, 2, seed=85):
        svc.push("u", v)
    with pytest.raises(RuntimeError, match="device on fire") as ei:
        svc.drain()
    assert len(ei.value.partial_reports) == 1
    assert sum(ei.value.partial_reports[0].absorbed.values()) == 2
    assert svc.drain() == ()               # nothing left behind
    svc.stop_background()


def test_checkpoint_waits_for_inflight_background_flush(tmp_path):
    """checkpoint_service serialises against the worker via the service
    lock: a checkpoint requested mid-flush snapshots the post-flush
    state, never a torn one."""
    st = FactorStore(6, capacity=2, width=2, panel=4, backend="reference")
    svc = StreamService(st, auto_flush=True, background=True)
    orig = st.apply
    entered, release = threading.Event(), threading.Event()

    def slow(Vup=None, Vdn=None):
        entered.set()
        release.wait(5)
        return orig(Vup, Vdn)

    st.apply = slow
    for v in _rows(6, 2, seed=86):
        svc.push("u", v)
    assert entered.wait(5)                 # worker mid-flush, lock held
    done = threading.Event()

    def snapshot():
        checkpoint_service(svc, tmp_path, step=1)
        done.set()

    t = threading.Thread(target=snapshot)
    t.start()
    assert not done.wait(0.2)              # blocked until the flush lands
    release.set()
    t.join(10)
    assert done.is_set()
    svc.drain()
    svc.stop_background()

    survivor = restore_service(tmp_path)
    assert survivor.pending("u") == 0      # flush preceded the snapshot
    np.testing.assert_allclose(
        np.asarray(survivor.store.factor.data, np.float32),
        np.asarray(svc.store.factor.data, np.float32), atol=1e-6)
    survivor.stop_background()


# ---------------------------------------------------------------------------
# ISSUE 10: structured fleets stay trace-free through the same ladder
# ---------------------------------------------------------------------------


def _blocklocal(n, block, m, seed, scale=0.2):
    rng = np.random.default_rng(seed)
    out = []
    nb = n // block
    for _ in range(m):
        j = int(rng.integers(0, max(nb - 1, 1)))
        v = np.zeros(n, np.float32)
        hi = min((j + 2) * block, n)
        v[j * block:hi] = scale * rng.normal(size=hi - j * block)
        out.append(v)
    return out


def test_acceptance_trace_free_two_rung_structured_sequence(tmp_path):
    """ISSUE 10 acceptance: the SAME two-rung admit/flush/evict/readmit/
    checkpoint/restore/flush sequence, on a blocktridiag fleet — zero
    step traces after warmup() (the structured avals are AOT-compiled),
    and the warm restore reproduces the block stacks bitwise."""
    n, block, width = 16, 4, 3
    st = FactorStore(n, capacity=2, ladder=(2, 4), width=width, panel=4,
                     interpret=True, structure="blocktridiag", block=block)
    svc = StreamService(st, auto_flush=False)
    warmup_store(st)

    rows = {u: _blocklocal(n, block, width, seed=60 + i)
            for i, u in enumerate("abcd")}
    with assert_no_retrace("two-rung structured serving") as w:
        svc.admit("a")
        svc.admit("b")
        for u in ("a", "b"):
            for v in rows[u]:
                svc.push(u, v)
        svc.flush(force=True)
        svc.evict("b")
        svc.admit("c")                       # readmit into the freed slot
        svc.admit("d")                       # ladder boundary: 2 -> 4
        assert st.capacity == 4
        for u in ("c", "d"):
            for v in rows[u]:
                svc.push(u, v)
        svc.push("a", (0.5 * rows["a"][0]).astype(np.float32), sign=-1)
        svc.flush(force=True)
        svc.decay(0.9)
        checkpoint_service(svc, tmp_path, step=1)
        svc.push("c", rows["c"][0])          # WAL-only traffic
        survivor = restore_service(tmp_path, warm=True)
        r1 = svc.flush(force=True)
        r2 = survivor.flush(force=True)
    assert w.traces == 0
    assert r1.absorbed == r2.absorbed == {"c": 1}
    assert survivor.store.structure == "blocktridiag"
    for a, b in zip(jax.tree_util.tree_leaves(svc.store.factor.data),
                    jax.tree_util.tree_leaves(survivor.store.factor.data)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_evict_readmit_recycled_slot_is_fresh_structured():
    """The structured twin of the dense slot-recycling test: a recycled
    slot's block stacks return exactly to sqrt(init_scale) * I (identity
    diag blocks, zero off blocks) — never a stale member."""
    n, block = 8, 4
    st = FactorStore(n, capacity=2, width=2, panel=4, interpret=True,
                     structure="blocktridiag", block=block, init_scale=2.0)
    svc = StreamService(st, auto_flush=False)
    svc.admit("u1")
    for v in _blocklocal(n, block, 2, seed=70, scale=0.3):
        svc.push("u1", v)
    svc.flush(force=True)
    s1 = st.slot("u1")
    svc.evict("u1")
    svc.admit("u2")
    assert st.slot("u2") == s1
    member = st.factor_for("u2").data
    np.testing.assert_allclose(
        np.asarray(member.diag, np.float32),
        np.broadcast_to(np.sqrt(2.0) * np.eye(block, dtype=np.float32),
                        (n // block, block, block)), atol=1e-6)
    np.testing.assert_array_equal(
        np.asarray(member.off, np.float32),
        np.zeros((n // block - 1, block, block), np.float32))


def test_structured_contract_violation_fails_at_push():
    """A row spanning non-adjacent blocks raises at push() time — the
    coalescer is keyed to the fleet's block size — and leaves the ring
    untouched (no poisoned row waiting to fail inside the kernel)."""
    st = FactorStore(8, capacity=2, width=2, panel=4, interpret=True,
                     structure="blocktridiag", block=2)
    svc = StreamService(st, auto_flush=False)
    svc.admit("u")
    bad = np.zeros(8, np.float32)
    bad[0] = bad[7] = 1.0                    # blocks 0 and 3: not adjacent
    with pytest.raises(ValueError, match="block rows 0..3"):
        svc.push("u", bad)
    assert svc.pending("u") == 0
    ok = np.zeros(8, np.float32)
    ok[2:6] = 1.0                            # pair {1, 2}: block-local
    svc.push("u", ok)
    assert svc.pending("u") == 1
