"""Backend-conformance property harness (ISSUE 5).

ONE parametrized suite asserting that every backend in the registry — the
serial oracle, the blocked jnp drivers, the per-panel Pallas kernels, the
single-launch fused kernel, and the (batched) sharded multi-device driver
— agrees on update / downdate / solve / logdet / grad, across
{fp32, bf16} × {single, batched}. Agreement used to be asserted piecemeal
per test file; any NEW backend registered in ``repro.core.backends`` gets
this coverage for free (the matrix is built from the registry, not from a
hand-kept list — a registered-but-untested backend fails the suite).

Per-backend skip markers: the sharded column needs >= 2 devices and skips
cleanly on a single-device run; the CI shard-emulation job
(``XLA_FLAGS=--xla_force_host_platform_device_count=4``) runs it on every
push, and the slow subprocess test at the bottom runs the same column
under an emulated 4-device mesh from any host.

The suite also carries the launch/mutation-count regression budget
(ISSUE 5 satellite): a table keyed by backend of how many Pallas launches
one rank-k update may construct — so a refactor that silently
reintroduces the per-panel kernel cascade fails tier-1, not a benchmark
eyeball.
"""
import functools
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pl

from repro.core import CholFactor, backends, chol_update_ref
from repro.core import structure
from repro.core.structure import BlockTriDiagStorage
from repro.kernels import blocktridiag as btd_k
from repro.kernels import fused as fused_k
from repro.kernels import sharded as sharded_k
from repro.runtime.compat import make_mesh_compat
from tests.conftest import require_devices
from tests.hypothesis_compat import given, settings
from tests.strategies import (
    banded_spd_problems,
    make_banded_problem,
    make_batched_problem,
    make_problem,
    spd_problems,
    tol_for,
)

N, K, PANEL, B = 64, 4, 16, 3
BF16_RTOL = 32 * 2.0 ** -8  # DESIGN.md §8 single-update tolerance

#: The DENSE columns only: the structured backends take array-shaped
#: inputs these tests cannot feed (the registry itself reports the split —
#: ``names(structure=...)``); they get their own axis below.
ALL_BACKENDS = backends.names(structure="dense")
#: The matrix columns: every registered backend, plus the fused kernel's
#: portable lowering as its own pseudo-column (same 'fused' registration,
#: ``lowering='portable'`` opt — the GPU single-launch path, DESIGN.md §5).
MATRIX_COLUMNS = ALL_BACKENDS + ("fused_portable",)
#: The structure axis (ISSUE 8): block-tridiagonal columns, checked against
#: the dense reference on banded SPD problems.
STRUCTURED_COLUMNS = backends.names(structure="blocktridiag")
SHAPES = ("single", "batched")
PRECISIONS = (None, "bf16")

NB, BLK = 8, 8  # structured problems: 8 blocks of 8 -> n = N = 64


def _registry_is_covered():
    # The matrix derives from the registry: this test exists so the
    # parametrization below can never silently lag a new registration.
    assert set(ALL_BACKENDS) >= {"reference", "paper", "gemm", "pallas",
                                 "pallas_gemm", "fused", "sharded"}
    assert set(STRUCTURED_COLUMNS) >= {"blocktridiag", "blocktridiag_ref"}
    # Dense and structured validity are disjoint: a dense column handed a
    # structured factor (or vice versa) is a registry bug.
    assert not set(ALL_BACKENDS) & set(STRUCTURED_COLUMNS)


def test_matrix_covers_the_whole_registry():
    _registry_is_covered()


@functools.lru_cache(maxsize=1)
def _mesh():
    """A mesh over min(4, device_count) devices (the conformance shards)."""
    shards = 4 if jax.device_count() >= 4 else 2
    return make_mesh_compat((shards,), ("model",),
                            devices=jax.devices()[:shards])


def _factor(backend, data, precision=None):
    """A ``CholFactor`` wired for ``backend`` (skips when unrunnable).

    ``backend`` may be a matrix pseudo-column: 'fused_portable' is the
    'fused' registration with the portable lowering pinned. The plain
    'fused' column pins 'mosaic' so both columns stay deterministic under
    the CI routing job's REPRO_FAKE_DEVICE_KIND=gpu environment (where
    'auto' would resolve both to portable).
    """
    meta = dict(panel=PANEL, backend=backend, precision=precision)
    if backend == "fused_portable":
        meta.update(backend="fused", lowering="portable")
    elif backend == "fused":
        meta.update(lowering="mosaic")
    if backend == "sharded":
        require_devices(2)
        meta.update(mesh=_mesh(), axis="model", interpret=None)
    else:
        meta.update(interpret=True)
    return CholFactor.from_factor(data, **meta)


def _problem(shape, precision, *, n=N, k=K, seed=0):
    if shape == "batched":
        L, V = make_batched_problem(B, n, k, seed=seed)
    else:
        L, V = make_problem(n, k, seed=seed)
    if precision is not None:
        L = L.astype(jnp.bfloat16)
    return L, V


def _ref_update(L32, V, sigma=1):
    if L32.ndim == 3:
        return jnp.stack([chol_update_ref(L32[b], V[b], sigma=sigma)
                          for b in range(L32.shape[0])])
    return chol_update_ref(L32, V, sigma=sigma)


def _rel_frob_A(out, ref):
    """Relative Frobenius distance of the reconstructed A's (batched-safe)."""
    o = jnp.asarray(out, jnp.float32)
    r = jnp.asarray(ref, jnp.float32)
    oA = jnp.swapaxes(o, -1, -2) @ o
    rA = jnp.swapaxes(r, -1, -2) @ r
    return float(jnp.max(jnp.linalg.norm(oA - rA, axis=(-2, -1))
                         / jnp.linalg.norm(rA, axis=(-2, -1))))


# ---------------------------------------------------------------------------
# Agreement: update + downdate roundtrip
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("precision", PRECISIONS, ids=["f32", "bf16"])
@pytest.mark.parametrize("backend", MATRIX_COLUMNS)
def test_update_and_downdate_agree_with_reference(backend, precision, shape):
    _registry_is_covered()
    L, V = _problem(shape, precision)
    L32 = jnp.asarray(L, jnp.float32)
    f = _factor(backend, L, precision=precision)
    up = f.update(V)
    ref_up = _ref_update(L32, V, sigma=1)
    if precision is None:
        np.testing.assert_allclose(
            np.asarray(up.data), np.asarray(ref_up),
            atol=tol_for(jnp.float32, N), err_msg=f"{backend} update")
    else:
        assert up.dtype == jnp.bfloat16, backend
        assert _rel_frob_A(up.data, ref_up) < BF16_RTOL, backend
    # Downdate the update back out: the paper's reversibility invariant.
    back = up.downdate(V)
    if precision is None:
        np.testing.assert_allclose(
            np.asarray(back.data), np.asarray(L32),
            atol=8 * tol_for(jnp.float32, N), err_msg=f"{backend} downdate")
    else:
        assert _rel_frob_A(back.data, L32) < 2 * BF16_RTOL, backend
    assert bool(jnp.all(back.is_valid()))


# ---------------------------------------------------------------------------
# Agreement: the consumer reads (solve / logdet) off an updated factor
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("backend", MATRIX_COLUMNS)
def test_solve_and_logdet_agree_with_reference(backend, shape):
    L, V = _problem(shape, None)
    f = _factor(backend, L).update(V)
    ref_up = _ref_update(L, V, sigma=1)
    rhs = jnp.ones(L.shape[:-2] + (N,), jnp.float32)
    ref_f = CholFactor.from_factor(ref_up, backend="reference")
    np.testing.assert_allclose(
        np.asarray(f.solve(rhs)), np.asarray(ref_f.solve(rhs)),
        atol=1e-3, err_msg=f"{backend} solve")
    np.testing.assert_allclose(
        np.asarray(f.logdet()), np.asarray(ref_f.logdet()),
        atol=1e-3, err_msg=f"{backend} logdet")


# ---------------------------------------------------------------------------
# Agreement: jax.grad through every backend (Murray rules, DESIGN.md §7)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("backend", MATRIX_COLUMNS)
def test_grad_agrees_with_reference_backend(backend, shape):
    n, k, panel = 16, 2, 4
    if shape == "batched":
        L, V = make_batched_problem(2, n, k, seed=5)
    else:
        L, V = make_problem(n, k, seed=5)

    def loss_with(name):
        meta = dict(panel=panel, backend=name)
        if name == "fused_portable":
            meta.update(backend="fused", lowering="portable")
        if name == "sharded":
            require_devices(2)
            meta.update(mesh=_mesh(), axis="model")
        else:
            meta.update(interpret=True)

        def loss(L, V):
            out = CholFactor.from_factor(L, **meta).update(V).data
            return jnp.sum(jnp.sin(out) * jnp.cos(0.5 * out))

        return loss

    gL, gV = jax.grad(loss_with(backend), argnums=(0, 1))(L, V)
    rL, rV = jax.grad(loss_with("reference"), argnums=(0, 1))(L, V)
    np.testing.assert_allclose(np.asarray(gL), np.asarray(rL), atol=1e-4,
                               err_msg=f"{backend} dL")
    np.testing.assert_allclose(np.asarray(gV), np.asarray(rV), atol=1e-4,
                               err_msg=f"{backend} dV")


# ---------------------------------------------------------------------------
# Structure axis (ISSUE 8): blocktridiag columns vs the dense reference on
# banded SPD problems. Deterministic twins — these run with or without
# hypothesis; the property variant below adds random shapes on top.
# ---------------------------------------------------------------------------


def _banded(backend, precision=None, seed=0):
    """A structured CholFactor + block-local V + the dense f32 baseline."""
    Ad, Ao, V = make_banded_problem(NB, BLK, K, seed=seed)
    f = CholFactor.from_blocktridiag(Ad, Ao, panel=PANEL, backend=backend,
                                     interpret=True, precision=precision)
    L32 = f.data.to_dense()
    if precision is not None:
        f = f.replace(data=f.data.astype(jnp.bfloat16))
    return f, V, L32


@pytest.mark.parametrize("precision", PRECISIONS, ids=["f32", "bf16"])
@pytest.mark.parametrize("backend", STRUCTURED_COLUMNS)
def test_structured_update_and_downdate_agree_with_dense_reference(
        backend, precision):
    _registry_is_covered()
    f, V, L32 = _banded(backend, precision=precision)
    up = f.update(V)
    ref_up = chol_update_ref(L32, V, sigma=1)
    if precision is None:
        np.testing.assert_allclose(
            np.asarray(up.data.to_dense()), np.asarray(ref_up),
            atol=tol_for(jnp.float32, N), err_msg=f"{backend} update")
    else:
        assert up.dtype == jnp.bfloat16, backend
        assert _rel_frob_A(up.data.to_dense(), ref_up) < BF16_RTOL, backend
    back = up.downdate(V)
    if precision is None:
        np.testing.assert_allclose(
            np.asarray(back.data.to_dense()), np.asarray(L32),
            atol=8 * tol_for(jnp.float32, N), err_msg=f"{backend} downdate")
    else:
        assert _rel_frob_A(back.data.to_dense(), L32) < 2 * BF16_RTOL, backend
    assert bool(back.is_valid())


@pytest.mark.parametrize("backend", STRUCTURED_COLUMNS)
def test_structured_solve_and_logdet_agree_with_dense_reference(backend):
    f, V, L32 = _banded(backend)
    up = f.update(V)
    ref_f = CholFactor.from_factor(chol_update_ref(L32, V, sigma=1),
                                   backend="reference")
    rhs = jnp.ones((N,), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(up.solve(rhs)), np.asarray(ref_f.solve(rhs)),
        atol=1e-3, err_msg=f"{backend} solve")
    np.testing.assert_allclose(
        np.asarray(up.logdet()), np.asarray(ref_f.logdet()),
        atol=1e-3, err_msg=f"{backend} logdet")
    # The PD guard refuses an infeasible downdate and leaves every block
    # bitwise unchanged (the structured jnp.where masks the whole pytree).
    guarded, ok = up.downdate_guarded(100.0 * V)
    assert not bool(ok)
    np.testing.assert_array_equal(np.asarray(guarded.data.diag),
                                  np.asarray(up.data.diag))


@pytest.mark.parametrize("backend", STRUCTURED_COLUMNS)
def test_structured_grad_agrees_with_dense_reference(backend):
    """jax.grad through the structured update matches the dense Murray
    rule on the SAME observable: a loss over the band blocks of the
    updated factor (what the storage holds — the dense factor's off-band
    entries are structurally zero there, so a loss reading them would be
    a different function, not a fair comparison). The block-leaf grads
    come out via band extraction of the dense grad: the embedding
    blocks->dense is linear, so its adjoint IS extraction.

    The V-grad is compared on each column's anchor-pair support rows
    only: the blockwise tangent rule (ISSUE 10) is defined on the
    block-local perturbation family — dV components OUTSIDE a column's
    adjacent block pair are out-of-family directions (they would leave
    the storage class in the primal too), so the dense reference's
    gradient there is the derivative of a different function. On the
    contract's directions the two rules agree to rounding; diag/off
    grads are in-family by construction and compare in full."""
    f, V, L32 = _banded(backend, seed=3)
    S = f.data

    def band_loss(diag, off):
        return (jnp.sum(jnp.sin(diag) * jnp.cos(0.5 * diag))
                + jnp.sum(jnp.sin(off) * jnp.cos(0.5 * off)))

    def loss_structured(diag, off, V):
        g = CholFactor.from_factor(BlockTriDiagStorage(diag, off),
                                   panel=PANEL, backend=backend,
                                   interpret=True)
        out = g.update(V).data
        return band_loss(out.diag, out.off)

    def loss_dense(L, V):
        out = CholFactor.from_factor(L, panel=PANEL, backend="reference",
                                     interpret=True).update(V).data
        outS = BlockTriDiagStorage.from_dense(out, BLK)
        return band_loss(outS.diag, outS.off)

    gd, go, gV = jax.grad(loss_structured, argnums=(0, 1, 2))(
        S.diag, S.off, V)
    rL, rV = jax.grad(loss_dense, argnums=(0, 1))(L32, V)
    support = np.zeros(V.shape, bool)
    for m in range(V.shape[1]):
        j = structure.anchor_block(np.asarray(V[:, m]), BLK)
        if j is not None:
            support[j * BLK:min((j + 2) * BLK, N), m] = True
    np.testing.assert_allclose(np.asarray(gV)[support],
                               np.asarray(rV)[support], atol=1e-4,
                               err_msg=f"{backend} dV (anchor-pair rows)")
    rS = BlockTriDiagStorage.from_dense(rL, BLK)
    np.testing.assert_allclose(np.asarray(gd), np.asarray(rS.diag),
                               atol=1e-4, err_msg=f"{backend} d(diag)")
    np.testing.assert_allclose(np.asarray(go), np.asarray(rS.off),
                               atol=1e-4, err_msg=f"{backend} d(off)")


@settings(max_examples=10, deadline=None)
@given(problem=banded_spd_problems(max_nb=5, max_b=8, max_k=3))
def test_property_structured_backends_agree_on_random_banded(problem):
    Ad, Ao, V = problem
    n = Ad.shape[0] * Ad.shape[1]
    ref = None
    for backend in STRUCTURED_COLUMNS:
        f = CholFactor.from_blocktridiag(Ad, Ao, backend=backend,
                                         interpret=True)
        out = f.update(V).data.to_dense()
        if ref is None:
            ref = chol_update_ref(f.data.to_dense(), V, sigma=1)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref),
            atol=4 * tol_for(jnp.float32, n), err_msg=backend)


# ---------------------------------------------------------------------------
# Routing: the auto heuristic per (faked) device kind
# ---------------------------------------------------------------------------


def test_auto_routing_per_device_kind(fake_device_kind):
    """The shared fake_device_kind fixture (conftest) drives the one probe
    both resolve() and default_interpret() read — no real hardware."""
    fake_device_kind("tpu")
    assert backends.resolve("auto", n=N) == "fused"
    assert backends.resolve_lowering("auto") == "mosaic"
    assert backends.default_interpret() is False
    assert backends.default_interpret(mosaic_only=True) is False
    for kind in ("gpu", "cuda", "rocm"):
        fake_device_kind(kind)
        # ISSUE 7 acceptance: the paper's target hardware takes the
        # single-launch fused path via the portable lowering — no more
        # routing GPU to the O(n/panel)-launch per-panel cascade.
        assert backends.resolve("auto", n=N) == "fused"
        assert backends.resolve_lowering("auto") == "portable"
        assert backends.default_interpret() is False
        assert backends.default_interpret(lowering="portable") is False
        assert backends.default_interpret(lowering="mosaic") is True
        assert backends.default_interpret(mosaic_only=True) is True
    fake_device_kind("cpu")
    assert backends.resolve("auto", n=N) in ("reference", "gemm")
    assert backends.resolve_lowering("auto") == "mosaic"
    assert backends.default_interpret() is True
    # The structure axis routes through the SAME heuristic: kernel on
    # Pallas-capable kinds (or interpret), lax.scan twin elsewhere; a
    # dense-only method asked to modify structured storage is an error.
    assert backends.resolve("auto", n=N, structure="blocktridiag") == \
        "blocktridiag_ref"
    assert backends.resolve("auto", n=N, structure="blocktridiag",
                            interpret=True) == "blocktridiag"
    for kind in ("tpu", "gpu"):
        fake_device_kind(kind)
        assert backends.resolve("auto", n=N, structure="blocktridiag") == \
            "blocktridiag"
    with pytest.raises(ValueError, match="structures"):
        backends.resolve("fused", n=N, structure="blocktridiag")
    with pytest.raises(ValueError, match="structures"):
        backends.resolve("blocktridiag", n=N, structure="dense")


def test_resolve_lowering_explicit_and_invalid():
    assert backends.resolve_lowering("mosaic", device_kind="gpu") == "mosaic"
    assert backends.resolve_lowering("portable", device_kind="tpu") == \
        "portable"
    assert backends.resolve_lowering(None, device_kind="cuda") == "portable"
    with pytest.raises(ValueError, match="lowering"):
        backends.resolve_lowering("triton", device_kind="gpu")


# ---------------------------------------------------------------------------
# Property: every cheap backend lands on the same factor (hypothesis)
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(problem=spd_problems(max_n=32, max_k=4))
def test_property_backends_agree_on_random_problems(problem):
    L, V = problem
    n = L.shape[0]
    ref = chol_update_ref(L, V, sigma=1)
    for backend in ("paper", "gemm", "fused"):
        out = CholFactor.from_factor(L, panel=16, backend=backend,
                                     interpret=True).update(V)
        np.testing.assert_allclose(
            np.asarray(out.data), np.asarray(ref),
            atol=4 * tol_for(jnp.float32, n), err_msg=backend)


# ---------------------------------------------------------------------------
# Launch/mutation budget regression (ISSUE 5 satellite): the table
# ---------------------------------------------------------------------------

#: Pallas launches ONE rank-k update may construct, keyed by backend.
#: ``None`` defers to the module's own accounting formula; jnp backends
#: must construct none. The sharded entry is launches per shard — under
#: SPMD one traced construction IS the per-shard launch, independent of
#: both the fleet size B and the number of shards.
LAUNCH_BUDGET = {
    "reference": 0,
    "paper": 0,
    "gemm": 0,
    "pallas": fused_k.launch_count(N, PANEL, method="pallas"),
    "pallas_gemm": fused_k.launch_count(N, PANEL, method="pallas_gemm"),
    "fused": fused_k.launch_count(N, PANEL, method="fused"),
    # ISSUE 7 acceptance: the portable lowering keeps the single-launch
    # contract — 1 pallas_call construction per sign block, same as mosaic.
    "fused_portable": fused_k.launch_count(N, PANEL, method="fused"),
    "sharded": 1,
    # ISSUE 8 acceptance: the whole block chain in ONE pallas_call per
    # sign block; the lax.scan twin constructs none.
    "blocktridiag": btd_k.launch_count(),
    "blocktridiag_ref": 0,
}

#: Batched engine mutations one FactorStore.apply may dispatch, by blocks.
MUTATION_BUDGET = {"up_only": 1, "down_only": 1, "both": 2}


def test_launch_budget_table_is_total():
    # Every matrix column must carry a budget — a new backend without
    # one fails here, not silently.
    assert set(LAUNCH_BUDGET) == set(MATRIX_COLUMNS) | set(STRUCTURED_COLUMNS)


@pytest.mark.parametrize("backend", STRUCTURED_COLUMNS)
def test_structured_pallas_launch_budget(backend, monkeypatch):
    """One structured rank-k update constructs exactly its budgeted number
    of pallas_calls (ONE for the block-chain kernel, zero for the twin) —
    and the kernel's own trace counter agrees."""
    f, V, _ = _banded(backend)
    count = [0]
    real = pl.pallas_call

    def counting(*args, **kw):
        count[0] += 1
        return real(*args, **kw)

    monkeypatch.setattr(pl, "pallas_call", counting)
    jax.clear_caches()
    before = btd_k.launches_traced()
    jax.block_until_ready(f.update(V).data)
    assert count[0] == LAUNCH_BUDGET[backend], (
        f"{backend}: {count[0]} pallas_call constructions, budget "
        f"{LAUNCH_BUDGET[backend]}")
    assert btd_k.launches_traced() - before == LAUNCH_BUDGET[backend]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("backend", MATRIX_COLUMNS)
def test_pallas_launch_budget(backend, shape, monkeypatch):
    """A rank-k update constructs exactly its budgeted number of
    pallas_calls — batched or not (vmap/the fleet grid fold B into the
    SAME launches). Counted by patching the one constructor every kernel
    module routes through, so a reintroduced per-panel cascade is caught
    no matter which module hosts it."""
    L, V = _problem(shape, None, n=N, k=K)
    f = _factor(backend, L)
    count = [0]
    real = pl.pallas_call

    def counting(*args, **kw):
        count[0] += 1
        return real(*args, **kw)

    monkeypatch.setattr(pl, "pallas_call", counting)
    # The kernel wrappers are jitted: force a retrace so every pallas_call
    # construction actually runs (a warm cache would count zero).
    jax.clear_caches()
    lo_before = fused_k.lowerings_traced()
    f.update(V).data.block_until_ready()
    assert count[0] == LAUNCH_BUDGET[backend], (
        f"{backend}/{shape}: {count[0]} pallas_call constructions, "
        f"budget {LAUNCH_BUDGET[backend]} — the launch-fusion story "
        "regressed")
    lo_after = fused_k.lowerings_traced()
    if backend == "fused_portable":
        # The single construction really was the portable spec.
        assert lo_after["portable"] - lo_before["portable"] == 1
        assert lo_after["mosaic"] == lo_before["mosaic"]
    elif backend == "fused":
        assert lo_after["mosaic"] - lo_before["mosaic"] == 1
        assert lo_after["portable"] == lo_before["portable"]


def test_sharded_launches_traced_counter_matches_budget():
    """The module's own instrumentation agrees with the budget table, and
    is independent of B (shards × sign blocks is the whole cost)."""
    require_devices(2)
    for shape in SHAPES:
        L, V = _problem(shape, None)
        f = _factor("sharded", L)
        before = sharded_k.launches_traced()
        f.update(V).data.block_until_ready()
        assert sharded_k.launches_traced() - before == \
            LAUNCH_BUDGET["sharded"], shape


@pytest.mark.parametrize("backend",
                         ["reference", "fused", "sharded", "blocktridiag"])
def test_store_mutation_budget(backend):
    """FactorStore.apply dispatches exactly one batched mutation per sign
    block — the stream half of the launch story — on every backend,
    including the sharded fleet and the structured (blocktridiag) fleet
    (ISSUE 10: the stream×structure row)."""
    from repro.stream import FactorStore
    from repro.stream import store as store_mod

    n, width, users = 32, 4, 3
    # panel 8 divides the per-shard column count on both a 2- and 4-way
    # mesh (w_loc = 16 / 8).
    kw = dict(capacity=users, width=width, panel=8)
    if backend == "sharded":
        require_devices(2)
        kw.update(backend="sharded", mesh=_mesh(), axis="model")
    elif backend == "blocktridiag":
        kw.update(backend=backend, interpret=True,
                  structure="blocktridiag", block=8)
    else:
        kw.update(backend=backend, interpret=True)
    st_ = FactorStore(n, **kw)
    for u in range(users):
        st_.admit(u)
    rng = np.random.default_rng(0)
    if backend == "blocktridiag":
        # Block-local rows (the structured modification contract).
        rows = {}
        for u in range(users):
            r = np.zeros((2, n), np.float32)
            r[:, 8:24] = 0.2 * rng.normal(size=(2, 16))
            rows[st_.slot(u)] = r
    else:
        rows = {st_.slot(u):
                (0.2 * rng.normal(size=(2, n))).astype(np.float32)
                for u in range(users)}
    blk = st_.pad_block(rows)

    before = store_mod.mutations_issued()
    st_.apply(Vup=blk)
    assert store_mod.mutations_issued() - before == \
        MUTATION_BUDGET["up_only"], backend
    before = store_mod.mutations_issued()
    st_.apply(Vup=blk, Vdn=blk)
    assert store_mod.mutations_issued() - before == \
        MUTATION_BUDGET["both"], backend


def test_structured_store_flush_is_one_launch_per_sign_block(monkeypatch):
    """ISSUE 10 stream×structure launch row: a whole structured FLEET
    flush constructs exactly ONE block-chain pallas_call per sign block —
    vmap folds the batch into the kernel grid, so the count is
    independent of the fleet size B (same contract as the dense fused
    column, at O(n·b) storage)."""
    from repro.stream import FactorStore

    n, block, users = 32, 8, 3
    st_ = FactorStore(n, capacity=users, width=2, panel=8,
                      backend="blocktridiag", interpret=True,
                      structure="blocktridiag", block=block)
    for u in range(users):
        st_.admit(u)
    rng = np.random.default_rng(1)
    rows = {}
    for u in range(users):
        r = np.zeros((1, n), np.float32)
        r[:, 8:24] = 0.2 * rng.normal(size=16)
        rows[st_.slot(u)] = r
    blk = st_.pad_block(rows)

    count = [0]
    real = pl.pallas_call

    def counting(*args, **kw):
        count[0] += 1
        return real(*args, **kw)

    monkeypatch.setattr(pl, "pallas_call", counting)
    jax.clear_caches()
    before = btd_k.launches_traced()
    st_.apply(Vup=blk, Vdn=blk)
    per_sign = btd_k.launch_count()
    assert count[0] == 2 * per_sign, (
        f"{count[0]} pallas_call constructions for a both-signs fleet "
        f"flush; budget {2 * per_sign} (one per sign block)")
    assert btd_k.launches_traced() - before == 2 * per_sign


# ---------------------------------------------------------------------------
# Guard regression (ISSUE 5 satellite): sharded-batched downdate_guarded
# ---------------------------------------------------------------------------


def test_downdate_guarded_sharded_batched_matches_reference_verdict():
    """Regression: ``downdate_guarded`` on a sharded-batched fleet must
    (a) report the same per-member verdict as the reference criterion and
    (b) leave refused members bitwise unchanged — the old
    ``ok[..., None, None]`` masking assumed the triangular-solve guard
    could read full local rows; the sharded path now reads the verdict
    off the psum-gathered diagonal instead."""
    require_devices(2)
    L, V = _problem("batched", None)
    f = _factor("sharded", L).update(V)
    # Member 1's block is scaled far outside the PD cone; 0 and 2 stay in.
    Vmix = V.at[1].multiply(100.0)
    guarded, ok = f.downdate_guarded(Vmix)
    ref_f = CholFactor.from_factor(f.data, panel=PANEL, backend="reference")
    _, ok_ref = ref_f.downdate_guarded(Vmix)
    np.testing.assert_array_equal(np.asarray(ok), np.asarray(ok_ref))
    assert bool(ok[0]) and not bool(ok[1]) and bool(ok[2])
    np.testing.assert_array_equal(np.asarray(guarded.data[1]),
                                  np.asarray(f.data[1]))
    np.testing.assert_allclose(np.asarray(guarded.data[0]),
                               np.asarray(L[0]), atol=1e-3)
    assert ok.shape == (B,)


# ---------------------------------------------------------------------------
# The acceptance run: the sharded column under an emulated 4-device mesh
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_conformance_matrix_passes_on_emulated_4_device_mesh():
    """ISSUE 5 acceptance: a batched CholFactor on a 4-device (emulated)
    mesh passes the conformance matrix. Subprocess so the main pytest
    process keeps its single-device config (same harness as
    tests/test_distributed.py)."""
    repo = Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    # Appended so it wins over any inherited count (XLA takes the LAST
    # occurrence of a repeated flag).
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = f"{repo / 'src'}:{env.get('PYTHONPATH', '')}"
    res = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         str(Path(__file__)), "-k", "sharded", "-m", "not slow"],
        capture_output=True, text=True, env=env, cwd=repo, timeout=1200,
    )
    assert res.returncode == 0, (
        f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}")
    # The sharded column must have RUN (not skipped away): require a
    # healthy number of passes and zero failures.
    assert " passed" in res.stdout and "failed" not in res.stdout
