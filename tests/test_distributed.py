"""Multi-device tests (8 virtual CPU devices via a subprocess, so the main
pytest process keeps its single-device jax config)."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

# Each test pays a fresh-interpreter jax import + 8-device trace: the
# canonical tier-1 "slow" split (scripts/test.sh --fast skips these).
pytestmark = pytest.mark.slow

REPO = Path(__file__).resolve().parent.parent


def run_in_devices(code: str, n_devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_devices} "
        + env.get("XLA_FLAGS", "")
    ).strip()
    env["PYTHONPATH"] = f"{REPO / 'src'}:{env.get('PYTHONPATH', '')}"
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
    )
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    return res.stdout


PREAMBLE = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import ref
from repro.core.distributed import chol_update_sharded
from repro.runtime.compat import make_mesh_compat
mesh = make_mesh_compat((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
n, k = 256, 16
B = rng.uniform(size=(n, n)).astype(np.float32)
V = rng.uniform(size=(n, k)).astype(np.float32)
A = B.T @ B + np.eye(n, dtype=np.float32)
L = jnp.array(np.linalg.cholesky(A).T); Vj = jnp.array(V)
"""


@pytest.mark.parametrize("strategy", ["fused", "gemm", "paper"])
def test_sharded_update_matches_reference(strategy):
    run_in_devices(
        PREAMBLE
        + f"""
Lr = ref.chol_update_ref(L, Vj, sigma=1)
with mesh:
    Ld = chol_update_sharded(L, Vj, sigma=1, mesh=mesh, axis="model", panel=32, strategy="{strategy}")
assert float(jnp.max(jnp.abs(Ld - Lr))) < 1e-4, "sharded mismatch"
print("ok")
"""
    )


def test_sharded_fused_one_launch_per_shard_and_registry_dispatch():
    """The tentpole claim: the fused strategy issues exactly ONE pallas
    launch per shard per rank-k update, and the 'sharded' name dispatches
    through the backend registry (mesh passed as a backend option)."""
    run_in_devices(
        PREAMBLE
        + """
from repro.core import chol_update
from repro.kernels import sharded as sharded_k
Lr = ref.chol_update_ref(L, Vj, sigma=1)
before = sharded_k.launches_traced()
with mesh:
    Ld = chol_update_sharded(L, Vj, sigma=1, mesh=mesh, axis="model", panel=32, strategy="fused")
Ld.block_until_ready()
assert sharded_k.launches_traced() - before == 1, "expected one launch per shard per update"
assert sharded_k.launch_count_sharded(256, 32, strategy="fused") == 1
assert float(jnp.max(jnp.abs(Ld - Lr))) < 1e-4
with mesh:
    Lapi = chol_update(L, Vj, sigma=1, method="sharded", panel=32, mesh=mesh, axis="model")
assert float(jnp.max(jnp.abs(Lapi - Lr))) < 1e-4, "registry dispatch mismatch"
print("ok")
"""
    )


def test_sharded_update_combined_axes_and_downdate():
    run_in_devices(
        PREAMBLE
        + """
Lr = ref.chol_update_ref(L, Vj, sigma=1)
with mesh:
    Ld = chol_update_sharded(L, Vj, sigma=1, mesh=mesh, axis=("data", "model"), panel=32)
assert float(jnp.max(jnp.abs(Ld - Lr))) < 1e-4
A2 = np.asarray(L.T @ L) + np.asarray(Vj) @ np.asarray(Vj).T
L2 = jnp.array(np.linalg.cholesky(A2).T)
with mesh:
    Ldd = chol_update_sharded(L2, Vj, sigma=-1, mesh=mesh, axis="model", panel=32)
assert float(jnp.max(jnp.abs(Ldd - L))) < 1e-4, "downdate mismatch"
print("ok")
"""
    )


def test_sharded_update_validation_errors():
    run_in_devices(
        PREAMBLE
        + """
ok = 0
with mesh:
    try:
        chol_update_sharded(L, Vj, sigma=1, mesh=mesh, axis="model", panel=128)
    except ValueError:
        ok += 1  # panel 128 > per-device 64
    try:
        chol_update_sharded(L, Vj, sigma=2, mesh=mesh, axis="model", panel=32)
    except ValueError:
        ok += 1
assert ok == 2
print("ok")
"""
    )
