"""Multi-device tests (8 virtual CPU devices via a subprocess, so the main
pytest process keeps its single-device jax config)."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

# Each test pays a fresh-interpreter jax import + 8-device trace: the
# canonical tier-1 "slow" split (scripts/test.sh --fast skips these).
pytestmark = pytest.mark.slow

REPO = Path(__file__).resolve().parent.parent


def run_in_devices(code: str, n_devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    # APPEND our device count: XLA takes the LAST occurrence of a repeated
    # flag, so prepending would let an inherited setting (e.g. the CI
    # shard-emulation job's =4) win and under-provision the subprocess.
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={n_devices}"
    ).strip()
    env["PYTHONPATH"] = f"{REPO / 'src'}:{env.get('PYTHONPATH', '')}"
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
    )
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    return res.stdout


PREAMBLE = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import ref
from repro.core.distributed import chol_update_sharded
from repro.runtime.compat import make_mesh_compat
mesh = make_mesh_compat((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
n, k = 256, 16
B = rng.uniform(size=(n, n)).astype(np.float32)
V = rng.uniform(size=(n, k)).astype(np.float32)
A = B.T @ B + np.eye(n, dtype=np.float32)
L = jnp.array(np.linalg.cholesky(A).T); Vj = jnp.array(V)
"""


@pytest.mark.parametrize("strategy", ["fused", "gemm", "paper"])
def test_sharded_update_matches_reference(strategy):
    run_in_devices(
        PREAMBLE
        + f"""
Lr = ref.chol_update_ref(L, Vj, sigma=1)
with mesh:
    Ld = chol_update_sharded(L, Vj, sigma=1, mesh=mesh, axis="model", panel=32, strategy="{strategy}")
assert float(jnp.max(jnp.abs(Ld - Lr))) < 1e-4, "sharded mismatch"
print("ok")
"""
    )


def test_sharded_fused_one_launch_per_shard_and_registry_dispatch():
    """The tentpole claim: the fused strategy issues exactly ONE pallas
    launch per shard per rank-k update, and the 'sharded' name dispatches
    through the backend registry (mesh passed as a backend option)."""
    run_in_devices(
        PREAMBLE
        + """
from repro.core import chol_update
from repro.kernels import sharded as sharded_k
Lr = ref.chol_update_ref(L, Vj, sigma=1)
before = sharded_k.launches_traced()
with mesh:
    Ld = chol_update_sharded(L, Vj, sigma=1, mesh=mesh, axis="model", panel=32, strategy="fused")
Ld.block_until_ready()
assert sharded_k.launches_traced() - before == 1, "expected one launch per shard per update"
assert sharded_k.launch_count_sharded(256, 32, strategy="fused") == 1
assert float(jnp.max(jnp.abs(Ld - Lr))) < 1e-4
with mesh:
    Lapi = chol_update(L, Vj, sigma=1, method="sharded", panel=32, mesh=mesh, axis="model")
assert float(jnp.max(jnp.abs(Lapi - Lr))) < 1e-4, "registry dispatch mismatch"
print("ok")
"""
    )


def test_sharded_update_combined_axes_and_downdate():
    run_in_devices(
        PREAMBLE
        + """
Lr = ref.chol_update_ref(L, Vj, sigma=1)
with mesh:
    Ld = chol_update_sharded(L, Vj, sigma=1, mesh=mesh, axis=("data", "model"), panel=32)
assert float(jnp.max(jnp.abs(Ld - Lr))) < 1e-4
A2 = np.asarray(L.T @ L) + np.asarray(Vj) @ np.asarray(Vj).T
L2 = jnp.array(np.linalg.cholesky(A2).T)
with mesh:
    Ldd = chol_update_sharded(L2, Vj, sigma=-1, mesh=mesh, axis="model", panel=32)
assert float(jnp.max(jnp.abs(Ldd - L))) < 1e-4, "downdate mismatch"
print("ok")
"""
    )


def test_sharded_batched_fleet_matches_reference_all_strategies():
    """ISSUE 5 tentpole: a stacked (B, n, n) fleet, each member
    column-sharded, updates correctly under every strategy — and the fused
    strategy still traces exactly ONE launch for the whole fleet."""
    run_in_devices(
        PREAMBLE
        + """
from repro.kernels import sharded as sharded_k
Bsz = 3
Ls = jnp.stack([L + 0.01 * b * jnp.eye(n) for b in range(Bsz)])
Vb = jnp.stack([Vj * (1.0 + 0.1 * b) for b in range(Bsz)])
refs = jnp.stack([ref.chol_update_ref(Ls[b], Vb[b], sigma=1) for b in range(Bsz)])
before = sharded_k.launches_traced()
with mesh:
    out = chol_update_sharded(Ls, Vb, sigma=1, mesh=mesh, axis="model", panel=32, strategy="fused")
out.block_until_ready()
assert sharded_k.launches_traced() - before == 1, (
    "a fleet update must fold B into ONE launch per shard")
assert float(jnp.max(jnp.abs(out - refs))) < 1e-4
for strategy in ("gemm", "paper"):
    with mesh:
        o2 = chol_update_sharded(Ls, Vb, sigma=1, mesh=mesh, axis="model", panel=32, strategy=strategy)
    assert float(jnp.max(jnp.abs(o2 - refs))) < 1e-4, strategy
print("ok")
"""
    )


def test_sharded_batched_factor_api_and_guard():
    """The object API end to end on a 4-shard mesh: batched CholFactor
    with a mesh binding, roundtrip, and the psum-gathered-diag guard
    verdict (the ok[..., None, None] regression)."""
    run_in_devices(
        PREAMBLE
        + """
from repro.core import CholFactor
mesh2 = make_mesh_compat((4,), ("model",), devices=jax.devices()[:4])
Bsz = 3
Ls = jnp.stack([L + 0.01 * b * jnp.eye(n) for b in range(Bsz)])
Vb = jnp.stack([Vj * (1.0 + 0.1 * b) for b in range(Bsz)])
f = CholFactor.from_factor(Ls, panel=32, backend="sharded", mesh=mesh2, axis="model")
up = f.update(Vb)
for b in range(Bsz):
    r = ref.chol_update_ref(Ls[b], Vb[b], sigma=1)
    assert float(jnp.max(jnp.abs(up.data[b] - r))) < 1e-4, b
back = up.downdate(Vb)
assert float(jnp.max(jnp.abs(back.data - Ls))) < 1e-3
# Guard: member 1 leaves the PD cone, the rest downdate cleanly.
Vmix = Vb.at[1].multiply(100.0)
guarded, ok = up.downdate_guarded(Vmix)
assert ok.shape == (Bsz,)
assert bool(ok[0]) and not bool(ok[1]) and bool(ok[2])
assert float(jnp.max(jnp.abs(guarded.data[1] - up.data[1]))) == 0.0
assert float(jnp.max(jnp.abs(guarded.data[0] - Ls[0]))) < 1e-3
print("ok")
"""
    )


def test_sharded_fleet_store_launch_economics_and_restart():
    """ISSUE 5 acceptance: absorbing k=16 rows for B users through a
    sharded FactorStore costs launches proportional to shards x sign
    blocks — independent of B (launches_traced + mutations_issued) — and
    checkpoint -> restore of the sharded fleet is bitwise on the same
    machine, placement included."""
    run_in_devices(
        """
import tempfile
import jax, jax.numpy as jnp, numpy as np
from repro.core import ref
from repro.kernels import sharded as sharded_k
from repro.runtime.compat import make_mesh_compat
from repro.stream import FactorStore, StreamService, mutations_issued
from repro.stream.durability import checkpoint_service, restore_service
from repro.stream.store import fleet_sharding

mesh = make_mesh_compat((4,), ("model",), devices=jax.devices()[:4])
n, width, B = 64, 16, 3
st = FactorStore(n, capacity=B, width=width, panel=16, backend="sharded",
                 mesh=mesh, axis="model")
svc = StreamService(st, auto_flush=False)
rng = np.random.default_rng(0)
rows = {u: [(0.2 * rng.normal(size=n)).astype(np.float32)
            for _ in range(width)] for u in range(B)}
bk, bm = sharded_k.launches_traced(), mutations_issued()
for u in range(B):
    for v in rows[u]:
        svc.push(u, v)
rep = svc.flush()
assert mutations_issued() - bm == 1, "one batched mutation per sign block"
assert sharded_k.launches_traced() - bk == 1, (
    "B users x k=16 rows must cost ONE traced launch per shard")
assert rep.absorbed == {u: width for u in range(B)}
for u in range(B):
    r = ref.chol_update_ref(jnp.eye(n),
                            jnp.asarray(np.stack(rows[u], axis=1)), sigma=1)
    assert float(jnp.max(jnp.abs(st.factor.data[st.slot(u)] - r))) < 1e-4, u
# Mixed traffic: exactly one launch per sign block, still independent of B.
bk = sharded_k.launches_traced()
for u in range(B):
    for v in rows[u][:4]:
        svc.push(u, (0.3 * np.asarray(v)).astype(np.float32))
    for v in rows[u][:2]:
        svc.push(u, (0.1 * np.asarray(v)).astype(np.float32), sign=-1)
rep2 = svc.flush(force=True)
assert sharded_k.launches_traced() - bk == 2, "shards x sign blocks only"
assert all(rep2.downdate_ok.values())
assert st.factor.data.sharding == fleet_sharding(mesh, "model")
# Membership ops preserve the placement.
st.admit("x1"); st.admit("x2")   # grow 3 -> 6
st.evict("x1"); st.evict("x2")
st.compact(min_capacity=B)
st.decay(0.9)
assert st.factor.data.sharding == fleet_sharding(mesh, "model")
# Kill-and-restart: bitwise fleet + restored sharded placement.
with tempfile.TemporaryDirectory() as d:
    svc.push(0, rows[0][0])                 # unflushed row seeds the WAL
    checkpoint_service(svc, d, 1)
    svc.push(1, rows[1][1])                 # WAL-tail traffic
    svc.flush(force=True)
    want = np.asarray(svc.store.factor.data)
    svc2 = restore_service(d)
    got = np.asarray(svc2.store.factor.data)
    np.testing.assert_array_equal(got, want)
    f2 = svc2.store.factor
    assert f2.backend == "sharded" and f2.mesh is not None
    assert f2.data.sharding == fleet_sharding(f2.mesh, "model")
    assert svc2.pending(0) == svc.pending(0)
print("ok")
"""
    )


def test_sharded_update_validation_errors():
    run_in_devices(
        PREAMBLE
        + """
ok = 0
with mesh:
    try:
        chol_update_sharded(L, Vj, sigma=1, mesh=mesh, axis="model", panel=128)
    except ValueError:
        ok += 1  # panel 128 > per-device 64
    try:
        chol_update_sharded(L, Vj, sigma=2, mesh=mesh, axis="model", panel=32)
    except ValueError:
        ok += 1
assert ok == 2
print("ok")
"""
    )
