"""Correctness of the rank-k Cholesky modification core (paper Algorithm 1 + §4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests.hypothesis_compat import given, settings, st

from repro.core import (
    chol_downdate,
    chol_update,
    chol_update_blocked,
    chol_update_dense,
    chol_update_ref,
    downdate_feasible,
    modify_error,
)

# Canonical generators live in tests/strategies.py (ISSUE 5 harness);
# re-exported here because older test files import them from this module.
from tests.strategies import make_problem, tol_for  # noqa: F401


@pytest.mark.parametrize("n,k", [(8, 1), (32, 2), (64, 4), (96, 16), (128, 8)])
@pytest.mark.parametrize("sigma", [1, -1])
def test_reference_matches_dense_refactorization(n, k, sigma):
    L, V = make_problem(n, k, seed=n + k)
    if sigma == -1:
        # Downdate a factor that contains V V^T so the result stays PD.
        A2 = L.T @ L + V @ V.T
        L = jnp.linalg.cholesky(A2).T
    L_new = chol_update_ref(L, V, sigma=sigma)
    L_dense = chol_update_dense(L, V, sigma=sigma)
    assert jnp.all(jnp.isfinite(L_new))
    np.testing.assert_allclose(L_new, L_dense, atol=tol_for(jnp.float32, n))
    # Factor structure: upper triangular, positive diagonal.
    assert float(jnp.max(jnp.abs(jnp.tril(L_new, -1)))) == 0.0
    assert bool(jnp.all(jnp.diagonal(L_new) > 0))


@pytest.mark.parametrize("strategy", ["paper", "gemm"])
@pytest.mark.parametrize("n,k,panel", [(64, 4, 16), (100, 3, 32), (256, 16, 64), (129, 1, 64)])
def test_blocked_matches_reference(strategy, n, k, panel):
    L, V = make_problem(n, k, seed=7 * n + k)
    L_ref = chol_update_ref(L, V, sigma=1)
    L_blk = chol_update_blocked(L, V, sigma=1, panel=panel, strategy=strategy)
    np.testing.assert_allclose(L_blk, L_ref, atol=tol_for(jnp.float32, n))


@pytest.mark.parametrize("strategy", ["paper", "gemm"])
def test_blocked_downdate(strategy):
    n, k, panel = 128, 8, 32
    L, V = make_problem(n, k, seed=3)
    A2 = L.T @ L + V @ V.T
    L2 = jnp.linalg.cholesky(A2).T
    L_down = chol_update_blocked(L2, V, sigma=-1, panel=panel, strategy=strategy)
    np.testing.assert_allclose(L_down, L, atol=tol_for(jnp.float32, n))


def test_update_then_downdate_roundtrip():
    n, k = 96, 5
    L, V = make_problem(n, k, seed=11)
    L_up = chol_update(L, V, sigma=1, method="gemm", panel=32)
    L_back = chol_update(L_up, V, sigma=-1, method="gemm", panel=32)
    np.testing.assert_allclose(L_back, L, atol=tol_for(jnp.float32, n))


def test_rank1_vector_input():
    n = 48
    L, V = make_problem(n, 1, seed=5)
    v = V[:, 0]
    L_a = chol_update(L, v, method="reference")
    L_b = chol_update(L, V, method="reference")
    np.testing.assert_allclose(L_a, L_b, atol=0)


def test_api_validation():
    L, V = make_problem(16, 1, seed=1)
    with pytest.raises(ValueError):
        chol_update(L, V, sigma=2)
    with pytest.raises(ValueError):
        chol_update(L, V, method="nope")


def test_downdate_feasibility_guard():
    n, k = 32, 2
    L, V = make_problem(n, k, seed=9)
    # Downdating by something inside A is feasible...
    A2 = L.T @ L + V @ V.T
    L2 = jnp.linalg.cholesky(A2).T
    assert bool(downdate_feasible(L2, V))
    # ... but downdating A by a huge V is not.
    assert not bool(downdate_feasible(L, 100.0 * V))


def test_chol_downdate_wrapper():
    n, k = 64, 4
    L, V = make_problem(n, k, seed=13)
    A2 = L.T @ L + V @ V.T
    L2 = jnp.linalg.cholesky(A2).T
    np.testing.assert_allclose(
        chol_downdate(L2, V, method="reference"),
        chol_update(L2, V, sigma=-1, method="reference"),
        atol=0,
    )


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=48),
    k=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    sigma=st.sampled_from([1, -1]),
)
def test_property_modification_equation(n, k, seed, sigma):
    """Invariant: Ltilde^T Ltilde == L^T L + sigma V V^T (paper's error metric)."""
    L, V = make_problem(n, k, seed=seed)
    if sigma == -1:
        A2 = L.T @ L + V @ V.T
        L = jnp.linalg.cholesky(A2).T
    L_new = chol_update_ref(L, V, sigma=sigma)
    err = float(modify_error(L_new, L, V, sigma=sigma))
    scale = float(jnp.max(jnp.abs(L.T @ L))) + 1.0
    assert err < 200 * n * float(jnp.finfo(jnp.float32).eps) * scale


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=4, max_value=40),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_property_rankk_equals_sequential_rank1(n, seed):
    """Rank-k modification == k sequential rank-1 modifications."""
    k = 4
    L, V = make_problem(n, k, seed=seed)
    L_k = chol_update_ref(L, V, sigma=1)
    L_seq = L
    for m in range(k):
        L_seq = chol_update_ref(L_seq, V[:, m], sigma=1)
    np.testing.assert_allclose(L_k, L_seq, atol=tol_for(jnp.float32, n) * 4)


@settings(max_examples=10, deadline=None)
@given(panel=st.sampled_from([8, 16, 32, 64, 96]))
def test_property_panel_size_invariance(panel):
    """The panelled result must not depend on the panel size."""
    n, k = 96, 3
    L, V = make_problem(n, k, seed=42)
    base = chol_update_blocked(L, V, sigma=1, panel=96, strategy="gemm")
    other = chol_update_blocked(L, V, sigma=1, panel=panel, strategy="gemm")
    np.testing.assert_allclose(other, base, atol=tol_for(jnp.float32, n))
