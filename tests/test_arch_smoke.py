"""Per-architecture smoke tests: reduced config, one forward/train step +
one decode step on CPU, asserting shapes and finiteness (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# ~10-25s of XLA compile per architecture: the model-zoo integration tier
# (scripts/test.sh --fast skips it; the core numerics tier stays).
pytestmark = pytest.mark.slow

import repro.optim as optim
from repro.configs import ARCHS
from repro.models import (
    decode_step,
    forward,
    init_cache,
    init_model,
    loss_fn,
    param_count,
    split_params,
)

B, S = 2, 64


def make_batch(cfg, key):
    k1, k2, k3 = jax.random.split(key, 3)
    tokens = jax.random.randint(k1, (B, S), 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.family == "encdec":
        batch["src_embeds"] = jax.random.normal(k2, (B, S, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        P = max(1, int(S * cfg.frontend_frac))
        batch["embeds"] = jax.random.normal(k3, (B, P, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_forward_and_train_step(name):
    cfg = ARCHS[name].reduced()
    key = jax.random.PRNGKey(hash(name) % 2**31)
    params = init_model(key, cfg)
    values, axes = split_params(params)
    assert param_count(params) > 0
    # axes tree mirrors values tree
    assert jax.tree.structure(jax.tree.map(lambda x: 0, values)) == jax.tree.structure(
        jax.tree.map(lambda a: 0, axes, is_leaf=lambda x: isinstance(x, tuple))
    )
    batch = make_batch(cfg, key)

    logits = jax.jit(lambda v, b: forward(v, cfg, b))(values, batch)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits)))

    # One full train step with the paper-technique optimizer in the loop.
    opt = optim.get_optimizer("cholesky_precond", 1e-3, rank=4, block_size=32)
    state = opt.init(values)

    @jax.jit
    def train_step(values, state, batch):
        (total, metrics), grads = jax.value_and_grad(
            lambda v: loss_fn(v, cfg, batch), has_aux=True
        )(values)
        grads, gnorm = optim.clip_by_global_norm(grads, 1.0)
        updates, state = opt.update(grads, state, values)
        values = optim.apply_updates(values, updates)
        return values, state, total, gnorm

    values2, state, total, gnorm = train_step(values, state, batch)
    assert bool(jnp.isfinite(total)), f"{name} loss not finite"
    assert bool(jnp.isfinite(gnorm))
    assert bool(optim.all_finite(values2)), f"{name} params not finite after step"
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), values, values2)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_decode_step(name):
    cfg = ARCHS[name].reduced()
    key = jax.random.PRNGKey(1)
    params = init_model(key, cfg)
    values, _ = split_params(params)
    cache = init_cache(cfg, B, S, jnp.float32)
    tok = jax.random.randint(key, (B,), 0, cfg.vocab_size)
    step = jax.jit(lambda v, c, t: decode_step(v, cfg, c, t))
    logits, cache = step(values, cache, tok)
    assert logits.shape == (B, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache["pos"]) == 1
    # a second step continues from the updated cache
    logits2, cache = step(values, cache, tok)
    assert bool(jnp.all(jnp.isfinite(logits2)))
    assert int(cache["pos"]) == 2


def test_decode_matches_forward_dense():
    """Incremental decode equals the training forward at every position."""
    cfg = ARCHS["h2o-danube-1.8b"].reduced()
    key = jax.random.PRNGKey(7)
    params = init_model(key, cfg)
    values, _ = split_params(params)
    tokens = jax.random.randint(key, (B, 16), 0, cfg.vocab_size)
    full = forward(values, cfg, {"tokens": tokens})  # (B, 16, V)
    cache = init_cache(cfg, B, 16, jnp.float32)
    step = jax.jit(lambda v, c, t: decode_step(v, cfg, c, t))
    outs = []
    for t in range(16):
        logits, cache = step(values, cache, tokens[:, t])
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), rtol=2e-2, atol=2e-3)


@pytest.mark.parametrize("name", ["rwkv6-3b", "zamba2-7b"])
def test_decode_matches_forward_ssm(name):
    cfg = ARCHS[name].reduced()
    key = jax.random.PRNGKey(9)
    params = init_model(key, cfg)
    values, _ = split_params(params)
    tokens = jax.random.randint(key, (B, 12), 0, cfg.vocab_size)
    full = forward(values, cfg, {"tokens": tokens})
    cache = init_cache(cfg, B, 12, jnp.float32)
    step = jax.jit(lambda v, c, t: decode_step(v, cfg, c, t))
    outs = []
    for t in range(12):
        logits, cache = step(values, cache, tokens[:, t])
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), rtol=2e-2, atol=2e-3)
