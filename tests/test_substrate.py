"""Data pipeline, checkpointing, and fault-tolerance substrate tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import all_steps, latest_step, restore, save
from repro.data import DataConfig, SyntheticTokens, frontend_stub_embeds
from repro.runtime import ResilientLoop, StragglerMonitor, elastic_reshard


def test_pipeline_deterministic_and_sharded():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8, seed=3)
    a = SyntheticTokens(cfg).batch_at(5)
    b = SyntheticTokens(cfg).batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])
    # host sharding partitions the same global batch
    h0 = SyntheticTokens(cfg, host_index=0, num_hosts=2).batch_at(5)
    h1 = SyntheticTokens(cfg, host_index=1, num_hosts=2).batch_at(5)
    assert h0["tokens"].shape == (4, 32)
    assert not np.array_equal(h0["tokens"], h1["tokens"])
    # different steps differ
    c = SyntheticTokens(cfg).batch_at(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_pipeline_has_learnable_structure():
    cfg = DataConfig(vocab_size=64, seq_len=256, global_batch=4, seed=0)
    batch = SyntheticTokens(cfg).batch_at(0)
    toks = np.asarray(batch["tokens"])
    # the +1 Markov backbone appears: P(next == cur+1) >> 1/V
    nxt = (toks[:, :-1] + 1) % cfg.vocab_size
    frac = float(np.mean(toks[:, 1:] == nxt))
    assert frac > 0.1


def test_frontend_stub_shapes():
    from repro.configs import ARCHS

    cfg = ARCHS["pixtral-12b"].reduced()
    e = frontend_stub_embeds(cfg, 2, 8)
    assert e.shape == (2, 8, cfg.d_model)
    assert e.dtype == jnp.bfloat16


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}
    save(tmp_path, 7, tree)
    assert latest_step(tmp_path) == 7
    out = restore(tmp_path, 7, jax.tree.map(jnp.zeros_like, tree))
    np.testing.assert_array_equal(out["a"], tree["a"])
    assert out["nested"]["b"].dtype == np.dtype(jnp.bfloat16)


def test_checkpoint_atomicity_and_pruning(tmp_path):
    tree = {"w": jnp.zeros((4,))}
    for s in [1, 2, 3, 4]:
        save(tmp_path, s, tree, keep=2)
    assert all_steps(tmp_path) == [3, 4]
    # a directory without DONE is invisible
    bad = tmp_path / "step_00000099"
    bad.mkdir()
    (bad / "tree.json").write_text("{}")
    assert latest_step(tmp_path) == 4
    with pytest.raises(FileNotFoundError):
        restore(tmp_path, 99, tree)


def test_resilient_loop_resume_and_nan_retry(tmp_path):
    """Simulated failure: the step function NaNs once at step 6; the loop
    must reload the last checkpoint instead of committing the poison."""
    calls = {"n": 0, "nan_fired": False}

    def step_fn(state, batch):
        calls["n"] += 1
        w = state["w"] + 1.0
        loss = float(jnp.sum(w))
        if int(state["w"][0]) == 6 and not calls["nan_fired"]:
            calls["nan_fired"] = True
            return {"w": w}, {"loss": float("nan")}
        return {"w": w}, {"loss": loss}

    loop = ResilientLoop(
        step_fn, lambda step: None, tmp_path, ckpt_every=2, max_retries=3
    )
    state, step = loop.run({"w": jnp.zeros((2,))}, 10)
    assert step == 10
    assert float(state["w"][0]) == 10.0  # exactly 10 committed steps
    assert calls["nan_fired"]

    # kill/restart: resume from the newest checkpoint, not from scratch
    loop2 = ResilientLoop(step_fn, lambda s: None, tmp_path, ckpt_every=2)
    state2, start = loop2.resume_or_init({"w": jnp.zeros((2,))})
    assert start == 10
    assert float(state2["w"][0]) == 10.0


def test_straggler_monitor():
    m = StragglerMonitor(k=5.0)
    for i in range(20):
        assert not m.record(i, 1.0 + 0.01 * (i % 3))
    assert m.record(20, 10.0)  # 10x the median -> flagged
    assert m.flagged and m.flagged[0][0] == 20


def test_elastic_reshard_across_meshes(tmp_path):
    """Checkpoint under one mesh, restore under another (elastic restart)."""
    import subprocess, sys, textwrap, os
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import save, restore
        from repro.runtime import elastic_reshard, make_mesh_compat
        tmp = %r
        mesh1 = make_mesh_compat((2, 4), ("data", "model"))
        w = jax.device_put(jnp.arange(32, dtype=jnp.float32).reshape(4, 8),
                           NamedSharding(mesh1, P("data", "model")))
        save(tmp, 1, {"w": w})
        # "lost half the pod": restore onto a 4-device mesh
        mesh2 = make_mesh_compat((1, 4), ("data", "model"))
        like = {"w": jnp.zeros((4, 8), jnp.float32)}
        sh = {"w": NamedSharding(mesh2, P("data", "model"))}
        out = restore(tmp, 1, like, shardings=sh)
        np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(w))
        assert out["w"].sharding.mesh.shape == {"data": 1, "model": 4}
        out2 = elastic_reshard(out, sh)
        np.testing.assert_array_equal(np.asarray(out2["w"]), np.asarray(w))
        print("ok")
    """ % str(tmp_path))
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{os.path.dirname(os.path.dirname(os.path.abspath(__file__)))}/src:" + env.get("PYTHONPATH", "")
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=300)
    assert res.returncode == 0, res.stderr
    assert "ok" in res.stdout
