"""Launcher integration tests: train driver end-to-end + serve driver +
input-spec coverage for every runnable cell."""
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES_BY_NAME, cells, get_config
from repro.launch import steps as St


def test_cells_enumeration():
    cs = cells()
    assert len(cs) == 34  # 40 assigned minus 6 full-attention long_500k skips
    longs = [a for a, s in cs if s == "long_500k"]
    assert sorted(longs) == sorted(
        ["rwkv6-3b", "zamba2-7b", "h2o-danube-1.8b", "mixtral-8x22b"]
    )


@pytest.mark.parametrize("arch,shape", cells())
def test_input_specs_cover_every_cell(arch, shape):
    cfg = get_config(arch)
    cell = SHAPES_BY_NAME[shape]
    specs = St.input_specs(cfg, cell)
    if cell.kind in ("train", "prefill"):
        assert specs["tokens"].shape == (cell.global_batch, cell.seq_len)
        if cfg.family == "vlm":
            assert "embeds" in specs
        if cfg.family == "encdec":
            assert "src_embeds" in specs
    else:
        assert specs["tokens"].shape == (cell.global_batch,)
        cache = specs["cache"]
        assert "pos" in cache
        # SWA archs get a bounded (ring/window) cache at 500k
        if shape == "long_500k" and cfg.attn is not None and cfg.attn.window:
            kv = cache.get("k", cache.get("sk"))
            assert kv.shape[2] <= cfg.attn.window


def test_param_shapes_and_axes_structure():
    cfg = ARCHS["gemma2-9b"]
    shapes, axes = St.param_shapes_and_axes(cfg)
    # full-size shapes, reduced-config axes, same structure
    assert shapes["embed"]["tokens"].shape == (cfg.vocab_padded, cfg.d_model)


@pytest.mark.slow
def test_train_driver_end_to_end(tmp_path):
    from repro.launch.train import main as train_main

    losses = train_main([
        "--arch", "h2o-danube-1.8b",
        "--steps", "12",
        "--batch", "4",
        "--seq", "64",
        "--optimizer", "adamw",
        "--lr", "3e-3",
        "--ckpt-dir", str(tmp_path),
        "--ckpt-every", "6",
        "--log-every", "6",
    ])
    assert len(losses) == 12
    assert losses[-1] < losses[0]
    # resumability: a second invocation resumes at step 12 and does nothing
    losses2 = train_main([
        "--arch", "h2o-danube-1.8b",
        "--steps", "12",
        "--batch", "4",
        "--seq", "64",
        "--ckpt-dir", str(tmp_path),
    ])
    assert losses2 == []


@pytest.mark.slow
def test_serve_driver_generates():
    from repro.launch.serve import generate
    from repro.models import init_model, split_params
    import jax

    cfg = ARCHS["llama3.2-3b"].reduced()
    values, _ = split_params(init_model(jax.random.PRNGKey(0), cfg))
    prompts = jnp.ones((2, 8), jnp.int32)
    toks, tps = generate(cfg, values, prompts, gen=8, cache_len=16)
    assert toks.shape == (2, 16)
    assert tps > 0


@pytest.mark.slow
def test_grad_accum_matches_single_batch():
    """grad_accum=2 must give the same update as accum=1 (linearity)."""
    import jax
    import repro.optim as optim
    from repro.data import DataConfig, SyntheticTokens
    from repro.models import init_model, split_params

    cfg = ARCHS["h2o-danube-1.8b"].reduced()
    values, _ = split_params(init_model(jax.random.PRNGKey(0), cfg))
    opt = optim.sgd(1e-2, momentum=0.0)
    batch = SyntheticTokens(DataConfig(cfg.vocab_size, 32, 4, seed=0)).batch_at(0)
    outs = {}
    for accum in (1, 2):
        step = St.make_train_step(cfg, opt, grad_accum=accum)
        state = opt.init(values)
        v2, _, metrics = jax.jit(step)(values, state, batch)
        outs[accum] = v2
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        outs[1], outs[2],
    )
    assert max(jax.tree.leaves(diffs)) < 5e-3
