"""Durability of the streaming service: checkpoint + replay-log restarts.

ISSUE 4 acceptance: kill-and-restart reproduces the exact factor state
(allclose at storage dtype) after a simulated crash mid-buffer. Plus the
checkpoint round-trip regression satellite: a batched ``CholFactor`` fleet
survives ``repro.checkpoint.save``/``restore`` with aux metadata (backend,
panel, precision) intact — previously only raw pytree leaves were
exercised.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro import checkpoint as ckpt
from repro.core import CholFactor, Precision
from repro.stream import (
    FactorStore,
    ReplayLog,
    StreamService,
    checkpoint_service,
    decode_row,
    encode_row,
    restore_service,
)
from repro.stream.durability import (
    _precision_from_json,
    _precision_to_json,
)


def _rows(n, m, seed, scale=0.25):
    rng = np.random.default_rng(seed)
    return [(scale * rng.normal(size=n)).astype(np.float32)
            for _ in range(m)]


def _service(n=12, B=3, width=4, **kw):
    st = FactorStore(n, capacity=B, width=width, panel=4,
                     backend="reference", **kw)
    return StreamService(st, window=6, auto_flush=False)


# ---------------------------------------------------------------------------
# Satellite: fleet checkpoint round trip with aux metadata intact
# ---------------------------------------------------------------------------


def test_checkpoint_fleet_aux_metadata_roundtrip(tmp_path):
    """A batched CholFactor fleet survives save/restore with backend,
    panel and precision intact — carried by the checkpoint's ``extra``
    meta, which raw pytree leaves lose."""
    B, n = 3, 16
    rng = np.random.default_rng(0)
    data = np.stack([np.linalg.cholesky(
        (lambda M: M.T @ M + np.eye(n))(rng.normal(size=(n, n)))
    ).T for _ in range(B)]).astype(np.float32)
    fleet = CholFactor.from_factor(
        jnp.asarray(data).astype(jnp.bfloat16), panel=8, backend="gemm",
        interpret=True, precision="bf16")

    aux = {"backend": fleet.backend, "panel": fleet.panel,
           "interpret": fleet.interpret,
           "precision": _precision_to_json(fleet.precision)}
    ckpt.save(tmp_path, 5, {"fleet": fleet.data}, extra={"fleet_aux": aux})

    meta = ckpt.read_meta(tmp_path, 5)
    got = meta["extra"]["fleet_aux"]
    template = {"fleet": np.zeros((B, n, n), np.dtype("float32"))}
    # The template's dtype is irrelevant: leaves restore at stored dtype.
    restored = ckpt.restore(tmp_path, 5, template)["fleet"]
    rebuilt = CholFactor.from_factor(
        jnp.asarray(restored), panel=got["panel"], backend=got["backend"],
        interpret=got["interpret"],
        precision=_precision_from_json(got["precision"]))
    assert rebuilt.backend == "gemm" and rebuilt.panel == 8
    assert rebuilt.interpret is True
    assert rebuilt.precision == Precision(storage="bfloat16", accum="float32")
    assert rebuilt.dtype == jnp.bfloat16 and rebuilt.batched
    np.testing.assert_array_equal(
        np.asarray(rebuilt.data, np.float32),
        np.asarray(fleet.data, np.float32))


def test_read_meta_missing_step_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.read_meta(tmp_path, 1)


def test_row_codec_roundtrip_all_dtypes():
    for dtype in ("float32", "bfloat16", "float64"):
        v = (np.arange(6) * 0.5).astype(_np(dtype))
        rec = encode_row(v)
        back = decode_row(rec)
        assert str(back.dtype) == dtype
        np.testing.assert_array_equal(
            back.astype(np.float64), v.astype(np.float64))


def _np(name):
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def test_precision_json_roundtrip():
    for p in (None, Precision(storage="bfloat16", accum="float32"),
              Precision(storage=None, accum="float64")):
        assert _precision_from_json(_precision_to_json(p)) == p


def test_mesh_json_roundtrip_single_device():
    """The sharded-fleet checkpoint aux (DESIGN.md §10): mesh axis
    names/sizes + column-axis binding survive the JSON round trip, and
    restore rebuilds an equivalent mesh. Single-device twin of the
    bitwise restart test in tests/test_distributed.py."""
    import jax

    from repro.core import CholFactor
    from repro.runtime.compat import make_mesh_compat
    from repro.stream.durability import _mesh_from_json, _mesh_to_json

    # Unsharded factors carry no mesh record.
    plain = CholFactor.identity(4, backend="gemm")
    assert _mesh_to_json(plain) is None
    assert _mesh_from_json(None) == (None, "model")
    # ...and a mesh override against a mesh-less record must fail loudly,
    # not hand back a replicated store the caller believes is sharded.
    with pytest.raises(ValueError):
        _mesh_from_json(None, mesh=object())
    # A mesh on a non-sharded store is equally loud (the inverse of the
    # sharded-without-mesh error).
    from repro.stream import FactorStore

    with pytest.raises(ValueError):
        FactorStore(4, capacity=1, backend="gemm", mesh=object())

    mesh = make_mesh_compat((1,), ("model",), devices=jax.devices()[:1])
    f = CholFactor.identity(4, backend="sharded", mesh=mesh, axis="model")
    rec = _mesh_to_json(f)
    assert rec == {"axes": ["model"], "shape": [1], "axis": "model"}
    mesh2, axis2 = _mesh_from_json(rec)
    assert axis2 == "model"
    assert tuple(mesh2.axis_names) == ("model",)
    assert mesh2.shape["model"] == 1
    # A caller-supplied mesh (elastic restore) wins over the rebuild.
    mesh3, _ = _mesh_from_json(rec, mesh=mesh)
    assert mesh3 is mesh
    # Tuple axis bindings round-trip as tuples (JSON stores a list).
    rec2 = dict(rec, axis=["data", "model"])
    _, axis3 = _mesh_from_json(rec2)
    assert axis3 == ("data", "model")


# ---------------------------------------------------------------------------
# Acceptance: kill-and-restart mid-buffer
# ---------------------------------------------------------------------------


def test_kill_and_restart_reproduces_exact_state(tmp_path):
    """Simulated crash mid-buffer: the survivor (checkpoint + WAL replay)
    matches the original — fleet arrays allclose at storage dtype, pending
    buffers and window schedule identical — and stays in lockstep through
    the next flush."""
    n, B, width = 12, 3, 4
    svc = _service(n=n, B=B, width=width)
    for u in range(B):
        svc.admit(u)

    # Phase 1: traffic + a flush, then the periodic checkpoint (buffers
    # deliberately non-empty: rows 2 per user still unflushed).
    for v in _rows(n, width, seed=1):
        for u in range(B):
            svc.push(u, v)
    svc.flush()
    svc.tick()
    for v in _rows(n, 2, seed=2):
        for u in range(B):
            svc.push(u, v)
    checkpoint_service(svc, tmp_path, step=1)

    # Phase 2: post-checkpoint traffic — ticks, another flush (absorbing
    # the checkpointed buffers), a decay, fresh unflushed rows. All of it
    # lives only in the WAL.
    svc.tick()
    svc.flush(force=True)
    svc.decay(0.9)
    for v in _rows(n, 1, seed=3):
        for u in range(B):
            svc.push(u, v)
    svc.tick()

    # CRASH: the process dies here. Restore from disk alone.
    survivor = restore_service(tmp_path)

    assert survivor.tick_count == svc.tick_count
    assert sorted(survivor.users()) == sorted(svc.users())
    assert survivor.scheduled() == svc.scheduled()
    for u in range(B):
        assert survivor.pending(u) == svc.pending(u)
        np.testing.assert_array_equal(
            survivor._coalescer(u).peek()[0], svc._coalescer(u).peek()[0])
    np.testing.assert_allclose(
        np.asarray(survivor.store.factor.data, np.float32),
        np.asarray(svc.store.factor.data, np.float32), atol=1e-6)

    # Lockstep continues: the same future flush lands on the same state.
    r1 = svc.flush(force=True)
    r2 = survivor.flush(force=True)
    assert r1.absorbed == r2.absorbed and r1.downdated == r2.downdated
    np.testing.assert_allclose(
        np.asarray(survivor.store.factor.data, np.float32),
        np.asarray(svc.store.factor.data, np.float32), atol=1e-6)


def test_restart_replays_window_schedule(tmp_path):
    """Scheduled (not yet due) window-downdates survive the crash and fire
    at the same tick on the survivor."""
    n, width = 8, 2
    svc = _service(n=n, B=1, width=width)
    svc.admit("u")
    for v in _rows(n, width, seed=4):
        svc.push("u", v)
    svc.flush()                       # schedules expiry at tick + window
    checkpoint_service(svc, tmp_path, step=3)

    survivor = restore_service(tmp_path)
    assert survivor.scheduled() == svc.scheduled() == width
    orig_fired = sur_fired = None
    for _ in range(7):
        a, b = svc.tick(), survivor.tick()
        orig_fired = orig_fired or (a and a.downdated)
        sur_fired = sur_fired or (b and b.downdated)
    assert orig_fired == sur_fired == {"u": width}
    np.testing.assert_allclose(
        np.asarray(survivor.store.factor.data, np.float32),
        np.asarray(svc.store.factor.data, np.float32), atol=1e-6)


def test_restart_bf16_fleet_allclose_at_storage_dtype(tmp_path):
    """The acceptance wording verbatim: allclose at STORAGE dtype — a bf16
    fleet restores as bf16 and matches bitwise (checkpoint stores raw
    bytes; replay re-runs the identical jitted mutations)."""
    n, width = 8, 2
    st = FactorStore(n, capacity=2, width=width, panel=4, backend="gemm",
                     precision="bf16")
    svc = StreamService(st, auto_flush=False)
    svc.admit(0)
    svc.admit(1)
    for v in _rows(n, width, seed=6):
        svc.push(0, v)
    svc.flush()
    svc.push(1, _rows(n, 1, seed=7)[0])      # crash with this unflushed
    checkpoint_service(svc, tmp_path, step=2)
    svc.push(0, _rows(n, 1, seed=8)[0])      # WAL-only traffic

    survivor = restore_service(tmp_path)
    assert survivor.store.factor.dtype == jnp.bfloat16
    assert survivor.store.factor.precision == svc.store.factor.precision
    np.testing.assert_array_equal(
        np.asarray(survivor.store.factor.data, np.float32),
        np.asarray(svc.store.factor.data, np.float32))
    r1, r2 = svc.flush(force=True), survivor.flush(force=True)
    assert r1.absorbed == r2.absorbed
    np.testing.assert_array_equal(
        np.asarray(survivor.store.factor.data, np.float32),
        np.asarray(svc.store.factor.data, np.float32))


def test_checkpoint_rotation_prunes_stale_wals(tmp_path):
    st = FactorStore(6, capacity=1, width=2, panel=4, backend="reference")
    svc = StreamService(st, auto_flush=False, capacity=8)
    svc.admit("u")
    for step in (1, 2, 3, 4, 5):
        svc.push("u", _rows(6, 1, seed=step)[0])
        checkpoint_service(svc, tmp_path, step=step, keep=2)
    live = set(ckpt.all_steps(tmp_path))
    assert live == {4, 5}
    wals = sorted(p.name for p in tmp_path.glob("wal_*.jsonl"))
    assert wals == ["wal_00000004_0.jsonl", "wal_00000005_0.jsonl"]
    # And the newest is still restorable.
    survivor = restore_service(tmp_path)
    assert survivor.pending("u") == svc.pending("u")


def test_recheckpointing_a_step_never_touches_its_committed_wal(tmp_path):
    """Regression: re-using a step number seeds a FRESH segment (new
    attempt suffix); the previously committed pair stays intact until the
    new checkpoint commits and re-points the meta, so there is no window
    where a committed step's WAL is truncated."""
    st = FactorStore(6, capacity=1, width=2, panel=4, backend="reference")
    svc = StreamService(st, auto_flush=False, capacity=8)
    svc.admit("u")
    svc.push("u", _rows(6, 1, seed=21)[0])
    checkpoint_service(svc, tmp_path, step=1)
    first_wal = ckpt.read_meta(tmp_path, 1)["extra"]["stream"]["wal"]
    svc.push("u", _rows(6, 1, seed=22)[0])
    checkpoint_service(svc, tmp_path, step=1)   # same step, new attempt
    second_wal = ckpt.read_meta(tmp_path, 1)["extra"]["stream"]["wal"]
    assert first_wal != second_wal
    assert not (tmp_path / first_wal).exists()  # orphan pruned post-commit
    survivor = restore_service(tmp_path)
    assert survivor.pending("u") == 2
    # Third same-step checkpoint: attempt numbering must be max+1, not a
    # count of surviving files — a count would re-use (and truncate) the
    # committed second segment after the first was pruned.
    svc.push("u", _rows(6, 1, seed=23)[0])
    checkpoint_service(svc, tmp_path, step=1)
    third_wal = ckpt.read_meta(tmp_path, 1)["extra"]["stream"]["wal"]
    assert third_wal not in (first_wal, second_wal)
    assert restore_service(tmp_path).pending("u") == 3


def test_failed_push_leaves_no_poison_record(tmp_path):
    """Regression: a push that raises live (full ring) must not be logged
    — otherwise every future replay would re-raise the same error and the
    checkpoint+WAL pair could never be restored."""
    st = FactorStore(6, capacity=1, width=2, panel=4, backend="reference")
    svc = StreamService(st, auto_flush=False)   # ring capacity 4
    svc.admit("u")
    checkpoint_service(svc, tmp_path, step=1)
    for v in _rows(6, 4, seed=11):
        svc.push("u", v)
    with pytest.raises(OverflowError):
        svc.push("u", _rows(6, 1, seed=12)[0])  # survivable live...
    survivor = restore_service(tmp_path)        # ...and at restore time
    assert survivor.pending("u") == svc.pending("u") == 4
    r1, r2 = svc.flush(force=True), survivor.flush(force=True)
    assert r1.absorbed == r2.absorbed == {"u": 4}
    np.testing.assert_allclose(
        np.asarray(survivor.store.factor.data, np.float32),
        np.asarray(svc.store.factor.data, np.float32), atol=1e-6)


def test_wal_seed_is_on_disk_before_checkpoint_commits(tmp_path, monkeypatch):
    """Regression: the seeded WAL segment must be complete before the
    checkpoint's DONE marker lands — a crash between the two must leave
    the PREVIOUS pair authoritative, never a committed step with missing
    buffers."""
    svc = _service(n=6, B=1, width=2)
    svc.admit("u")
    svc.push("u", _rows(6, 1, seed=13)[0])

    seen = {}
    real_save = ckpt.save

    def spy_save(ckpt_dir, step, tree, **kw):
        (wal,) = tmp_path.glob(f"wal_{step:08d}_*.jsonl")
        seen["ops"] = [r["op"] for r in ReplayLog.read(wal)]
        return real_save(ckpt_dir, step, tree, **kw)

    monkeypatch.setattr(
        "repro.stream.durability.ckpt.save", spy_save)
    checkpoint_service(svc, tmp_path, step=1)
    assert seen["ops"] == ["buffer"], (
        "unflushed buffer must be in the WAL before save commits")


def test_replay_log_read_missing_and_append(tmp_path):
    assert ReplayLog.read(tmp_path / "nope.jsonl") == []
    log = ReplayLog(tmp_path / "wal.jsonl")
    log.append({"op": "tick"})
    log.append({"op": "flush", "force": True})
    log.close()
    recs = ReplayLog.read(tmp_path / "wal.jsonl")
    assert [r["op"] for r in recs] == ["tick", "flush"]


# ---------------------------------------------------------------------------
# Free-slot LIFO order survives kill-and-restart
# ---------------------------------------------------------------------------


def test_restore_preserves_empty_slot_lifo_order(tmp_path):
    """After evictions the live LIFO free-slot order diverges from any
    derived (descending) order; the checkpoint records it and restore
    must pop the SAME slot the pre-crash process would have — otherwise
    slot-indexed state diverges on replayed admissions."""
    st = FactorStore(6, capacity=8, width=2, panel=4, backend="reference")
    svc = StreamService(st, auto_flush=False)
    for u in range(4):
        svc.admit(u)
    svc.evict(0)
    svc.evict(3)
    assert svc.store.empty_slots[0] == 3       # LIFO: last evicted first
    checkpoint_service(svc, tmp_path, step=1)

    survivor = restore_service(tmp_path)
    assert survivor.store.empty_slots == svc.store.empty_slots
    # Bitwise restart: the next admission lands in the same slot.
    assert survivor.admit("fresh") == svc.admit("fresh") == 3


def test_from_state_empty_slots_fallback_and_validation():
    """Pre-slot-map checkpoints (no recorded order) fall back to
    descending; a recorded order inconsistent with the slot table is
    refused loudly."""
    st = FactorStore(6, capacity=4, width=2, panel=4, backend="reference")
    st.admit(0)
    st.admit(1)
    st.evict(0)

    re = FactorStore.from_state(
        st.factor, width=st.width, slots={1: st.slot(1)}, last_used={1: 0},
        init_scale=st.init_scale, ladder=st.ladder, widths=st.widths)
    assert re.empty_slots == (0, 2, 3)          # derived: descending stack

    with pytest.raises(ValueError, match="empty_slots"):
        FactorStore.from_state(
            st.factor, width=st.width, slots={1: st.slot(1)},
            last_used={1: 0}, init_scale=st.init_scale, ladder=st.ladder,
            widths=st.widths, empty_slots=(0, 1, 3))


# ---------------------------------------------------------------------------
# ISSUE 10: structured fleets through the durability layer
# ---------------------------------------------------------------------------


def _blocklocal_rows(n, block, m, seed, scale=0.25):
    """m block-local rows: each supported inside one adjacent block pair."""
    rng = np.random.default_rng(seed)
    rows = []
    nb = n // block
    for _ in range(m):
        j = int(rng.integers(0, max(nb - 1, 1)))
        v = np.zeros(n, np.float32)
        hi = min((j + 2) * block, n)
        v[j * block:hi] = scale * rng.normal(size=hi - j * block)
        rows.append(v)
    return rows


def _structured_service(n=16, block=4, B=2, width=3):
    st = FactorStore(n, capacity=B, width=width, panel=4, interpret=True,
                     structure="blocktridiag", block=block)
    return StreamService(st, window=6, auto_flush=False)


def test_kill_and_restart_structured_fleet_bitwise(tmp_path):
    """ISSUE 10 acceptance: a blocktridiag fleet round-trips
    checkpoint_service -> restore_service(warm=True) BITWISE (the block
    stacks are raw-byte checkpointed, no dense transit), and the survivor
    stays in lockstep through the next flush."""
    import jax

    n, block, B, width = 16, 4, 2, 3
    svc = _structured_service(n=n, block=block, B=B, width=width)
    for u in range(B):
        svc.admit(u)
    for v in _blocklocal_rows(n, block, width, seed=1):
        for u in range(B):
            svc.push(u, v)
    svc.flush()
    # Crash mid-buffer: unflushed rows live only in the seeded WAL.
    for v in _blocklocal_rows(n, block, 2, seed=2):
        svc.push(0, v)
    checkpoint_service(svc, tmp_path, step=1)

    meta = ckpt.read_meta(tmp_path, 1)["extra"]["stream"]
    assert meta["structure"] == "blocktridiag" and meta["block"] == block

    survivor = restore_service(tmp_path, warm=True)
    assert survivor.store.structure == "blocktridiag"
    assert survivor.store.block == block
    for a, b in zip(jax.tree_util.tree_leaves(svc.store.factor.data),
                    jax.tree_util.tree_leaves(survivor.store.factor.data)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert survivor.pending(0) == svc.pending(0)

    # Lockstep: the replayed buffers absorb to the same factor.
    r1, r2 = svc.flush(force=True), survivor.flush(force=True)
    assert r1.absorbed == r2.absorbed
    np.testing.assert_allclose(
        np.asarray(survivor.store.factor.data.diag, np.float32),
        np.asarray(svc.store.factor.data.diag, np.float32), atol=1e-6)


def test_structured_checkpoint_fails_loudly_for_dense_reader(tmp_path):
    """A structured checkpoint must never be reinterpreted as a dense
    fleet: a dense-template reader fails on leaf names, and an unknown
    structure kind in the meta is refused by name."""
    import json
    from pathlib import Path

    svc = _structured_service()
    svc.admit("u")
    checkpoint_service(svc, tmp_path, step=1)

    # Dense-only reader (the pre-ISSUE-10 template): loud leaf mismatch.
    cap, n = svc.store.capacity, svc.store.n
    with pytest.raises(ValueError, match="missing leaves"):
        ckpt.restore(tmp_path, 1, {"fleet": np.zeros((cap, n, n),
                                                     np.float32)})

    # Unknown structure kind recorded in meta: refused by name.
    mp = Path(tmp_path) / "step_00000001" / "tree.json"
    m = json.loads(mp.read_text())
    m["extra"]["stream"]["structure"] = "banded"
    mp.write_text(json.dumps(m))
    with pytest.raises(ValueError, match="banded"):
        restore_service(tmp_path)


def test_pre_structure_checkpoint_restores_dense_unchanged(tmp_path):
    """Compat default: checkpoints written before the storage-kind record
    (no 'structure'/'block' keys) restore as dense fleets, bit-for-bit."""
    import json
    from pathlib import Path

    svc = _service(n=8, B=2, width=2)
    svc.admit("u")
    for v in _rows(8, 2, seed=5):
        svc.push("u", v)
    svc.flush()
    checkpoint_service(svc, tmp_path, step=1)

    mp = Path(tmp_path) / "step_00000001" / "tree.json"
    m = json.loads(mp.read_text())
    del m["extra"]["stream"]["structure"]
    del m["extra"]["stream"]["block"]
    mp.write_text(json.dumps(m))

    survivor = restore_service(tmp_path)
    assert survivor.store.structure == "dense"
    np.testing.assert_array_equal(
        np.asarray(survivor.store.factor.data),
        np.asarray(svc.store.factor.data))
