"""repro.stream: coalescer, factor fleet, service — the streaming layer.

Coverage demanded by ISSUE 4: the sign-scheduling equivalence proof
(coalesced flush == sequential application on SPD-preserving streams,
property-based where hypothesis is present), the launch-count assertion
(a fleet of B users absorbing k=16 buffered rank-1 rows issues exactly ONE
fused batched rank-k mutation per sign block), fleet management
(admit/grow/evict/compact/decay), window forgetting, deadline flushes and
the feasibility-guarded downdate path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests.hypothesis_compat import given, settings, st

from repro.core import CholFactor, chol_update_ref
from repro.stream import (
    Coalescer,
    FactorStore,
    RingBuffer,
    StreamService,
    mutations_issued,
)
from tests.strategies import gauss_rows as _rows, make_problem, spd_stream, tol_for


def _seq_apply(L, stream, *, backend="reference", panel=16):
    """Sequential oracle: apply signed rank-1 rows in arrival order."""
    f = CholFactor.from_factor(L, panel=panel, backend=backend)
    for sign, v in stream:
        col = jnp.asarray(v)[:, None]
        f = f.update(col) if sign == 1 else f.downdate(col)
    return f


# ---------------------------------------------------------------------------
# RingBuffer / Coalescer
# ---------------------------------------------------------------------------


def test_ring_buffer_fifo_wrap_and_overflow():
    rb = RingBuffer(4, capacity=3)
    for i in range(3):
        rb.push(np.full(4, i, np.float32))
    assert rb.full and rb.count == 3
    with pytest.raises(OverflowError):
        rb.push(np.zeros(4, np.float32))
    out = rb.drain(2)                       # drop 0, 1 -> head wraps
    np.testing.assert_array_equal(out[:, 0], [0.0, 1.0])
    rb.push(np.full(4, 3, np.float32))      # physically wraps the ring
    rb.push(np.full(4, 4, np.float32))
    np.testing.assert_array_equal(rb.peek()[:, 0], [2.0, 3.0, 4.0])
    np.testing.assert_array_equal(rb.drain()[:, 0], [2.0, 3.0, 4.0])
    assert rb.count == 0
    with pytest.raises(ValueError):
        rb.push(np.zeros(5, np.float32))    # wrong row dim


def test_coalescer_width_trigger_and_sign_split():
    c = Coalescer(8, width=3)
    ups = _rows(8, 3, seed=0)
    dns = _rows(8, 2, seed=1)
    c.push_update(ups[0], tick=5)
    c.push_downdate(dns[0])
    c.push_update(ups[1])
    assert not c.ready() and c.pending == 3
    c.push_update(ups[2])                   # third update: width trigger
    assert c.ready() and c.pending_up == 3 and c.pending_down == 1
    c.push_downdate(dns[1])
    blocks = c.drain(tick=9)
    np.testing.assert_array_equal(blocks.up, np.stack(ups))     # FIFO
    np.testing.assert_array_equal(blocks.down, np.stack(dns))
    assert c.pending == 0 and c.first_tick is None
    with pytest.raises(ValueError):
        c.push(ups[0], sign=0)


def test_coalescer_deadline_and_partial_drain():
    c = Coalescer(4, width=4, deadline=3)
    c.push_update(np.ones(4, np.float32), tick=10)
    assert not c.expired(12)
    assert c.expired(13)
    # Over-width backlog drains in width-sized chunks, oldest first.
    c2 = Coalescer(4, width=2, capacity=6)
    for i in range(5):
        c2.push_update(np.full(4, i, np.float32))
    first = c2.drain()
    np.testing.assert_array_equal(first.up[:, 0], [0.0, 1.0])
    assert c2.pending == 3 and c2.first_tick is not None


def test_coalesced_flush_matches_sequential_deterministic():
    """The sign-schedule equivalence, deterministic twin of the property
    test below (runs even without hypothesis)."""
    n = 16
    L, _ = make_problem(n, 1, seed=3)
    for seed in (0, 1, 2):
        stream = spd_stream(n, 6, seed)
        f_seq = _seq_apply(L, stream)
        c = Coalescer(n, width=len(stream), capacity=2 * len(stream))
        for sign, v in stream:
            c.push(v, sign=sign)
        f_co, ok = c.flush_into(
            CholFactor.from_factor(L, panel=16, backend="reference"))
        assert bool(np.all(ok))
        np.testing.assert_allclose(
            f_co.data, f_seq.data, atol=4 * tol_for(jnp.float32, n))


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=4, max_value=24),
    n_ops=st.integers(min_value=1, max_value=10),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_property_sign_schedule_equals_sequential(n, n_ops, seed):
    """ISSUE 4 satellite: random interleaved update/downdate streams —
    one sign-scheduled coalesced flush (updates first, then the downdate
    block) lands on the same factor as sequential arrival-order
    application, whenever the stream keeps every sequential prefix SPD.
    Soundness: A + sum(u u^T) - sum(d d^T) is order-free and the Cholesky
    factor of an SPD matrix is unique."""
    L, _ = make_problem(n, 1, seed=seed % 1000)
    stream = spd_stream(n, n_ops, seed)
    f_seq = _seq_apply(L, stream)
    c = Coalescer(n, width=len(stream), capacity=2 * len(stream))
    for sign, v in stream:
        c.push(v, sign=sign)
    f_co, ok = c.flush_into(
        CholFactor.from_factor(L, panel=16, backend="reference"))
    assert bool(np.all(ok))
    np.testing.assert_allclose(
        f_co.data, f_seq.data, atol=6 * tol_for(jnp.float32, n))


# ---------------------------------------------------------------------------
# FactorStore: fleet management
# ---------------------------------------------------------------------------


def test_store_admit_grow_evict_compact():
    st_ = FactorStore(8, capacity=2, width=4, panel=4, backend="reference",
                      init_scale=4.0)
    assert st_.admit("a") != st_.admit("b")
    assert st_.admit("a") == st_.slot("a")          # idempotent
    st_.admit("c")                                   # forces a grow
    assert st_.capacity == 4 and st_.active == 3
    # Admitted slots are the warm start sqrt(init_scale) * I.
    np.testing.assert_allclose(
        np.asarray(st_.factor.data[st_.slot("c")]), 2.0 * np.eye(8),
        atol=1e-6)
    st_.evict("b")
    assert not st_.has("b") and st_.active == 2
    slot_a_data = np.asarray(st_.factor.data[st_.slot("a")])
    st_.compact()
    assert st_.capacity == 2 and sorted(st_.users()) == ["a", "c"]
    np.testing.assert_array_equal(
        np.asarray(st_.factor.data[st_.slot("a")]), slot_a_data)


def test_service_evict_idle_and_decay():
    st_ = FactorStore(4, capacity=2, width=2, panel=4, backend="reference",
                      init_scale=1.0)
    svc = StreamService(st_, auto_flush=False)
    svc.admit("old")
    for _ in range(10):
        svc.tick()
    svc.admit("new")
    # The service owns staleness policy; eviction also clears the user's
    # coalescer/schedule state (not just the slot table).
    assert svc.evict_idle(max_idle=5) == ("old",)
    assert st_.users() == ("new",)
    svc.decay(0.5)  # factor of 0.25 * A
    np.testing.assert_allclose(
        np.asarray(st_.factor_for("new").matrix()), 0.25 * np.eye(4),
        atol=1e-6)


def test_store_non_f32_dtype_threads_through_init_and_decay():
    """Regression (ISSUE 8 satellite): the identity-init and decay paths
    hardcoded np.float32 arithmetic while the zero-pad path respected
    ``row_dtype`` — an f64 fleet silently rounded its init scalar and
    decay multiplier through f32. Scalars chosen to be invisible to f32:
    the old code produces exactly 1.0 for both."""
    jax.config.update("jax_enable_x64", True)
    try:
        init = 1.0 + 2.0 ** -40   # f32(init) == 1.0
        alpha = 1.0 - 2.0 ** -30  # f32(alpha) == 1.0 (no decay at all)
        st_ = FactorStore(4, capacity=2, width=2, panel=4,
                          backend="reference", init_scale=init,
                          dtype=jnp.float64)
        assert st_.row_dtype == np.dtype(np.float64)
        st_.admit("u")
        got = np.asarray(st_.factor.data[st_.slot("u")])
        assert got.dtype == np.float64
        expect = np.sqrt(init, dtype=np.float64)
        assert got[0, 0] == expect != np.float64(1.0)
        st_.decay(alpha)
        got2 = np.asarray(st_.factor.data[st_.slot("u")])
        assert got2[0, 0] == expect * np.float64(alpha)
        assert got2[0, 0] != got[0, 0]
    finally:
        jax.config.update("jax_enable_x64", False)
        jax.clear_caches()


def test_store_apply_matches_batched_reference_and_pads():
    B, n, k = 3, 24, 4
    st_ = FactorStore(n, capacity=B, width=k, panel=8, backend="gemm")
    for u in range(B):
        st_.admit(u)
    # Ragged traffic: user 0 gets k rows, user 1 two, user 2 none.
    rows = {0: np.stack(_rows(n, k, seed=10)),
            1: np.stack(_rows(n, 2, seed=11))}
    ok = st_.apply(st_.pad_block({st_.slot(u): r for u, r in rows.items()}))
    assert ok is None  # update-only: no guard verdict
    for u in range(B):
        expect = jnp.eye(n)
        if u in rows:
            expect = chol_update_ref(expect, jnp.asarray(rows[u].T), sigma=1)
        np.testing.assert_allclose(
            st_.factor.data[st_.slot(u)], expect,
            atol=tol_for(jnp.float32, n), err_msg=f"user {u}")
    with pytest.raises(ValueError):
        st_.pad_block({0: np.zeros((k + 1, n), np.float32)})


# ---------------------------------------------------------------------------
# The launch-count story (acceptance criterion)
# ---------------------------------------------------------------------------


def test_fleet_flush_is_one_batched_mutation_per_sign_block():
    """ISSUE 4 acceptance: B users x k=16 buffered rank-1 rows -> exactly
    ONE fused batched rank-k mutation per sign block, counted by the
    stream analogue of ``repro.kernels.sharded.launches_traced``."""
    B, n, width = 4, 32, 16
    st_ = FactorStore(n, capacity=B, width=width, panel=16, backend="fused",
                      interpret=True)
    svc = StreamService(st_, auto_flush=False)
    rows = {u: _rows(n, width, seed=100 + u, scale=0.2) for u in range(B)}

    before = mutations_issued()
    for u in range(B):
        for v in rows[u]:
            svc.push(u, v)                   # auto-admits
    rep = svc.flush()
    assert mutations_issued() - before == 1, (
        "update-only flush must be ONE batched mutation for the whole fleet")
    assert rep.mutations == 1 and rep.rounds == 1
    assert rep.absorbed == {u: width for u in range(B)}
    for u in range(B):
        ref = chol_update_ref(jnp.eye(n),
                              jnp.asarray(np.stack(rows[u], axis=1)), sigma=1)
        np.testing.assert_allclose(
            st_.factor.data[st_.slot(u)], ref, atol=tol_for(jnp.float32, n))

    # Mixed traffic: width updates + downdates of half of each earlier row
    # -> exactly TWO mutations (one per sign block), sign-scheduled.
    before = mutations_issued()
    for u in range(B):
        for v in rows[u][:width]:
            svc.push(u, (0.3 * np.asarray(v)).astype(np.float32))
        for v in rows[u][:4]:
            svc.push(u, (0.5 * np.asarray(v)).astype(np.float32), sign=-1)
    rep2 = svc.flush()
    assert mutations_issued() - before == 2, (
        "mixed flush must be one mutation per sign block")
    assert rep2.mutations == 2 and rep2.rounds == 1
    assert all(rep2.downdate_ok.values())


def test_flush_backlog_drains_in_rounds():
    n, width = 8, 2
    st_ = FactorStore(n, capacity=1, width=width, panel=4,
                      backend="reference")
    svc = StreamService(st_, auto_flush=False, capacity=6)
    rows = _rows(n, 5, seed=7)
    for v in rows:
        svc.push("u", v)
    rep = svc.flush(force=True)
    assert rep.absorbed == {"u": 5}
    assert rep.rounds == 3                    # ceil(5 / width)
    ref = chol_update_ref(jnp.eye(n), jnp.asarray(np.stack(rows, axis=1)),
                          sigma=1)
    np.testing.assert_allclose(st_.factor.data[st_.slot("u")], ref,
                               atol=tol_for(jnp.float32, n))


# ---------------------------------------------------------------------------
# StreamService policies
# ---------------------------------------------------------------------------


def test_auto_flush_width_trigger():
    st_ = FactorStore(8, capacity=2, width=3, panel=4, backend="reference")
    svc = StreamService(st_)
    reps = [svc.push("u", v) for v in _rows(8, 3, seed=2)]
    assert reps[0] is None and reps[1] is None
    assert reps[2] is not None and reps[2].reason == "width"
    assert reps[2].absorbed == {"u": 3}
    assert svc.pending("u") == 0


def test_deadline_flush_on_tick():
    st_ = FactorStore(8, capacity=1, width=8, panel=4, backend="reference")
    svc = StreamService(st_, deadline=2, auto_flush=False)
    svc.push("u", _rows(8, 1, seed=3)[0])
    assert svc.tick() is None                 # age 1 < deadline
    rep = svc.tick()                          # age 2 == deadline
    assert rep is not None and rep.reason == "deadline"
    assert rep.absorbed == {"u": 1}


def test_window_forgetting_restores_prior_state():
    """Rows absorbed with window=W are downdated W ticks later — the
    sliding window as deferred, coalesced downdates."""
    n, width = 12, 4
    st_ = FactorStore(n, capacity=2, width=width, panel=4,
                      backend="reference")
    svc = StreamService(st_, window=3, auto_flush=False)
    for u in range(2):
        svc.admit(u)
    for v in _rows(n, width, seed=5):
        for u in range(2):
            svc.push(u, v)
    rep = svc.flush()
    assert rep.absorbed == {0: width, 1: width}
    assert svc.scheduled() == 2 * width
    reps = [svc.tick() for _ in range(3)]
    fired = [r for r in reps if r is not None]
    assert len(fired) == 1 and fired[0].downdated == {0: width, 1: width}
    assert all(fired[0].downdate_ok.values())
    assert svc.scheduled() == 0
    np.testing.assert_allclose(
        np.asarray(st_.factor.data), np.broadcast_to(np.eye(n), (2, n, n)),
        atol=4 * tol_for(jnp.float32, n))


def test_window_backlog_beyond_ring_capacity_drains_in_rounds():
    """Regression: several window groups coming due at the SAME tick (a
    serving loop that missed heartbeats) must not overflow the downdate
    ring — the flush makes room by draining early rounds."""
    n, width = 8, 2
    st_ = FactorStore(n, capacity=1, width=width, panel=4,
                      backend="reference")
    svc = StreamService(st_, window=1, auto_flush=False)  # ring capacity 4
    groups = 4                                             # 8 due rows > 4
    for g in range(groups):
        for v in _rows(n, width, seed=20 + g):
            svc.push("u", v)
        svc.flush()
    assert svc.scheduled() == groups * width
    rep = svc.tick()
    assert rep is not None
    assert rep.downdated == {"u": groups * width}
    assert all(rep.downdate_ok.values())
    assert svc.scheduled() == 0
    np.testing.assert_allclose(
        np.asarray(st_.factor.data[st_.slot("u")]), np.eye(n),
        atol=8 * tol_for(jnp.float32, n))


def test_guard_refuses_infeasible_downdate_others_proceed():
    n = 10
    st_ = FactorStore(n, capacity=2, width=4, panel=4, backend="reference")
    svc = StreamService(st_, auto_flush=False)
    good = _rows(n, 1, seed=8, scale=0.1)[0]
    svc.admit(0)
    svc.admit(1)
    svc.push(0, good)
    svc.push(0, (0.5 * good).astype(np.float32), sign=-1)
    svc.push(1, (10.0 * np.ones(n)).astype(np.float32), sign=-1)  # infeasible
    before = np.asarray(st_.factor.data[st_.slot(1)]).copy()
    rep = svc.flush(force=True)
    assert rep.downdate_ok[0] is True
    assert rep.downdate_ok[1] is False
    np.testing.assert_array_equal(
        np.asarray(st_.factor.data[st_.slot(1)]), before)


def test_service_adopts_users_admitted_directly_on_the_store():
    """Regression: a user admitted on the FactorStore before the service
    wrapped it still gets a coalescer at service admit/push time (admit
    keys on service membership, not store membership)."""
    st_ = FactorStore(8, capacity=2, width=2, panel=4, backend="reference")
    st_.admit("early")
    svc = StreamService(st_, auto_flush=False)
    for v in _rows(8, 2, seed=30):
        svc.push("early", v)                 # must not KeyError
    rep = svc.flush()
    assert rep.absorbed == {"early": 2}
    svc.evict("early")
    assert not st_.has("early")


def test_service_evict_drops_pending_and_schedule():
    st_ = FactorStore(6, capacity=2, width=2, panel=4, backend="reference")
    svc = StreamService(st_, window=5, auto_flush=False)
    for v in _rows(6, 2, seed=9):
        svc.push("gone", v)
    svc.flush()
    assert svc.scheduled() == 2
    svc.push("gone", _rows(6, 1, seed=10)[0])
    svc.evict("gone")
    assert svc.scheduled() == 0 and svc.pending("gone") == 0
    assert not st_.has("gone")
    # A later flush at the expiry tick must be a clean no-op.
    for _ in range(6):
        assert svc.tick() is None
