"""Fused single-launch kernel: parity vs the serial oracle + batched API.

Coverage demanded by the fusion design (DESIGN.md §5): sigma = ±1, n not a
multiple of the panel size, rank k in {1, 4, 16}, both in-kernel panel-apply
strategies, and the vmapped batched entry point against a Python loop of
single updates.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import chol_update, chol_update_batched, ref
from repro.kernels import fused as F

from tests.test_core_cholupdate import make_problem, tol_for


def _downdatable(L, V):
    A2 = L.T @ L + V @ V.T
    return jnp.linalg.cholesky(A2).T


@pytest.mark.parametrize("sigma", [1, -1])
@pytest.mark.parametrize("k", [1, 4, 16])
@pytest.mark.parametrize("n,panel", [(64, 16), (96, 32), (129, 64)])
def test_fused_matches_reference(n, panel, k, sigma):
    L, V = make_problem(n, k, seed=n + 3 * k)
    if sigma == -1:
        L = _downdatable(L, V)
    L_ref = ref.chol_update_ref(L, V, sigma=sigma)
    L_f = F.chol_update_fused(L, V, sigma=sigma, panel=panel, interpret=True)
    np.testing.assert_allclose(L_f, L_ref, atol=tol_for(jnp.float32, n))
    # factor structure survives the fused path (incl. the padded tail)
    assert float(jnp.max(jnp.abs(jnp.tril(L_f, -1)))) == 0.0


@pytest.mark.parametrize("panel_apply", ["gemm", "paper"])
def test_fused_panel_apply_strategies_agree(panel_apply):
    n, k, panel = 128, 8, 32
    L, V = make_problem(n, k, seed=17)
    L_ref = ref.chol_update_ref(L, V, sigma=1)
    L_f = F.chol_update_fused(
        L, V, sigma=1, panel=panel, panel_apply=panel_apply, interpret=True
    )
    np.testing.assert_allclose(L_f, L_ref, atol=tol_for(jnp.float32, n))


def test_fused_ragged_n_and_rank1_vector():
    # n=100 with panel=32 exercises the identity-padded tail; a (n,) vector
    # must behave exactly like its (n, 1) reshape.
    n, panel = 100, 32
    L, V = make_problem(n, 1, seed=23)
    a = F.chol_update_fused(L, V[:, 0], sigma=1, panel=panel, interpret=True)
    b = F.chol_update_fused(L, V, sigma=1, panel=panel, interpret=True)
    np.testing.assert_allclose(a, b, atol=0)
    np.testing.assert_allclose(
        a, ref.chol_update_ref(L, V, sigma=1), atol=tol_for(jnp.float32, n)
    )


def test_fused_via_api_and_validation():
    n, k, panel = 96, 4, 32
    L, V = make_problem(n, k, seed=31)
    L_api = chol_update(L, V, sigma=1, method="fused", panel=panel, interpret=True)
    L_ref = ref.chol_update_ref(L, V, sigma=1)
    np.testing.assert_allclose(L_api, L_ref, atol=tol_for(jnp.float32, n))
    with pytest.raises(ValueError):
        F.chol_update_fused(L, V, sigma=2, interpret=True)
    with pytest.raises(ValueError):
        F.chol_update_fused(L, V, panel_apply="nope", interpret=True)


def test_fused_update_downdate_roundtrip():
    n, k, panel = 96, 5, 32
    L, V = make_problem(n, k, seed=41)
    L_up = F.chol_update_fused(L, V, sigma=1, panel=panel, interpret=True)
    L_back = F.chol_update_fused(L_up, V, sigma=-1, panel=panel, interpret=True)
    np.testing.assert_allclose(L_back, L, atol=tol_for(jnp.float32, n))
    # paper's own acceptance metric
    assert float(ref.modify_error(L_up, L, V, sigma=1)) < 1e-2


@pytest.mark.parametrize("method", ["fused", "gemm", "reference"])
def test_batched_matches_loop_of_singles(method):
    B, n, k, panel = 4, 80, 4, 32
    Ls, Vs = [], []
    for b in range(B):
        L, V = make_problem(n, k, seed=100 + b)
        Ls.append(L)
        Vs.append(V)
    Lb = jnp.stack(Ls)
    Vb = jnp.stack(Vs)
    out = chol_update_batched(
        Lb, Vb, sigma=1, method=method, panel=panel, interpret=True
    )
    assert out.shape == (B, n, n)
    for b in range(B):
        single = chol_update(
            Ls[b], Vs[b], sigma=1, method=method, panel=panel, interpret=True
        )
        np.testing.assert_allclose(out[b], single, atol=tol_for(jnp.float32, n))


def test_batched_rank1_2d_input_and_validation():
    B, n = 3, 48
    Ls, Vs = [], []
    for b in range(B):
        L, V = make_problem(n, 1, seed=200 + b)
        Ls.append(L)
        Vs.append(V[:, 0])
    Lb, Vb = jnp.stack(Ls), jnp.stack(Vs)  # V is (B, n)
    out = chol_update_batched(Lb, Vb, sigma=1, method="fused", panel=16,
                              interpret=True)
    for b in range(B):
        np.testing.assert_allclose(
            out[b],
            ref.chol_update_ref(Ls[b], Vs[b], sigma=1),
            atol=tol_for(jnp.float32, n),
        )
    with pytest.raises(ValueError):
        chol_update_batched(Ls[0], Vs[0])  # unbatched input
    with pytest.raises(ValueError):
        chol_update_batched(Lb, Vb[:, : n // 2])  # n mismatch


@pytest.mark.parametrize("grid_mode", ["indexed", "rect"])
@pytest.mark.parametrize("sigma", [1, -1])
def test_fused_grid_modes_agree(grid_mode, sigma):
    """The 1-D scalar-prefetch indexed grid and the clamped rectangular grid
    are the same algorithm: bitwise-comparable results, fewer grid steps."""
    n, k, panel = 96, 4, 32
    L, V = make_problem(n, k, seed=53)
    if sigma == -1:
        L = _downdatable(L, V)
    out = F.chol_update_fused(L, V, sigma=sigma, panel=panel,
                              grid_mode=grid_mode, interpret=True)
    np.testing.assert_allclose(
        out, ref.chol_update_ref(L, V, sigma=sigma),
        atol=tol_for(jnp.float32, n),
    )
    with pytest.raises(ValueError):
        F.chol_update_fused(L, V, grid_mode="nope", interpret=True)


# ---------------------------------------------------------------------------
# ISSUE 7: the portable lowering (plain GridSpec, chain in loop carries)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("grid_mode", ["indexed", "rect"])
@pytest.mark.parametrize("sigma", [1, -1])
def test_portable_lowering_matches_mosaic_and_reference(grid_mode, sigma):
    """ISSUE 7 acceptance: portable == mosaic == reference, both grid
    modes, both signs, in interpret mode (f32)."""
    n, k, panel = 96, 4, 32
    L, V = make_problem(n, k, seed=61)
    if sigma == -1:
        L = _downdatable(L, V)
    kw = dict(sigma=sigma, panel=panel, grid_mode=grid_mode, interpret=True)
    out_m = F.chol_update_fused(L, V, lowering="mosaic", **kw)
    out_p = F.chol_update_fused(L, V, lowering="portable", **kw)
    np.testing.assert_allclose(
        out_p, ref.chol_update_ref(L, V, sigma=sigma),
        atol=tol_for(jnp.float32, n))
    np.testing.assert_allclose(out_p, out_m, atol=tol_for(jnp.float32, n))


@pytest.mark.parametrize("grid_mode", ["indexed", "rect"])
def test_portable_lowering_bf16_matches_mosaic(grid_mode):
    """The precision split survives the scratch→carry move: bf16 storage,
    fp32 recurrence/transform state, same tolerance as the mosaic spec."""
    n, k, panel = 96, 4, 32
    L, V = make_problem(n, k, seed=67)
    kw = dict(sigma=1, panel=panel, grid_mode=grid_mode, interpret=True,
              precision="bf16")
    out_m = F.chol_update_fused(L, V, lowering="mosaic", **kw)
    out_p = F.chol_update_fused(L, V, lowering="portable", **kw)
    assert out_p.dtype == jnp.bfloat16
    ref_up = ref.chol_update_ref(L, V, sigma=1)
    err = float(jnp.max(jnp.abs(out_p.astype(jnp.float32) - ref_up)))
    assert err < 32 * 2.0 ** -8 * float(jnp.max(jnp.abs(ref_up)))
    np.testing.assert_allclose(np.asarray(out_p, jnp.float32),
                               np.asarray(out_m, jnp.float32), rtol=0,
                               atol=4 * 2.0 ** -8)


@pytest.mark.parametrize("panel_apply", ["gemm", "paper"])
def test_portable_lowering_panel_apply_strategies(panel_apply):
    n, k, panel = 64, 8, 16
    L, V = make_problem(n, k, seed=71)
    out = F.chol_update_fused(L, V, sigma=1, panel=panel,
                              panel_apply=panel_apply, lowering="portable",
                              interpret=True)
    np.testing.assert_allclose(
        out, ref.chol_update_ref(L, V, sigma=1),
        atol=tol_for(jnp.float32, n))


def test_portable_lowering_vmap_single_launch():
    """vmap folds B into the ONE portable launch (the step tables are
    unbatched constants, so the cond chain survives batching)."""
    B, n, k, panel = 3, 64, 4, 16
    Ls, Vs = [], []
    for b in range(B):
        L, V = make_problem(n, k, seed=80 + b)
        Ls.append(L)
        Vs.append(V)
    Lb, Vb = jnp.stack(Ls), jnp.stack(Vs)
    jax.clear_caches()
    before = F.lowerings_traced()
    out = jax.vmap(lambda l, v: F.chol_update_fused(
        l, v, sigma=1, panel=panel, lowering="portable", interpret=True)
    )(Lb, Vb)
    after = F.lowerings_traced()
    assert after["portable"] - before["portable"] == 1
    for b in range(B):
        np.testing.assert_allclose(
            out[b], ref.chol_update_ref(Ls[b], Vs[b], sigma=1),
            atol=tol_for(jnp.float32, n))


def test_lowering_auto_resolves_by_device_kind(fake_device_kind):
    """lowering='auto' (the default) picks the portable spec on GPU kinds
    and the mosaic spec elsewhere — and records which spec it traced."""
    n, k, panel = 48, 2, 16
    L, V = make_problem(n, k, seed=91)
    fake_device_kind("gpu")
    jax.clear_caches()
    before = F.lowerings_traced()
    F.chol_update_fused(L, V, sigma=1, panel=panel, interpret=True)
    after = F.lowerings_traced()
    assert after["portable"] - before["portable"] == 1
    assert after["mosaic"] == before["mosaic"]
    with pytest.raises(ValueError, match="lowering"):
        F.chol_update_fused(L, V, sigma=1, panel=panel, lowering="nope",
                            interpret=True)


def test_explicit_interpret_false_wins_over_default(fake_device_kind,
                                                    monkeypatch):
    """ISSUE 7 bugfix regression: an explicit ``interpret=False`` must
    reach the kernel call untouched — the old entry point consulted
    ``default_interpret(mosaic_only=True)`` only when the argument was
    None, but the routing heuristics (and this test's fake GPU kind) must
    never override a caller's explicit choice in either direction."""
    n, k, panel = 48, 2, 16
    L, V = make_problem(n, k, seed=97)
    seen = {}
    real = F._fused_call

    def capture(Lp, vt, **kw):
        seen.update(kw)
        # Execute in interpret mode regardless, so the capture runs on the
        # CPU host even when the caller asked for a compiled kernel.
        kw["interpret"] = True
        return real(Lp, vt, **kw)

    monkeypatch.setattr(F, "_fused_call", capture)
    fake_device_kind("gpu")
    # Explicit False survives the fake-GPU default (which would be False
    # for portable anyway — so ALSO check the mosaic lowering, where the
    # auto-detect on a GPU kind says True).
    F.chol_update_fused(L, V, sigma=1, panel=panel, lowering="mosaic",
                        interpret=False)
    assert seen["interpret"] is False
    F.chol_update_fused(L, V, sigma=1, panel=panel, lowering="mosaic",
                        interpret=True)
    assert seen["interpret"] is True
    # No explicit argument: the lowering-aware auto-detect decides.
    F.chol_update_fused(L, V, sigma=1, panel=panel, lowering="mosaic")
    assert seen["interpret"] is True  # mosaic can't compile on gpu
    F.chol_update_fused(L, V, sigma=1, panel=panel, lowering="portable")
    assert seen["interpret"] is False  # portable compiles on gpu
    fake_device_kind("cpu")
    F.chol_update_fused(L, V, sigma=1, panel=panel, interpret=False)
    assert seen["interpret"] is False


def test_grid_steps_accounting():
    # The squash satellite, as arithmetic: triangular vs rectangular steps.
    assert F.grid_steps(4096, 256, grid_mode="indexed") == 16 * 17 // 2
    assert F.grid_steps(4096, 256, grid_mode="rect") == 16 * 16
    assert F.grid_steps(100, 256, grid_mode="indexed") == 1
    with pytest.raises(ValueError):
        F.grid_steps(4096, 256, grid_mode="nope")


def test_launch_count_accounting():
    # The tentpole claim, as arithmetic: one launch regardless of n/panel.
    assert F.launch_count(4096, 256, method="fused") == 1
    assert F.launch_count(4096, 256, method="pallas") == 15
    assert F.launch_count(4096, 256, method="pallas_2phase") == 31
    assert F.launch_count(100, 256, method="fused") == 1
    # single-panel problem: no trailing block, so the cascade launches none
    assert F.launch_count(100, 256, method="pallas") == 0
    assert F.launch_count(100, 256, method="pallas_2phase") == 1
    with pytest.raises(ValueError):
        F.launch_count(4096, 256, method="nope")
