"""repro.core.structure: the storage-structure layer (ISSUE 8).

Unit coverage for the layer the conformance harness's structure axis
builds on: ``BlockTriDiagStorage``'s chain factorization and block
substitution against their dense twins, the block-local V contract
validator, dense delegation bit-identity through the refactored
``CholFactor``, checkpoint round-trip of a structured factor, and the two
acceptance pins that justify the layer's existence —

* the structured modification path never materialises an ``(n, n)`` array
  (asserted on the jaxpr: every intermediate aval, including inside
  sub-jaxprs, stays well under n² elements);
* ``backends.dispatch`` keys its size heuristic on the factor ORDER, not
  ``shape[0]`` (the batched direct-dispatch regression).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.core import CholFactor, api, backends, chol_update_ref
from repro.core.structure import (
    BlockTriDiagStorage,
    DenseStorage,
    assert_blocklocal,
    is_factor_storage,
)
from tests.strategies import make_banded_problem, make_problem, tol_for

NB, BLK, K = 6, 8, 3
N = NB * BLK


def _problem(seed=0):
    Ad, Ao, V = make_banded_problem(NB, BLK, K, seed=seed)
    S = BlockTriDiagStorage.from_matrix_blocks(Ad, Ao)
    return S, V, Ad, Ao


# ---------------------------------------------------------------------------
# BlockTriDiagStorage vs its dense twin
# ---------------------------------------------------------------------------


def test_chain_factorization_matches_dense_cholesky():
    S, _, Ad, Ao = _problem()
    A = np.zeros((N, N), np.float32)
    for j in range(NB):
        A[j * BLK:(j + 1) * BLK, j * BLK:(j + 1) * BLK] = Ad[j]
    for j in range(NB - 1):
        blk = np.asarray(Ao[j])
        A[j * BLK:(j + 1) * BLK, (j + 1) * BLK:(j + 2) * BLK] = blk
        A[(j + 1) * BLK:(j + 2) * BLK, j * BLK:(j + 1) * BLK] = blk.T
    Ld = jnp.linalg.cholesky(jnp.asarray(A)).T
    np.testing.assert_allclose(np.asarray(S.to_dense()), np.asarray(Ld),
                               atol=tol_for(jnp.float32, N))
    # And the storage reconstructs the blocks it was factored from.
    Ad2, Ao2 = S.matrix_blocks()
    np.testing.assert_allclose(np.asarray(Ad2), np.asarray(Ad), atol=1e-4)
    np.testing.assert_allclose(np.asarray(Ao2), np.asarray(Ao), atol=1e-4)


def test_block_substitution_matches_dense_solves():
    S, _, _, _ = _problem(seed=1)
    Ld = S.to_dense()
    rng = np.random.default_rng(2)
    for rhs_shape in [(N,), (N, 2)]:
        b = jnp.asarray(rng.normal(size=rhs_shape), jnp.float32)
        for trans in (True, False):
            got = S.solve_triangular(b, trans=trans)
            want = jax.scipy.linalg.solve_triangular(
                Ld, b, trans=1 if trans else 0, lower=False)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=1e-4, err_msg=f"trans={trans}")
        np.testing.assert_allclose(
            np.asarray(S.solve(b)),
            np.asarray(jnp.linalg.solve(S.matrix(), b)),
            atol=1e-3)
    np.testing.assert_allclose(
        float(S.logdet()),
        float(2.0 * jnp.sum(jnp.log(jnp.diagonal(Ld)))), rtol=1e-6)
    assert bool(S.is_valid())
    assert S.n == N and not S.batched
    assert "blocktridiag" in S.describe()


def test_from_dense_to_dense_round_trip_and_feasibility():
    S, V, _, _ = _problem(seed=3)
    S2 = BlockTriDiagStorage.from_dense(S.to_dense(), BLK)
    np.testing.assert_allclose(np.asarray(S2.diag), np.asarray(S.diag),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(S2.off), np.asarray(S.off),
                               atol=1e-6)
    # Feasibility verdict agrees with the dense criterion.
    assert bool(S.downdate_feasible(0.1 * V))
    assert not bool(S.downdate_feasible(100.0 * V))


def test_blocklocal_contract_validator():
    V = np.zeros((N, 2), np.float32)
    V[0:2 * BLK, 0] = 1.0          # block pair {0, 1}: fine
    V[3 * BLK:4 * BLK, 1] = 1.0    # single block: fine
    assert_blocklocal(V, BLK)
    V[0, 1] = 1.0                  # column 1 now spans blocks {0, 3}
    with pytest.raises(ValueError, match="spans block rows"):
        assert_blocklocal(V, BLK)
    with pytest.raises(ValueError):
        BlockTriDiagStorage(jnp.zeros((4, 8, 8)), jnp.zeros((2, 8, 8)))


# ---------------------------------------------------------------------------
# Dense delegation bit-identity through the refactored CholFactor
# ---------------------------------------------------------------------------


def test_dense_delegation_is_bit_identical():
    from repro.core import solve as _solve

    L, V = make_problem(24, 2, seed=4)
    f = CholFactor.from_factor(L, backend="gemm", panel=8)
    assert f.structure == "dense"
    assert isinstance(f.storage, DenseStorage)
    # The pytree leaf stays the BARE array (checkpoint layout unchanged).
    leaves, _ = jax.tree_util.tree_flatten(f)
    assert len(leaves) == 1 and leaves[0] is f.data
    assert isinstance(f.data, jax.Array)
    rhs = jnp.ones((24,), jnp.float32)
    np.testing.assert_array_equal(np.asarray(f.solve(rhs)),
                                  np.asarray(_solve.chol_solve(L, rhs)))
    np.testing.assert_array_equal(
        np.asarray(f.solve_triangular(rhs, trans=True)),
        np.asarray(_solve.solve_triangular(L, rhs, trans=True)))
    np.testing.assert_array_equal(np.asarray(f.logdet()),
                                  np.asarray(_solve.chol_logdet(L)))
    np.testing.assert_array_equal(
        np.asarray(f.matrix()),
        np.asarray(jnp.swapaxes(L, -1, -2) @ L))
    np.testing.assert_array_equal(np.asarray(f.diagonal()),
                                  np.asarray(jnp.diagonal(L)))
    assert not is_factor_storage(L)
    assert is_factor_storage(f.storage)


# ---------------------------------------------------------------------------
# Structured factor as a pytree: jit, scan, checkpoint
# ---------------------------------------------------------------------------


def test_structured_factor_jits_and_scans():
    S, V, _, _ = _problem(seed=5)
    f = CholFactor.from_storage(S, backend="blocktridiag_ref")

    @jax.jit
    def step(fac, v):
        return fac.update(v), fac.logdet()

    f2, ld = step(f, V)
    assert isinstance(f2.data, BlockTriDiagStorage)
    ref = chol_update_ref(S.to_dense(), V, sigma=1)
    np.testing.assert_allclose(np.asarray(f2.data.to_dense()),
                               np.asarray(ref),
                               atol=tol_for(jnp.float32, N))


def test_structured_factor_checkpoint_round_trip(tmp_path):
    S, V, _, _ = _problem(seed=6)
    f = CholFactor.from_storage(S, backend="blocktridiag_ref").update(V)
    state = {"factor": f, "step": jnp.asarray(3)}
    ckpt.save(tmp_path, 1, state)
    like = {"factor": CholFactor.from_storage(
        BlockTriDiagStorage(jnp.zeros_like(S.diag), jnp.zeros_like(S.off)),
        backend="blocktridiag_ref"), "step": jnp.asarray(0)}
    got = ckpt.restore(tmp_path, 1, like)
    assert isinstance(got["factor"].data, BlockTriDiagStorage)
    np.testing.assert_array_equal(np.asarray(got["factor"].data.diag),
                                  np.asarray(f.data.diag))
    np.testing.assert_array_equal(np.asarray(got["factor"].data.off),
                                  np.asarray(f.data.off))


# ---------------------------------------------------------------------------
# Acceptance: the modification path never materialises (n, n)
# ---------------------------------------------------------------------------


def _iter_jaxprs(jaxpr):
    """The jaxpr and every sub-jaxpr reachable through eqn params."""
    yield jaxpr
    for eqn in jaxpr.eqns:
        for val in eqn.params.values():
            vals = val if isinstance(val, (tuple, list)) else (val,)
            for v in vals:
                inner = getattr(v, "jaxpr", v)
                if hasattr(inner, "eqns"):
                    yield from _iter_jaxprs(inner)


@pytest.mark.parametrize("backend", ["blocktridiag", "blocktridiag_ref"])
def test_modification_path_never_materialises_dense(backend):
    """ISSUE 8 acceptance: every intermediate aval of the structured
    update — including inside scan/pallas/custom_jvp sub-jaxprs — holds
    far fewer than n² elements. The largest structured buffer is the
    (nb·b, b) stacked diag (n·b elements); a dense materialisation at
    n = 48 would be 2304 and trips the n²/2 bar immediately."""
    S, V, _, _ = _problem()

    def step(S, V):
        return api.chol_update(S, V, method=backend, interpret=True)

    jaxpr = jax.make_jaxpr(step)(S, V)
    bar = N * N // 2
    biggest = 0
    for jx in _iter_jaxprs(jaxpr.jaxpr):
        for eqn in jx.eqns:
            for var in list(eqn.invars) + list(eqn.outvars):
                aval = getattr(var, "aval", None)
                shape = getattr(aval, "shape", None)
                if shape is None:
                    continue
                size = int(np.prod(shape, dtype=np.int64))
                biggest = max(biggest, size)
                assert size < bar, (
                    f"{backend}: aval {shape} ({size} elems) in "
                    f"{eqn.primitive} — the O(n·b) path materialised a "
                    f"dense-scale buffer (bar {bar})")
    # Sanity that the walk saw the real buffers, not an empty graph.
    assert biggest >= NB * BLK * BLK


def test_structured_grad_does_not_densify():
    """ISSUE 10 acceptance (flips the old does-densify pin): the tangent
    rule applies the Murray recurrences blockwise along the chain, so NO
    n² intermediate appears in the primal OR the tangent/adjoint graph.
    The largest legitimate buffer is a (nb, b, b) block stack (n·b
    elements); at N = 48 a dense lift would be 2304 and trip the n²/2
    bar immediately."""
    S, V, _, _ = _problem()

    def loss(S, V):
        return api.chol_update(S, V, method="blocktridiag_ref").logdet()

    jaxpr = jax.make_jaxpr(jax.grad(loss, argnums=(0, 1)))(S, V)
    bar = N * N // 2
    biggest = 0
    for jx in _iter_jaxprs(jaxpr.jaxpr):
        for eqn in jx.eqns:
            for var in list(eqn.invars) + list(eqn.outvars):
                aval = getattr(var, "aval", None)
                shape = getattr(aval, "shape", None)
                if shape is None:
                    continue
                size = int(np.prod(shape, dtype=np.int64))
                biggest = max(biggest, size)
                assert size < bar, (
                    f"aval {shape} ({size} elems) in {eqn.primitive} — "
                    f"the grad graph materialised a dense-scale buffer "
                    f"(bar {bar})")
    # Sanity that the walk saw the real block buffers.
    assert biggest >= NB * BLK * BLK


# ---------------------------------------------------------------------------
# Regression: dispatch sizes its heuristic by factor order, not shape[0]
# ---------------------------------------------------------------------------


def test_dispatch_n_is_factor_order_not_batch_count(monkeypatch,
                                                    fake_device_kind):
    """``backends.dispatch`` used ``n=L.shape[0]`` — for a batched
    (B, n, n) leaf reaching the funnel directly that reads the BATCH
    count, so a fleet of 2 factors of order 512 resolved as n=2 and the
    auto heuristic picked the serial oracle instead of the panelled GEMM
    driver. The backend is stubbed out: only routing is under test."""
    fake_device_kind("cpu")
    resolved = []

    def fake_get(name):
        resolved.append(name)
        return lambda L, V, **kw: L

    monkeypatch.setattr(backends, "get", fake_get)
    B, n = 2, 512
    L = jnp.zeros((B, n, n), jnp.float32)
    V = jnp.zeros((B, n, 1), jnp.float32)
    backends.dispatch(L, V, sigma=1, method="auto", panel=256,
                      interpret=None)
    # n=512 >= 2*panel -> 'gemm'; the old shape[0]=2 gave 'reference'.
    assert resolved == ["gemm"]
    # Structured storage routes by the storage's own order (no .shape at
    # all on the storage path).
    resolved.clear()
    S, V2, _, _ = _problem()
    backends.dispatch(S, V2, sigma=1, method="auto", panel=256,
                      interpret=True)
    assert resolved == ["blocktridiag"]


def test_structured_factor_repr_and_scale():
    S, _, _, _ = _problem()
    f = CholFactor.from_storage(S, backend="blocktridiag_ref")
    assert "blocktridiag" in repr(f)
    g = f.scale(0.5)
    np.testing.assert_allclose(np.asarray(g.data.diag),
                               0.5 * np.asarray(S.diag), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(g.data.off),
                               0.5 * np.asarray(S.off), rtol=1e-6)
    # replace() keeps the storage data shared (metadata-only change).
    h = f.with_backend("blocktridiag")
    assert h.data is f.data
    assert dataclasses.replace(h, panel=32).panel == 32
