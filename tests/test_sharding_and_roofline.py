"""Sharding rules + roofline analysis unit tests (mesh-free where possible)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES_BY_NAME
from repro.roofline import analysis as RA
from repro.roofline.hloparse import analyze_hlo


class FakeMesh:
    def __init__(self, shape):
        self.shape = dict(shape)


def spec_of(axes, shape, mesh, **kw):
    from repro.sharding.rules import logical_to_spec

    return logical_to_spec(axes, shape, mesh, **kw)


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_tp_sharding_divisible():
    s = spec_of(("embed", "mlp"), (4096, 14336), MESH)
    assert s == P(None, "model")
    s = spec_of(("embed", "heads", "head_dim"), (4096, 32, 128), MESH)
    assert s == P(None, "model", None)


def test_tp_replicates_indivisible_heads():
    notes = []
    s = spec_of(("embed", "heads", "head_dim"), (3072, 24, 128), MESH,
                notes=notes)
    assert s == P(None, None, None)
    assert notes and notes[0][0] == "heads"


def test_fsdp_shards_embed_over_data():
    s = spec_of(("embed", "mlp"), (4096, 14336), MESH, fsdp=True)
    assert s == P("data", "model")
    s3 = spec_of(("embed", "mlp"), (4096, 14336), MESH3, fsdp=True)
    assert s3 == P(("pod", "data"), "model")


def test_dp_policy_fully_shards_over_both_axes():
    s = spec_of(("embed", "mlp"), (2560, 8960), MESH, policy="dp")
    assert s == P(("data", "model"), None)
    # TP axes are not sharded under dp
    s = spec_of(("vocab", "embed"), (65536, 2560), MESH, policy="dp")
    assert s[1] == ("data", "model") or s[1] == (("data", "model"))


def test_experts_ep_vs_expert_mlp():
    # arctic: 128 experts shard; mixtral: 8 experts replicate, d_ff shards
    s = spec_of(("experts", "embed", "expert_mlp"), (128, 7168, 4864), MESH)
    assert s == P("model", None, None)
    s = spec_of(("experts", "embed", "expert_mlp"), (8, 6144, 16384), MESH)
    assert s == P(None, None, "model")


def test_one_mesh_axis_per_tensor():
    # both dims want 'model': only the first gets it
    s = spec_of(("mlp", "vocab"), (14336, 256000), MESH)
    assert s == P("model", None)


def test_active_params_sane():
    for name, cfg in ARCHS.items():
        n = RA.active_params(cfg)
        assert n > 1e8, f"{name}: active params {n} too small"
    # MoE active << total: arctic top-2 of 128
    arctic = ARCHS["arctic-480b"]
    active = RA.active_params(arctic)
    assert active < 30e9  # ~17B active vs ~480B total


def test_model_flops_attention_term():
    cfg = ARCHS["granite-20b"]
    f_train = RA.model_flops(cfg, SHAPES_BY_NAME["train_4k"])
    f_prefill = RA.model_flops(cfg, SHAPES_BY_NAME["prefill_32k"])
    # prefill_32k has 1/2 the tokens but ~8x the attention work per token;
    # with the attention term it must exceed 1/3 of the train flops
    assert f_prefill > f_train / 3.0


def test_hloparse_counts_loops():
    """A scanned matmul must count trip x body flops."""
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    flops, coll, kinds, info = analyze_hlo(compiled.as_text())
    expect = 7 * 2 * 8 * 16 * 16
    assert flops == pytest.approx(expect, rel=0.01), (flops, expect)
    assert coll == 0.0


def test_collective_cost_model():
    text = """
HloModule test

ENTRY %main (p: f32[128,256]) -> f32[128,256] {
  %p = f32[128,256] parameter(0)
  ROOT %ar = f32[128,256] all-reduce(%p), replica_groups=[16,16]<=[256], to_apply=%add
}
"""
    flops, coll, kinds, _ = analyze_hlo(text)
    assert coll == pytest.approx(2 * 128 * 256 * 4)  # ring 2x
    assert "all-reduce" in kinds


def test_analytic_memory_model_orders():
    cfg = ARCHS["llama3.2-3b"]
    train = RA.analytic_memory_bytes(cfg, SHAPES_BY_NAME["train_4k"], 256,
                                     params_local_bytes=4e8,
                                     opt_local_bytes=1.6e9)
    decode = RA.analytic_memory_bytes(cfg, SHAPES_BY_NAME["decode_32k"], 256,
                                      params_local_bytes=4e8)
    assert train > decode  # training traffic dominates decode per step
    assert decode > 4e8    # at least one param read


def test_constrain_batch_dim_noop_without_mesh():
    from repro.sharding.rules import constrain_batch_dim

    x = jnp.ones((4, 8))
    y = constrain_batch_dim(x, 0)  # no mesh in context -> passthrough
    np.testing.assert_array_equal(x, y)
