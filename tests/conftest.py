"""Shared fixtures for the tier-1 suite (ISSUE 5 test-harness satellite).

What lives here (vs ``tests/strategies.py``, which holds the data
generators and hypothesis strategies):

* ``fake_device_kind`` — patch the device kind the backend heuristics
  see, without real hardware (previously hand-rolled per test file).
* ``fake_mesh`` — a mesh-shaped duck type with controllable identity, for
  cache-keying tests where real (interned) Meshes can't produce two
  distinct-but-equal objects.
* ``require_devices`` — skip helper for multi-device tests so the
  conformance matrix runs its sharded column under the CI shard-emulation
  job (``XLA_FLAGS=--xla_force_host_platform_device_count=4``) and skips
  cleanly on a single-device run.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest


@pytest.fixture
def fake_device_kind(monkeypatch):
    """Make backend heuristics see a chosen device kind.

    Usage::

        def test_...(fake_device_kind):
            fake_device_kind("tpu")
            assert backends.resolve("auto", n=64) == "fused"

    Patches ``jax.default_backend`` AND sets ``REPRO_FAKE_DEVICE_KIND``
    (the env override ``backends.device_kind`` reads first — setting it
    here also shadows any job-level value, e.g. the CI routing job's
    ``gpu``), scoped to the test by monkeypatch. The test scope also drops
    ``REPRO_FORCE_INTERPRET`` so interpret auto-detect assertions see the
    faked kind, not the CI pin.
    """

    def _set(kind: str):
        monkeypatch.setattr(jax, "default_backend", lambda: kind)
        monkeypatch.setenv("REPRO_FAKE_DEVICE_KIND", kind)
        monkeypatch.delenv("REPRO_FORCE_INTERPRET", raising=False)

    return _set


class FakeMesh:
    """Mesh-shaped duck type (axis_names / shape / devices) with regular
    object identity — real jax Meshes are interned, so two equal meshes
    built at different times are the SAME object and can't exercise
    identity-safe cache keying. Unhashable on purpose: an object-keyed
    cache would crash instead of silently retaining it."""

    axis_names = ("model",)
    shape = {"model": 1}

    __hash__ = None

    def __init__(self):
        self.devices = np.array(jax.devices()[:1])


@pytest.fixture
def fake_mesh():
    """Factory for distinct-but-equal fake meshes (see ``FakeMesh``)."""
    return FakeMesh


def require_devices(n: int) -> None:
    """Skip unless the process has >= n devices (shard-emulation jobs set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4``)."""
    if jax.device_count() < n:
        pytest.skip(
            f"needs >= {n} devices (have {jax.device_count()}); run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=4"
        )
