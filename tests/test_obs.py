"""``repro.obs`` — the unified metrics registry + span tracing (ISSUE 9).

Four layers of pins:

1. **Registry semantics** on private ``Registry()`` instances: the golden
   snapshot shape, the power-of-two histogram bucket edges (exactly
   representable, so equality — not tolerance — is the assertion), bucket
   boundary placement (``edges[i-1] < v <= edges[i]``), ``total``/``value``
   read-only semantics, percentile/diff helpers, and thread-safety under
   concurrent writers (the background flush worker's access pattern).
2. **Chrome-trace schema**: every exported event carries
   ``name/ph/ts/dur/pid/tid`` (instants add ``s='t'`` and ``dur=0``) and
   the whole object survives a JSON round-trip — the contract the CI
   tracing step validates against the real fast-split trace.
3. **Shim equivalence**: the legacy counters (``mutations_issued``,
   ``traces_counted``, ``lowerings_traced``) are thin reads over the
   registry, so their values and ``metrics.snapshot()`` cannot disagree —
   asserted over live traffic, not by construction alone.
4. **Serving integration**: the ISSUE 6 two-rung acceptance sequence emits
   ZERO ``repro.stream.retraces`` (the metric mirrors the retrace guard),
   a traced service run exports flush/drain/checkpoint spans, flush
   reports carry coalesce/mutate timings and widths, warmup records
   per-executable compile seconds, and the bandwidth model in
   ``backends.modeled_bytes_per_update`` is pinned against the kernel
   modules' own formulas so they cannot drift apart.
"""
import json
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import metrics, tracing
from repro.obs.metrics import (
    LATENCY_BUCKETS_S,
    WIDTH_BUCKETS,
    Registry,
    diff_snapshots,
    percentile_from,
)
from repro.stream import (
    FactorStore,
    StreamService,
    assert_no_retrace,
    checkpoint_service,
    restore_service,
    warmup_store,
)
from repro.stream import store as store_mod
from tests.strategies import gauss_rows as _rows


def _ladder_store(n=8, *, ladder=(2, 4), width=3, backend="reference",
                  **kw):
    return FactorStore(n, capacity=ladder[0], ladder=ladder, width=width,
                       panel=4, backend=backend, **kw)


# ---------------------------------------------------------------------------
# Registry: buckets, snapshot golden, semantics
# ---------------------------------------------------------------------------


def test_latency_bucket_edges_are_exact_powers_of_two():
    # 25 edges, 1us .. 2^24 us; power-of-two multiples of 1e-6 are exactly
    # representable (1e-6 rounds once, doubling is exact), so == holds.
    assert len(LATENCY_BUCKETS_S) == 25
    assert LATENCY_BUCKETS_S[0] == 1e-6
    for lo, hi in zip(LATENCY_BUCKETS_S, LATENCY_BUCKETS_S[1:]):
        assert hi == 2 * lo
    assert WIDTH_BUCKETS == tuple(float(2 ** i) for i in range(13))


def test_histogram_bucket_boundary_semantics():
    reg = Registry()
    h = reg.histogram("lat", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0):        # v <= edges[0] -> counts[0]
        h.observe(v)
    h.observe(1.5)              # edges[0] < v <= edges[1] -> counts[1]
    h.observe(2.0)              # boundary lands in its OWN bucket
    h.observe(9.0)              # overflow -> trailing slot
    snap = reg.snapshot()["histograms"]["lat"]
    assert snap["edges"] == [1.0, 2.0, 4.0]
    assert snap["counts"] == [2, 2, 0, 1]
    assert len(snap["counts"]) == len(snap["edges"]) + 1
    assert snap["count"] == 5
    assert snap["sum"] == pytest.approx(14.0)


def test_registry_snapshot_golden():
    reg = Registry()
    reg.counter("req", backend="fused", sign="up").inc(3)
    reg.counter("req", backend="fused", sign="down").inc()
    reg.gauge("depth").set(2.5)
    reg.histogram("lat", buckets=(1.0, 2.0)).observe(1.5)
    assert reg.snapshot() == {
        "counters": {"req{backend=fused,sign=down}": 1,
                     "req{backend=fused,sign=up}": 3},
        "gauges": {"depth": 2.5},
        "histograms": {"lat": {"count": 1, "sum": 1.5,
                               "edges": [1.0, 2.0],
                               "counts": [0, 1, 0]}},
    }


def test_label_keys_sorted_total_and_readonly_value():
    reg = Registry()
    # Label insertion order must not mint distinct series.
    reg.counter("c", b=2, a=1).inc()
    reg.counter("c", a=1, b=2).inc()
    assert reg.snapshot()["counters"] == {"c{a=1,b=2}": 2}
    assert reg.total("c") == 2
    # value() reads without creating; the missing series stays missing.
    assert reg.value("c", a=9) == 0
    assert reg.snapshot()["counters"] == {"c{a=1,b=2}": 2}
    # total() skips histograms (they have no scalar value to sum).
    reg.histogram("c", buckets=(1.0,), kind="h").observe(5.0)
    assert reg.total("c") == 2
    # A name+labels key is one series of ONE kind.
    with pytest.raises(TypeError):
        reg.gauge("c", a=1, b=2)


def test_percentile_from_and_diff_snapshots():
    reg = Registry()
    h = reg.histogram("lat", buckets=(1.0, 2.0, 4.0))
    for v in (0.5,) * 50 + (3.0,) * 49 + (100.0,):
        h.observe(v)
    assert h.percentile(50) == 1.0     # upper edge of the rank's bucket
    assert h.percentile(99) == 4.0
    assert h.percentile(100) == 4.0    # overflow reports the last edge
    assert np.isnan(percentile_from(
        {"count": 0, "edges": [1.0], "counts": [0, 0]}, 50))

    before = reg.snapshot()
    h.observe(0.5)
    reg.counter("c").inc(7)
    d = diff_snapshots(before, reg.snapshot())
    assert d["counters"]["c"] == 7              # absent-before passes through
    assert d["histograms"]["lat"]["count"] == 1
    assert d["histograms"]["lat"]["counts"][0] == 1
    assert sum(d["histograms"]["lat"]["counts"]) == 1
    with pytest.raises(ValueError):
        diff_snapshots(
            {"histograms": {"lat": {"count": 0, "sum": 0.0,
                                    "edges": [9.0], "counts": [0, 0]}}},
            reg.snapshot())


def test_registry_thread_safety_under_concurrent_writers():
    reg = Registry()
    N, M = 8, 500

    def hammer(i):
        for _ in range(M):
            reg.counter("hits", worker=i % 2).inc()
            reg.histogram("lat").observe(1e-6)
            reg.gauge("depth").add(1)

    threads = [threading.Thread(target=hammer, args=(i,)) for i in range(N)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert reg.total("hits") == N * M
    snap = reg.snapshot()
    assert snap["histograms"]["lat"]["count"] == N * M
    assert snap["gauges"]["depth"] == N * M


def test_export_jsonl_appends_parseable_records(tmp_path):
    reg = Registry()
    reg.counter("c").inc(2)
    path = tmp_path / "metrics.jsonl"
    reg.export_jsonl(path)
    reg.counter("c").inc()
    reg.export_jsonl(path)
    recs = [json.loads(line) for line in path.read_text().splitlines()]
    assert [r["counters"]["c"] for r in recs] == [2, 3]
    assert all("ts" in r for r in recs)


# ---------------------------------------------------------------------------
# Tracing: schema, decorator, export
# ---------------------------------------------------------------------------


def test_chrome_trace_event_schema():
    rec = tracing.SpanRecorder(capacity=16)
    with tracing.span("flush", recorder=rec, reason="force") as ev:
        ev.labels["mutations"] = 2
    tracing.instant("retrace", recorder=rec, steps=1)
    trace = tracing.chrome_trace(rec.events())
    events = trace["traceEvents"]
    assert [e["name"] for e in events] == ["flush", "retrace"]
    for e in events:
        for key in ("name", "ph", "ts", "dur", "pid", "tid"):
            assert key in e, f"event missing {key!r}: {e}"
    span_ev, inst = events
    assert span_ev["ph"] == "X" and span_ev["dur"] >= 0
    assert span_ev["args"] == {"reason": "force", "mutations": 2}
    assert inst["ph"] == "i" and inst["dur"] == 0 and inst["s"] == "t"
    # Non-JSON label values are stringified, never a serialization error.
    with tracing.span("odd", recorder=rec, shape=(2, 4)):
        pass
    json.dumps(tracing.chrome_trace(rec.events()))


def test_traced_decorator_and_ring_bound():
    rec = tracing.SpanRecorder(capacity=4)
    for i in range(10):
        with tracing.span("s", recorder=rec, i=i):
            pass
    assert len(rec) == 4                      # ring: oldest spans dropped
    assert [e.labels["i"] for e in rec.events()] == [6, 7, 8, 9]

    before = len(tracing.RECORDER)

    @tracing.traced()
    def add(a, b):
        return a + b

    assert add(2, 3) == 5
    events = tracing.RECORDER.events()
    assert len(events) == before + 1
    assert events[-1].name.endswith("add")


def test_export_chrome_trace_writes_valid_json(tmp_path):
    rec = tracing.SpanRecorder()
    with tracing.span("checkpoint", recorder=rec, step=1):
        pass
    path = tmp_path / "trace.json"
    tracing.export_chrome_trace(path, rec.events())
    trace = json.loads(path.read_text())
    assert trace["otherData"]["producer"] == "repro.obs"
    assert trace["traceEvents"][0]["name"] == "checkpoint"


# ---------------------------------------------------------------------------
# Shim equivalence + the bandwidth-model pin
# ---------------------------------------------------------------------------


def test_legacy_shims_equal_registry_totals():
    st = _ladder_store()
    svc = StreamService(st, auto_flush=False)
    svc.admit("a")
    for v in _rows(8, 3, seed=1):
        svc.push("a", v)
    svc.push("a", (0.5 * _rows(8, 1, seed=1)[0]).astype(np.float32),
             sign=-1)
    svc.flush(force=True)
    # The shims ARE registry reads — assert it over real traffic anyway,
    # so a future rewrite of either side cannot silently diverge.
    assert store_mod.mutations_issued() == int(
        metrics.total("repro.stream.mutations"))
    assert store_mod.traces_counted() == int(
        metrics.total("repro.stream.step_traces"))
    snap = metrics.snapshot()["counters"]
    assert store_mod.mutations_issued() == sum(
        v for k, v in snap.items()
        if k.startswith("repro.stream.mutations"))


def test_kernel_launch_shims_equal_registry():
    from repro.kernels import blocktridiag as btd_k
    from repro.kernels import fused as fused_k
    from repro.kernels import sharded as sharded_k

    low = fused_k.lowerings_traced()
    assert low["portable"] == int(metrics.value(
        "repro.kernels.launches", module="fused", lowering="portable"))
    assert low["mosaic"] == int(metrics.value(
        "repro.kernels.launches", module="fused", lowering="mosaic"))
    assert sharded_k.launches_traced() == sum(
        int(metrics.value("repro.kernels.launches", module="sharded",
                          lowering=lw))
        for lw in ("portable", "mosaic"))
    assert btd_k.launches_traced() == int(metrics.value(
        "repro.kernels.launches", module="blocktridiag"))
    # Drive a fused launch and watch BOTH views move together.
    before = fused_k.lowerings_traced()
    L = jnp.eye(8, dtype=jnp.float32)
    V = 0.1 * jnp.ones((8, 2), jnp.float32)
    from repro.core import backends
    backends.dispatch(L, V, sigma=1.0, method="fused", panel=4,
                      interpret=True)
    after = fused_k.lowerings_traced()
    assert sum(after.values()) == sum(before.values()) + 1
    assert after["portable"] == int(metrics.value(
        "repro.kernels.launches", module="fused", lowering="portable"))


def test_modeled_bytes_pins_kernel_formulas():
    from repro.core import backends
    from repro.kernels import blocktridiag as btd_k
    from repro.kernels import fused as fused_k

    for n, panel, k in ((64, 16, 8), (96, 32, 16), (33, 8, 1)):
        for dt in (jnp.float32, jnp.bfloat16):
            assert backends.modeled_bytes_per_update(
                structure="dense", n=n, panel=panel, k=k,
                storage_dtype=dt) == fused_k.bytes_per_update(
                    n, panel, k, storage_dtype=dt)
    for nb, b, k in ((5, 4, 3), (12, 8, 16)):
        assert backends.modeled_bytes_per_update(
            structure="blocktridiag", n=nb * b, panel=b, k=k,
            storage_dtype=jnp.float32, nblocks=nb,
            block=b) == btd_k.bytes_per_update(
                nb, b, k, storage_dtype=jnp.float32)


def test_dispatch_records_resolve_and_bytes_counters():
    from repro.core import backends

    before = metrics.snapshot()
    L = jnp.eye(8, dtype=jnp.float32)
    V = 0.1 * jnp.ones((8, 2), jnp.float32)
    backends.dispatch(L, V, sigma=-1.0, method="reference", panel=4,
                      interpret=True)
    d = diff_snapshots(before, metrics.snapshot())["counters"]
    key = ("repro.backends.resolve{backend=reference,dtype=float32,"
           "lowering=none,method=reference,sign=down,structure=dense}")
    assert d.get(key) == 1
    bkey = ("repro.backends.bytes{backend=reference,dtype=float32,"
            "lowering=none,sign=down,structure=dense}")
    assert d.get(bkey) == backends.modeled_bytes_per_update(
        structure="dense", n=8, panel=4, k=2, storage_dtype=jnp.float32)


# ---------------------------------------------------------------------------
# Serving integration: retrace pin, spans, timings, warmup compile times
# ---------------------------------------------------------------------------


def test_two_rung_sequence_emits_zero_retrace_metric(tmp_path):
    """ISSUE 9 regression pin: the metric mirror of the ISSUE 6 retrace
    guard — a warmed two-rung admit/flush/evict/readmit/checkpoint/
    restore/flush sequence bumps ``repro.stream.retraces`` by ZERO (and
    records no ``stream.retrace`` instant events)."""
    n, width = 8, 3
    st = _ladder_store(n, ladder=(2, 4), width=width)
    svc = StreamService(st, auto_flush=False)
    warmup_store(st)

    retraces0 = metrics.total("repro.stream.retraces")
    instants0 = sum(1 for e in tracing.RECORDER.events()
                    if e.name == "stream.retrace")
    rows = {u: np.stack(_rows(n, width, seed=40 + i, scale=0.2))
            for i, u in enumerate("abcd")}
    with assert_no_retrace("obs two-rung sequence"):
        svc.admit("a")
        svc.admit("b")
        for u in ("a", "b"):
            for v in rows[u]:
                svc.push(u, v)
        svc.flush(force=True)
        svc.evict("b")
        svc.admit("c")
        svc.admit("d")                       # ladder boundary: 2 -> 4
        for u in ("c", "d"):
            for v in rows[u]:
                svc.push(u, v)
        svc.push("a", (0.5 * rows["a"][0]).astype(np.float32), sign=-1)
        svc.flush(force=True)
        checkpoint_service(svc, tmp_path, step=1)
        survivor = restore_service(tmp_path, warm=True)
        survivor.flush(force=True)
    assert metrics.total("repro.stream.retraces") == retraces0
    assert sum(1 for e in tracing.RECORDER.events()
               if e.name == "stream.retrace") == instants0


def test_flush_report_carries_timings_and_widths():
    st = _ladder_store()
    svc = StreamService(st, auto_flush=False)
    svc.admit("a")
    svc.admit("b")
    for u in ("a", "b"):
        for v in _rows(8, 3, seed=7):
            svc.push(u, v)
    rep = svc.flush(force=True)
    assert not rep.empty
    assert rep.t_coalesce_s >= 0.0
    assert rep.t_mutate_s > 0.0
    assert rep.widths == (3,)                # one up block, width 3
    # The width observation landed in the histogram too.
    snap = metrics.snapshot()["histograms"]
    key = "repro.stream.coalesce_width{sign=up}"
    assert snap[key]["count"] >= 1
    assert snap[key]["edges"] == list(WIDTH_BUCKETS)
    # An empty flush reports zeroed timings and no widths...
    rep2 = svc.flush(force=True)
    assert rep2.empty and rep2.widths == ()
    # ...and is excluded from the latency histogram (percentiles would
    # otherwise be dominated by no-op sweeps).
    flush_counts = lambda: sum(
        h["count"] for k, h in metrics.snapshot()["histograms"].items()
        if k.startswith("repro.stream.flush_seconds"))
    before = flush_counts()
    svc.flush(force=True)
    assert flush_counts() == before


def test_warmup_records_per_executable_compile_seconds():
    store_mod._steps_for.cache_clear()        # force real AOT builds
    st = _ladder_store(ladder=(2,), width=2)
    rep = warmup_store(st)
    assert rep.compiled > 0
    assert set(rep.compile_seconds)           # per-step keys, e.g. 'both'
    assert all(not k.endswith("[sharded]") for k in rep.compile_seconds)
    assert all(v >= 0 for v in rep.compile_seconds.values())
    assert sum(rep.compile_seconds.values()) <= rep.seconds + 1e-6
    snap = metrics.snapshot()["histograms"]
    builds = {k: h for k, h in snap.items()
              if k.startswith("repro.stream.compile_seconds")}
    assert builds and all("sharded=0" in k or "sharded=1" in k
                          for k in builds)
    # Warm cache: a second walk compiles nothing and times nothing.
    rep2 = warmup_store(st)
    assert rep2.compiled == 0 and rep2.compile_seconds == {}
    # The warmup span recorded its compiled/cached split.
    ev = [e for e in tracing.RECORDER.events() if e.name == "stream.warmup"]
    assert ev and ev[-1].labels["cached"] == rep2.cached


def test_service_run_exports_flush_drain_checkpoint_spans(tmp_path):
    """ISSUE 9 acceptance: a StreamService session (background worker on)
    exports a valid Chrome trace containing flush/drain/checkpoint spans,
    with the worker's spans on their own tid."""
    st = _ladder_store()
    svc = StreamService(st, auto_flush=True, background=True)
    svc.admit("a")
    for v in _rows(8, 6, seed=11):
        svc.push("a", v)
    svc.drain()
    checkpoint_service(svc, tmp_path, step=1)
    svc.stop_background()

    path = tmp_path / "trace.json"
    tracing.export_chrome_trace(path)
    trace = json.loads(path.read_text())
    events = trace["traceEvents"]
    names = {e["name"] for e in events}
    assert {"stream.flush", "stream.drain", "stream.checkpoint"} <= names
    assert "stream.background_flush" in names
    for e in events:
        for key in ("name", "ph", "ts", "dur", "pid", "tid"):
            assert key in e
    producer_tids = {e["tid"] for e in events if e["name"] == "stream.drain"}
    worker_tids = {e["tid"] for e in events
                   if e["name"] == "stream.background_flush"}
    assert producer_tids and worker_tids
    assert producer_tids.isdisjoint(worker_tids)
    # Flush spans attached their outcome labels before closing.
    flush = [e for e in events if e["name"] == "stream.flush"][-1]
    assert {"reason", "mutations", "rounds", "empty"} <= set(flush["args"])
    # The queue-depth gauge exists (worker instrumentation ran).
    assert "repro.stream.queue_depth" in metrics.snapshot()["gauges"]


def test_wal_and_occupancy_metrics(tmp_path):
    st = _ladder_store(ladder=(2, 4))
    svc = StreamService(st, auto_flush=False)
    svc.admit("a")
    # checkpoint_service attaches the WAL; traffic after it is logged.
    checkpoint_service(svc, tmp_path, step=1)
    before = metrics.snapshot()
    svc.push("a", _rows(8, 1, seed=3)[0])
    d = diff_snapshots(before, metrics.snapshot())["counters"]
    assert d.get("repro.stream.wal_records{op=push}") == 1
    assert d.get("repro.stream.wal_bytes", 0) > 0
    g = metrics.snapshot()["gauges"]
    assert g["repro.stream.active"] == 1.0
    assert g["repro.stream.capacity"] == 2.0
    assert g["repro.stream.ladder_occupancy"] == 0.5
    # The checkpoint span was recorded with its step label.
    ckpts = [e for e in tracing.RECORDER.events()
             if e.name == "stream.checkpoint"]
    assert ckpts and ckpts[-1].labels["step"] == 1
