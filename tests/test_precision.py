"""Mixed-precision policy coverage (DESIGN.md §8).

The contract under test: with ``precision='bf16'`` the factor tiles and the
running ``V^T`` are *stored* in bfloat16 (halving the HBM bytes of this
bandwidth-bound problem) while the diagonal recurrence, the rotation state
``(c, s)``/``T``, GEMM accumulation, and the Murray tangent map all run in
fp32. Single updates must agree with the fp32 reference to bf16 rounding,
and — the acceptance criterion — hundreds of *sequential* updates must show
bounded drift: the fp32 recurrence keeps the error a random walk of
storage-rounding steps, O(sqrt(T) * eps_bf16), not a blow-up.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CholFactor,
    Precision,
    chol_update,
    chol_update_batched,
    chol_update_ref,
)
from repro.core import backends
from repro.kernels import fused as fused_k
from tests.strategies import make_problem

BF16_EPS = 2.0 ** -8  # bfloat16 machine epsilon (8 mantissa bits incl. implicit)

# Documented single-update tolerance: one update rounds each stored tile
# once, so elementwise error is O(eps_bf16 * |L|); relative Frobenius on the
# reconstructed A stays well under 32 * eps.
SINGLE_UPDATE_RTOL = 32 * BF16_EPS

# Documented sequential-drift tolerance (the acceptance criterion): T
# updates accumulate T independent storage roundings — a random walk,
# rel_frob(A) <~ C * sqrt(T) * eps_bf16. Measured 0.090 at T=200 (C ~ 0.8);
# asserted with C = 2 margin.
DRIFT_C = 2.0


def rel_frob(A, B):
    A = jnp.asarray(A, jnp.float32)
    B = jnp.asarray(B, jnp.float32)
    return float(jnp.linalg.norm(A - B) / jnp.linalg.norm(B))


# ---------------------------------------------------------------------------
# Policy object
# ---------------------------------------------------------------------------


def test_precision_parse_presets_and_dtypes():
    p = Precision.parse("bf16")
    assert p.storage == np.dtype(jnp.bfloat16)
    assert p.accum == np.dtype(np.float32)
    assert Precision.parse("bfloat16") == p  # canonical: presets dedupe
    f32 = Precision.parse("f32")
    assert f32.storage == np.dtype(np.float32) and f32.accum == np.dtype(np.float32)
    assert Precision.parse(None) is None
    assert Precision.parse(p) is p
    bare = Precision.parse(jnp.bfloat16)  # bare dtype: storage=that, accum=f32
    assert bare == p
    assert Precision.parse("f64").accum == np.dtype(np.float64)
    # Hashable (static aux / jit static arg requirement).
    assert hash(p) == hash(Precision(storage="bfloat16", accum="float32"))


def test_precision_validation_rejects_bad_policies():
    with pytest.raises(ValueError):
        Precision(storage="float32", accum="bfloat16")  # accum < fp32
    with pytest.raises(ValueError):
        Precision(storage="float64", accum="float32")   # storage > accum
    with pytest.raises(ValueError):
        Precision.parse("int32")                        # not floating
    with pytest.raises(ValueError):
        Precision.parse("not-a-dtype")


def test_precision_helpers():
    p = Precision.parse("bf16")
    x = jnp.ones((4, 4), jnp.float32)
    assert p.cast_storage(x).dtype == jnp.bfloat16
    assert p.up(p.cast_storage(x)).dtype == jnp.float32
    assert p.storage_for(jnp.float32) == np.dtype(jnp.bfloat16)
    assert p.bytes_per_element(jnp.float32) == 2
    none_storage = Precision(storage=None)
    assert none_storage.storage_for(jnp.float32) == np.dtype(np.float32)
    assert none_storage.cast_storage(x) is x


# ---------------------------------------------------------------------------
# Single update: every backend honors the split
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["reference", "paper", "gemm", "pallas",
                                    "pallas_gemm", "fused"])
@pytest.mark.parametrize("sigma", [1, -1])
def test_bf16_single_update_matches_fp32_reference(method, sigma):
    n, k = 96, 4
    L, V = make_problem(n, k, seed=n + k)
    if sigma == -1:
        # Downdate a factor that contains V V^T so the result stays PD.
        L = jnp.asarray(
            np.linalg.cholesky(np.asarray(L.T @ L + V @ V.T)).T, jnp.float32
        )
    ref = chol_update_ref(L, V, sigma=sigma)
    out = chol_update(L, V, sigma=sigma, method=method, panel=32,
                      interpret=True, precision="bf16")
    assert out.dtype == jnp.bfloat16  # the factor IS stored narrow
    err = rel_frob(out.astype(jnp.float32).T @ out.astype(jnp.float32),
                   ref.T @ ref)
    assert err < SINGLE_UPDATE_RTOL, f"{method}: rel={err:.4f}"


def test_bf16_fused_paper_panel_apply_matches_too():
    # The 'paper' element-wise rotation chain inside the fused kernel uses
    # the parked (c, s) scratch — which must be fp32 under the policy.
    n, k = 64, 3
    L, V = make_problem(n, k, seed=11)
    ref = chol_update_ref(L, V, sigma=1)
    out = fused_k.chol_update_fused(L, V, sigma=1, panel=16,
                                    panel_apply="paper", interpret=True,
                                    precision="bf16")
    assert out.dtype == jnp.bfloat16
    assert rel_frob(out.astype(jnp.float32).T @ out.astype(jnp.float32),
                    ref.T @ ref) < SINGLE_UPDATE_RTOL


def test_fp32_policy_explicit_equals_legacy_none():
    # precision='f32' must be numerically identical to the legacy no-policy
    # path on fp32 inputs (same dtypes everywhere, casts are no-ops).
    n, k = 64, 2
    L, V = make_problem(n, k, seed=3)
    a = chol_update(L, V, method="gemm", panel=32, precision="f32")
    b = chol_update(L, V, method="gemm", panel=32)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bf16_batched_and_factor_api():
    B, n, k = 3, 64, 2
    Ls, Vs = zip(*[make_problem(n, k, seed=100 + b) for b in range(B)])
    Lb, Vb = jnp.stack(Ls), jnp.stack(Vs)
    out = chol_update_batched(Lb, Vb, method="gemm", panel=32,
                              precision="bf16")
    assert out.dtype == jnp.bfloat16
    for b in range(B):
        ref = chol_update_ref(Ls[b], Vs[b], sigma=1)
        assert rel_frob(out[b].astype(jnp.float32).T @ out[b].astype(jnp.float32),
                        ref.T @ ref) < SINGLE_UPDATE_RTOL
    # Object API: policy rides as static aux through jit and mutations.
    f = CholFactor.from_factor(Ls[0], panel=32, backend="gemm",
                               precision="bf16")
    assert f.precision == Precision.parse("bf16")
    g = jax.jit(lambda fac, v: fac.update(v))(f, Vs[0])
    assert g.data.dtype == jnp.bfloat16
    assert g.precision == f.precision  # metadata rides
    assert bool(g.is_valid())


# ---------------------------------------------------------------------------
# The acceptance criterion: bounded drift over >= 200 sequential updates
# ---------------------------------------------------------------------------


def _drift(method, T, *, n=64, k=2, panel=32, interpret=None):
    rng = np.random.default_rng(7)
    L0 = jnp.asarray(np.linalg.cholesky(n * np.eye(n, dtype=np.float32)).T)
    Vs = jnp.asarray(rng.normal(size=(T, n, k)).astype(np.float32))

    def scan_with(precision, L_init):
        def step(L, V):
            return chol_update(L, V, method=method, panel=panel,
                               interpret=interpret, precision=precision), None
        return jax.jit(
            lambda L, Vs: jax.lax.scan(step, L, Vs)[0])(L_init, Vs)

    L_bf = scan_with("bf16", L0.astype(jnp.bfloat16))
    L_f32 = scan_with(None, L0)
    assert L_bf.dtype == jnp.bfloat16
    Lb32 = L_bf.astype(jnp.float32)
    return rel_frob(Lb32.T @ Lb32, L_f32.T @ L_f32)


def test_error_accumulation_200_sequential_updates_bounded():
    """>=200 sequential rank-k updates: bf16 storage drifts like a random
    walk of storage roundings, rel_frob(A) < 2 sqrt(T) eps_bf16 (measured
    0.090 at T=200; bound 0.221)."""
    T = 200
    drift = _drift("gemm", T)
    bound = DRIFT_C * np.sqrt(T) * BF16_EPS
    assert drift < bound, f"drift {drift:.4f} exceeds {bound:.4f}"
    # And it really is accumulation, not a single-step blow-up: a short
    # prefix must sit well inside the long-run bound.
    assert _drift("gemm", 20) < DRIFT_C * np.sqrt(20) * BF16_EPS


@pytest.mark.slow
def test_error_accumulation_fused_kernel_bounded():
    """Same harness through the fused Pallas kernel (interpret mode)."""
    T = 60
    drift = _drift("fused", T, panel=32, interpret=True)
    assert drift < DRIFT_C * np.sqrt(T) * BF16_EPS


# ---------------------------------------------------------------------------
# Bandwidth accounting: the point of the whole exercise
# ---------------------------------------------------------------------------


def test_bytes_per_update_halved_for_bf16_panels():
    n, panel, k = 4096, 256, 16
    b32 = fused_k.bytes_per_update(n, panel, k, storage_dtype=jnp.float32)
    b16 = fused_k.bytes_per_update(n, panel, k, storage_dtype=jnp.bfloat16)
    assert b16 * 2 == b32  # exactly half: every HBM operand is storage-typed
    # Sanity: the absolute number is the tile traffic the docstring claims.
    n_tiles = n // panel
    expected32 = (2 * (n_tiles * (n_tiles + 1) // 2) * panel * panel + k * n) * 4
    assert b32 == expected32


# ---------------------------------------------------------------------------
# Autodiff: tangents/cotangents stay fp32
# ---------------------------------------------------------------------------


def test_grad_through_bf16_update_is_fp32_and_matches_fp32_grad():
    n, k = 8, 2
    rng = np.random.default_rng(5)
    B = rng.normal(size=(n, n))
    L = jnp.asarray(np.linalg.cholesky(B.T @ B + n * np.eye(n)).T, jnp.float32)
    V = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)

    def loss(V, precision):
        out = chol_update(L, V, method="gemm", panel=4, precision=precision)
        return jnp.sum(out.astype(jnp.float32) ** 2)

    g_bf = jax.grad(lambda v: loss(v, "bf16"))(V)
    g_32 = jax.grad(lambda v: loss(v, None))(V)
    # Cotangents of fp32 inputs stay fp32 even though the primal factor is
    # stored bf16 (the Murray rule computes in fp32)...
    assert g_bf.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(g_bf)))
    # ...and only storage rounding separates the two gradients.
    assert rel_frob(g_bf, g_32) < SINGLE_UPDATE_RTOL


def test_jvp_tangent_dtype_follows_primal_out():
    n, k = 6, 2
    rng = np.random.default_rng(9)
    B = rng.normal(size=(n, n))
    L = jnp.asarray(np.linalg.cholesky(B.T @ B + n * np.eye(n)).T, jnp.float32)
    V = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)

    def f(L, V):
        return chol_update(L, V, method="reference", precision="bf16")

    out, tangent = jax.jvp(f, (L, V), (jnp.eye(n, dtype=jnp.float32) * 0.1,
                                       jnp.zeros_like(V)))
    # custom_jvp contract: tangent aval == primal-out aval (bf16 storage),
    # but computed via the fp32 path, so it is finite and non-trivial.
    assert out.dtype == jnp.bfloat16 and tangent.dtype == jnp.bfloat16
    assert bool(jnp.all(jnp.isfinite(tangent.astype(jnp.float32))))


# ---------------------------------------------------------------------------
# Sharded driver: psum-gathered diag blocks upcast before the chain phase
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_sharded_bf16_matches_reference():
    import os
    import subprocess
    import sys
    # Subprocess for the host-device-count flag, as in tests/test_distributed.
    code = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=2'
import numpy as np, jax.numpy as jnp
from repro.core import chol_update, chol_update_ref
from repro.runtime.compat import make_mesh_compat
rng = np.random.default_rng(0)
n, k = 64, 2
B = rng.uniform(size=(n, n)).astype(np.float32)
V = jnp.asarray(rng.uniform(size=(n, k)).astype(np.float32))
L = jnp.asarray(np.linalg.cholesky(B.T @ B + np.eye(n, dtype=np.float32)).T)
ref = chol_update_ref(L, V, sigma=1)
mesh = make_mesh_compat((2,), ('model',))
for strategy in ('fused', 'gemm', 'paper'):
    out = chol_update(L, V, method='sharded', mesh=mesh, panel=16,
                      interpret=True, precision='bf16', strategy=strategy)
    assert out.dtype == jnp.bfloat16, strategy
    o = out.astype(jnp.float32)
    rel = float(jnp.linalg.norm(o.T @ o - ref.T @ ref)
                / jnp.linalg.norm(ref.T @ ref))
    assert rel < 32 * 2.0 ** -8, (strategy, rel)
print('OK')
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert res.returncode == 0, res.stderr
    assert "OK" in res.stdout
