"""Optimizer substrate tests, including the paper-technique preconditioner."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.optim as optim


def quad_problem(seed=0, m=64, n=32, N=256, cond=1e3):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(N, m)).astype(np.float32) @ np.diag(
        np.logspace(0, -np.log10(cond), m)
    ).astype(np.float32)
    Wstar = rng.normal(size=(m, n)).astype(np.float32)
    Y = X @ Wstar

    def loss_fn(params):
        return 0.5 * jnp.mean(jnp.square(jnp.asarray(X) @ params["w"] - jnp.asarray(Y)))

    params = {"w": jnp.zeros((m, n), jnp.float32)}
    return loss_fn, params


def run_steps(opt, loss_fn, params, steps):
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        l, g = jax.value_and_grad(loss_fn)(params)
        upd, state = opt.update(g, state, params)
        return optim.apply_updates(params, upd), state, l

    l0 = None
    for i in range(steps):
        params, state, l = step(params, state)
        if l0 is None:
            l0 = float(l)
    return params, state, l0, float(l)


@pytest.mark.parametrize(
    "name,kw",
    [
        ("adamw", {}),
        ("sgd", {"momentum": 0.9}),
        ("cholesky_precond", {"rank": 8, "block_size": 64}),
        ("cholesky_precond", {"rank": 8, "block_size": 32, "window": 8}),
    ],
)
def test_optimizers_decrease_loss(name, kw):
    loss_fn, params = quad_problem()
    opt = optim.get_optimizer(name, 0.03, **kw)
    params, state, l0, l_end = run_steps(opt, loss_fn, params, 120)
    assert np.isfinite(l_end)
    assert l_end < 0.5 * l0, f"{name} failed to reduce loss: {l0} -> {l_end}"
    assert bool(optim.all_finite(params))


def test_cholesky_precond_factors_stay_valid():
    """Factors must remain upper-triangular with positive diagonal (PD stats)."""
    loss_fn, params = quad_problem(seed=3)
    opt = optim.get_optimizer(
        "cholesky_precond", 0.03, rank=4, block_size=32, window=4
    )
    _, state, _, _ = run_steps(opt, loss_fn, params, 30)
    c = state["factors"]["w"]["c"].data  # the maintained CholFactor's array
    assert bool(jnp.all(jnp.stack([jnp.all(jnp.diagonal(ci) > 0) for ci in c])))
    for ci in c:
        assert float(jnp.max(jnp.abs(jnp.tril(ci, -1)))) < 1e-5


def test_cholesky_precond_window_tracks_recent_stats():
    """With a window, statistics from old sketches must be evicted: the factor
    built over a window of W steps equals (decay-scaled) eps*I + last-W sketches."""
    rng = np.random.default_rng(0)
    d, other, k, W = 16, 32, 4, 4  # m <= n -> left side, factor over d=16
    opt = optim.get_optimizer(
        "cholesky_precond", 0.01, rank=k, block_size=d, window=W, beta=1.0, eps=1e-2
    )
    params = {"w": jnp.zeros((d, other), jnp.float32)}
    g_seq = [jnp.asarray(rng.normal(size=(d, other)), jnp.float32) for _ in range(8)]
    state = opt.init(params)
    for g in g_seq:
        _, state = opt.update({"w": g}, state, params)
    C = state["factors"]["w"]["c"].data[0]
    A = C.T @ C
    # Ring buffer holds exactly the last W sketches.
    ring = state["factors"]["w"]["ring"]
    A_expected = 1e-2 * jnp.eye(d) + sum(ring[i] @ ring[i].T for i in range(W))
    np.testing.assert_allclose(np.asarray(A), np.asarray(A_expected), rtol=2e-3, atol=2e-4)


def test_cholesky_precond_fused_backend_in_training():
    """The maintained CholFactor routes through the registry: the fused
    single-launch kernel (interpret mode here) runs inside the training
    step, matching the reference backend's statistics."""
    rng = np.random.default_rng(7)
    d, other, k = 32, 48, 4
    params = {"w": jnp.zeros((d, other), jnp.float32)}
    grads = {"w": jnp.asarray(rng.normal(size=(d, other)), jnp.float32)}
    outs = {}
    for backend in ("fused", "reference"):
        opt = optim.get_optimizer(
            "cholesky_precond", 0.01, rank=k, block_size=d,
            update_method=backend,
        )
        state = opt.init(params)
        fac = state["factors"]["w"]["c"]
        assert fac.backend == backend
        for _ in range(2):
            upd, state = opt.update(grads, state, params)
        outs[backend] = (upd["w"], state["factors"]["w"]["c"].data)
    np.testing.assert_allclose(outs["fused"][0], outs["reference"][0],
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(outs["fused"][1], outs["reference"][1],
                               rtol=1e-4, atol=1e-4)


def test_adamw_bf16_state_dtype():
    loss_fn, params = quad_problem(seed=1)
    opt = optim.adamw(0.01, state_dtype=jnp.bfloat16)
    params, state, l0, l_end = run_steps(opt, loss_fn, params, 60)
    assert state["m"]["w"].dtype == jnp.bfloat16
    assert l_end < l0


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = optim.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(10.0)
    assert float(optim.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_schedules():
    s = optim.warmup_cosine(1.0, warmup_steps=10, total_steps=100, floor=0.1)
    assert float(s(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(s(jnp.asarray(10))) == pytest.approx(1.0, rel=1e-5)
    assert float(s(jnp.asarray(100))) == pytest.approx(0.1, rel=1e-5)
    inv = optim.inverse_sqrt(1.0, warmup_steps=100)
    assert float(inv(jnp.asarray(400))) == pytest.approx(0.5)


def test_get_optimizer_unknown():
    with pytest.raises(ValueError):
        optim.get_optimizer("adagrad", 0.1)
