"""Optional-import shim for ``hypothesis`` (property-based tests).

The tier-1 suite must collect and run whether or not ``hypothesis`` is
installed (it is pinned in ``requirements-dev.txt`` but absent from the bare
runtime image). When it is available this module re-exports the real
``given`` / ``settings`` / ``strategies``; when it is not, the decorators
degrade into a zero-argument pytest skip with a clear marker, so the
property-based tests show up as skipped instead of killing collection.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False
    _SKIP_REASON = (
        "hypothesis not installed — property-based test skipped "
        "(pip install -r requirements-dev.txt)"
    )

    def given(*_args, **_kwargs):
        def decorate(fn):
            # Plain zero-arg stand-in (not functools.wraps: pytest follows
            # __wrapped__ and would demand fixtures for the strategy params).
            def shim():
                pytest.skip(_SKIP_REASON)

            shim.__name__ = fn.__name__
            shim.__doc__ = fn.__doc__
            shim.pytestmark = list(getattr(fn, "pytestmark", []))
            return shim

        return decorate

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _StrategyStub:
        """Accepts any strategy constructor call and returns a placeholder."""

        def __getattr__(self, name):
            def strategy(*_args, **_kwargs):
                return None

            return strategy

    st = _StrategyStub()
