"""CholFactor engine + backend registry + Murray autodiff coverage.

The object-API contract the refactor introduces (DESIGN.md §7): one
stateful factor, every mutation through the registry, differentiable
end-to-end. Coverage demanded by the issue: property-based update/downdate
round-trip, ``downdate_feasible`` guarding, backend-registry dispatch, and
gradcheck of the custom derivative rules against finite differences.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests.hypothesis_compat import given, settings, st

from repro.core import (
    CholFactor,
    backends,
    chol_downdate_batched,
    chol_update,
    chol_update_ref,
    resolve_backend_for,
)
from tests.strategies import make_problem, tol_for


# ---------------------------------------------------------------------------
# Object API
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["reference", "gemm", "fused"])
def test_factor_update_matches_reference(backend):
    n, k = 96, 4
    L, V = make_problem(n, k, seed=n + k)
    f = CholFactor.from_factor(L, panel=32, backend=backend, interpret=True)
    out = f.update(V)
    assert isinstance(out, CholFactor)
    assert out.panel == f.panel and out.backend == backend  # metadata rides
    np.testing.assert_allclose(
        out.data, chol_update_ref(L, V, sigma=1), atol=tol_for(jnp.float32, n)
    )


def test_factor_from_matrix_solve_logdet():
    n, k = 64, 3
    L, V = make_problem(n, k, seed=5)
    A = L.T @ L
    f = CholFactor.from_matrix(A, panel=32)
    np.testing.assert_allclose(f.data, L, atol=1e-3)
    f2 = f.update(V)
    b = jnp.arange(n, dtype=jnp.float32)
    x = f2.solve(b)
    resid = jnp.max(jnp.abs((A + V @ V.T) @ x - b))
    assert float(resid) < 1e-2
    ld = float(f2.logdet())
    ld_exact = float(jnp.linalg.slogdet(A + V @ V.T)[1])
    assert abs(ld - ld_exact) < 1e-2
    assert bool(f2.is_valid())


def test_factor_identity_and_scale():
    f = CholFactor.identity(8, scale=4.0)
    np.testing.assert_allclose(f.matrix(), 4.0 * jnp.eye(8), atol=1e-6)
    g = f.scale(0.5)  # factor of (0.5)^2 * A
    np.testing.assert_allclose(g.matrix(), jnp.eye(8), atol=1e-6)


def test_factor_scale_uses_magnitude():
    # Regression: scale(alpha) represents alpha^2 * A, so only |alpha|
    # matters — a raw negative multiplier used to flip the diagonal sign and
    # produce a factor is_valid() then flags downstream.
    f = CholFactor.identity(8, scale=4.0)
    neg = f.scale(-0.5)
    np.testing.assert_allclose(neg.data, f.scale(0.5).data, atol=0)
    assert bool(neg.is_valid())
    assert float(neg.data[0, 0]) > 0
    # jit/traced alpha too (the optimizer's decay path).
    traced = jax.jit(lambda fac, a: fac.scale(a))(f, jnp.float32(-0.5))
    np.testing.assert_allclose(traced.data, neg.data, atol=0)


def test_factor_downdate_guarded():
    n, k = 48, 2
    L, V = make_problem(n, k, seed=9)
    f = CholFactor.from_factor(L, panel=16, backend="reference")
    # Feasible: downdating something the factor contains.
    f_up = f.update(V)
    guarded, ok = f_up.downdate_guarded(V)
    assert bool(ok)
    np.testing.assert_allclose(guarded.data, f.data, atol=tol_for(jnp.float32, n))
    # Infeasible: the guard must refuse and return the factor unchanged.
    guarded2, ok2 = f.downdate_guarded(100.0 * V)
    assert not bool(ok2)
    np.testing.assert_allclose(guarded2.data, f.data, atol=0)


def test_factor_batched_ops_and_guard():
    B, n, k = 3, 64, 4
    Ls, Vs = zip(*[make_problem(n, k, seed=300 + b) for b in range(B)])
    f = CholFactor(jnp.stack(Ls), panel=32, backend="gemm")
    assert f.batched
    out = f.update(jnp.stack(Vs))
    for b in range(B):
        np.testing.assert_allclose(
            out.data[b], chol_update_ref(Ls[b], Vs[b], sigma=1),
            atol=tol_for(jnp.float32, n),
        )
    # Per-element guarding: one feasible, one not.
    Vmix = jnp.stack([Vs[0], 100.0 * Vs[1], Vs[2]])
    guarded, ok = out.downdate_guarded(Vmix)
    assert ok.shape == (B,)
    assert bool(ok[0]) and not bool(ok[1]) and bool(ok[2])
    np.testing.assert_allclose(guarded.data[1], out.data[1], atol=0)
    np.testing.assert_allclose(
        guarded.data[0], Ls[0], atol=tol_for(jnp.float32, n)
    )
    # Batched solve + logdet shapes.
    bs = jnp.ones((B, n))
    assert out.solve(bs).shape == (B, n)
    assert out.logdet().shape == (B,)


def test_factor_is_a_pytree_through_jit_and_scan():
    n, k = 48, 2
    L, V = make_problem(n, k, seed=21)
    f = CholFactor.from_factor(L, panel=16, backend="reference")

    @jax.jit
    def roundtrip(fac, V):
        return fac.update(V).downdate(V)

    out = roundtrip(f, V)
    assert out.backend == "reference" and out.panel == 16
    np.testing.assert_allclose(out.data, L, atol=tol_for(jnp.float32, n))

    def step(fac, v):  # factor as scan carry: the streaming consumer shape
        return fac.update(v[:, None]), fac.logdet()

    fac_end, lds = jax.lax.scan(step, f, jnp.stack([V[:, 0], V[:, 1]]))
    assert lds.shape == (2,)
    two = f.update(V[:, :1]).update(V[:, 1:2])
    np.testing.assert_allclose(fac_end.data, two.data, atol=1e-4)


def test_chol_downdate_batched_mirrors_update():
    B, n, k = 2, 48, 3
    Ls, Vs = zip(*[make_problem(n, k, seed=40 + b) for b in range(B)])
    Lb, Vb = jnp.stack(Ls), jnp.stack(Vs)
    up = jax.vmap(lambda l, v: chol_update_ref(l, v, sigma=1))(Lb, Vb)
    back = chol_downdate_batched(up, Vb, method="gemm", panel=16)
    np.testing.assert_allclose(back, Lb, atol=tol_for(jnp.float32, n) * 4)


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------


def test_registry_names_and_errors():
    assert set(backends.names()) >= {
        "reference", "paper", "gemm", "pallas", "pallas_gemm", "fused",
        "sharded",
    }
    assert backends.methods() == backends.names() + ("auto",)
    with pytest.raises(ValueError):
        backends.get("nope")
    with pytest.raises(ValueError):
        backends.resolve("nope", n=64)
    # sharded without a mesh must fail loudly through the public API
    L, V = make_problem(16, 1, seed=1)
    with pytest.raises(ValueError):
        chol_update(L, V, method="sharded")


def test_auto_heuristic_prefers_fused_on_pallas_capable_targets():
    # Device-kind routing (the satellite fix: auto used to never pick fused).
    assert backends.resolve("auto", n=4096, device_kind="tpu") == "fused"
    assert backends.resolve("auto", n=64, device_kind="tpu") == "fused"
    assert backends.resolve("auto", n=64, interpret=True) == "fused"
    # CPU fallbacks: oracle under two panels, GEMM beyond.
    assert backends.resolve("auto", n=64, device_kind="cpu") == "reference"
    assert backends.resolve("auto", n=4096, device_kind="cpu") == "gemm"
    # Explicit names pass through untouched.
    assert backends.resolve("paper", n=8) == "paper"


def test_auto_heuristic_recognizes_gpu():
    # Regression: 'auto' treated TPU as the only Pallas-capable device, so
    # on GPU — the paper's actual target hardware — it silently fell back
    # to the jnp gemm path and never launched a kernel. Since the portable
    # lowering (DESIGN.md §5.1) GPU routes to the FUSED kernel too: the
    # single-launch chain walk compiles under Triton via the carry-style
    # portable lowering, so pallas_gemm is no longer the GPU ceiling.
    for kind in ("gpu", "cuda", "rocm", "GPU"):
        name = backends.resolve("auto", n=4096, device_kind=kind)
        assert backends.get(name).kind == "pallas", (kind, name)
        assert name == "fused"
        assert backends.resolve_lowering("auto", device_kind=kind) == \
            "portable"
    assert backends.resolve("auto", n=64, device_kind="gpu") == "fused"
    # The interpret auto-detect agrees: the auto lowering compiles wherever
    # the device kind is Pallas-capable (mosaic on TPU, portable on GPU) —
    # one shared policy, not three copies.
    assert backends.default_interpret() == (
        jax.default_backend().lower() not in backends.PALLAS_DEVICE_KINDS)
    assert backends.default_interpret(mosaic_only=True) == (
        jax.default_backend() != "tpu")


def test_batched_path_resolves_through_the_same_heuristic(monkeypatch):
    # Regression: chol_update_batched hard-defaulted to method='fused',
    # bypassing the device-kind heuristic the single-factor path uses. Both
    # must funnel through backends.resolve — and the batched path resolves
    # once per batch, not once per vmapped element.
    from repro.core import api

    calls = []
    real_resolve = backends.resolve

    def spy(method, **kw):
        calls.append(method)
        return real_resolve(method, **kw)

    monkeypatch.setattr(backends, "resolve", spy)
    api._impl_cache.clear()
    n, k, B = 64, 2, 3
    Ls, Vs = zip(*[make_problem(n, k, seed=600 + b) for b in range(B)])
    out = api.chol_update_batched(jnp.stack(Ls), jnp.stack(Vs), panel=16)
    assert out.shape == (B, n, n)
    # First call is the per-batch 'auto' resolution; inside the vmap the
    # method is already concrete (never 'auto' again).
    assert calls[0] == "auto"
    assert all(m != "auto" for m in calls[1:])
    # And the resolved name matches what the single-factor path picks.
    expected = real_resolve("auto", n=n, panel=16, interpret=None)
    np.testing.assert_allclose(
        out[0], chol_update(Ls[0], Vs[0], method=expected, panel=16),
        atol=tol_for(jnp.float32, n),
    )


def test_impl_cache_is_bounded_and_keys_meshes_by_metadata(fake_mesh):
    from repro.core import api

    api._impl_cache.clear()
    # Bounded: cycling through many configurations must not grow without
    # limit (the old unbounded lru_cache leaked every distinct opts tuple).
    for i in range(api._IMPL_CACHE_MAX + 40):
        api._cached_impl("gemm", 16 + i, None, None, {})
    assert api.impl_cache_len() <= api._IMPL_CACHE_MAX

    # Mesh-valued opts key by identity-safe metadata: two equal meshes built
    # at different times share ONE entry (no per-object retention). Real
    # jax Meshes are interned, so the shared FakeMesh duck type (conftest)
    # forces distinct objects with equal metadata — the serving-process
    # leak scenario.
    api._impl_cache.clear()
    mesh_a, mesh_b = fake_mesh(), fake_mesh()
    assert mesh_a is not mesh_b
    impl_a = api._cached_impl("sharded", 16, None, None, {"mesh": mesh_a})
    impl_b = api._cached_impl("sharded", 16, None, None, {"mesh": mesh_b})
    assert impl_a is impl_b
    assert api.impl_cache_len() == 1
    api._impl_cache.clear()


def test_registry_dispatch_agrees_across_backends():
    n, k = 80, 4
    L, V = make_problem(n, k, seed=77)
    ref_out = chol_update_ref(L, V, sigma=1)
    for name in ("reference", "paper", "gemm", "pallas", "pallas_gemm",
                 "fused"):
        out = backends.get(name)(L, V, sigma=1, panel=16, interpret=True)
        np.testing.assert_allclose(
            out, ref_out, atol=tol_for(jnp.float32, n),
            err_msg=f"backend {name} diverges",
        )


def test_resolve_backend_for_factor():
    f = CholFactor.identity(32, backend="auto", panel=256)
    assert resolve_backend_for(f) == backends.resolve("auto", n=32, panel=256)
    g = f.with_backend("fused")
    assert resolve_backend_for(g) == "fused"


# ---------------------------------------------------------------------------
# Mixed-dtype inputs: pinned behaviour, per backend
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["reference", "paper", "gemm", "pallas",
                                     "pallas_gemm", "fused"])
def test_mixed_dtype_V_is_cast_to_factor_dtype(backend):
    # Pinned: update(V) with V.dtype != L.dtype casts V to the FACTOR's
    # dtype before dispatch, on every backend — the maintained factor is
    # never silently promoted (and never silently demoted) by a caller
    # handing in a differently-typed modification.
    n, k = 64, 2
    L, V = make_problem(n, k, seed=88)
    ref = chol_update_ref(L, V, sigma=1)
    for vdtype in (jnp.bfloat16, jnp.float16):
        Vm = V.astype(vdtype)
        out = chol_update(L, Vm, method=backend, panel=16, interpret=True)
        assert out.dtype == L.dtype, (backend, vdtype)
        # Accuracy: only V's quantization separates it from the oracle.
        np.testing.assert_allclose(
            out, chol_update_ref(L, Vm.astype(L.dtype), sigma=1),
            atol=tol_for(jnp.float32, n),
        )
        assert float(jnp.max(jnp.abs(out - ref))) < 0.1  # V rounding only
    # The object API pins the same contract.
    f = CholFactor.from_factor(L, panel=16, backend=backend, interpret=True)
    out_f = f.update(V.astype(jnp.bfloat16))
    assert out_f.dtype == L.dtype


def test_mixed_dtype_bf16_factor_fp32_V():
    # The other direction: a bf16-stored factor receiving an fp32 V keeps
    # its own (narrow) dtype.
    n, k = 48, 2
    L, V = make_problem(n, k, seed=13)
    f = CholFactor.from_factor(L.astype(jnp.bfloat16), panel=16,
                               backend="gemm", precision="bf16")
    out = f.update(V)  # V is fp32
    assert out.dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# Murray derivative rules (custom JVP/VJP)
# ---------------------------------------------------------------------------


def _small_problem(n, k, seed):
    rng = np.random.default_rng(seed)
    B = rng.normal(size=(n, n))
    A = B.T @ B + n * np.eye(n)
    L = jnp.asarray(np.linalg.cholesky(A).T, jnp.float32)
    V = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    return L, V


@pytest.mark.parametrize("method", ["reference", "fused"])
@pytest.mark.parametrize("sigma", [1, -1])
def test_gradcheck_vs_finite_differences(method, sigma):
    """jax.grad through chol_update (any backend, incl. the Pallas kernel)
    must match central finite differences — Murray's rules, not AD of the
    recurrence."""
    n, k = 6, 2
    L, V = _small_problem(n, k, seed=3)
    if sigma == -1:
        L = jnp.asarray(
            np.linalg.cholesky(np.asarray(L.T @ L + V @ V.T)).T, jnp.float32
        )

    def loss(L, V):
        out = chol_update(L, V, sigma=sigma, method=method, panel=4,
                          interpret=True)
        return jnp.sum(jnp.sin(out) * jnp.cos(0.5 * out))

    gL, gV = jax.grad(loss, argnums=(0, 1))(L, V)
    eps = 1e-3
    for (arr, grad, idx) in [(L, gL, (1, 3)), (L, gL, (2, 2)),
                             (V, gV, (0, 1)), (V, gV, (4, 0))]:
        e = jnp.zeros_like(arr).at[idx].set(eps)
        if arr is L:
            fd = (loss(L + e, V) - loss(L - e, V)) / (2 * eps)
        else:
            fd = (loss(L, V + e) - loss(L, V - e)) / (2 * eps)
        assert float(abs(fd - grad[idx])) < 5e-2, (
            f"{'L' if arr is L else 'V'}{idx}: fd={float(fd):.4f} "
            f"analytic={float(grad[idx]):.4f}"
        )


def test_jvp_matches_directional_finite_difference():
    n, k = 5, 2
    L, V = _small_problem(n, k, seed=11)
    dL = jnp.triu(jnp.ones((n, n))) * 0.3
    dV = 0.2 * jnp.ones((n, k))

    def f(L, V):
        return chol_update(L, V, sigma=1, method="reference")

    _, tangent = jax.jvp(f, (L, V), (dL, dV))
    eps = 1e-3
    fd = (f(L + eps * dL, V + eps * dV) - f(L - eps * dL, V - eps * dV)) / (
        2 * eps
    )
    np.testing.assert_allclose(tangent, fd, atol=5e-2)


def test_grad_through_factor_update_and_solve():
    """The optimizer shape: grad of a solve against an updated factor."""
    n, k = 8, 2
    L, V = _small_problem(n, k, seed=19)
    b = jnp.ones((n,))

    def loss(V):
        f = CholFactor.from_factor(L, backend="reference")
        return jnp.sum(f.update(V).solve(b) ** 2)

    g = jax.grad(loss)(V)
    assert g.shape == V.shape
    eps = 1e-3
    e = jnp.zeros_like(V).at[3, 1].set(eps)
    fd = (loss(V + e) - loss(V - e)) / (2 * eps)
    assert float(abs(fd - g[3, 1])) < 5e-2


# ---------------------------------------------------------------------------
# Property-based round trip
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=4, max_value=48),
    k=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_property_factor_roundtrip(n, k, seed):
    """update(V) then downdate(V) recovers the factor to tolerance, through
    the object API (the paper's reversibility claim as an invariant)."""
    L, V = make_problem(n, k, seed=seed)
    f = CholFactor.from_factor(L, panel=16, backend="reference")
    back = f.update(V).downdate(V)
    np.testing.assert_allclose(
        back.data, f.data, atol=4 * tol_for(jnp.float32, n)
    )
    assert bool(back.is_valid())
