#!/usr/bin/env bash
# Perf-trajectory snapshot (ISSUE 2 CI satellite): run the benchmark suite
# and APPEND one JSON record per run to benchmarks/results/BENCH_cholupdate.json
# so future PRs can diff their numbers against every predecessor.
#
#   scripts/bench.sh              # quick sizes (CI-friendly)
#   scripts/bench.sh --full       # paper-scale sizes
#   scripts/bench.sh --only cholupdate,kernels,stream
#   scripts/bench.sh --dtype float32,bfloat16   # storage-dtype axis
#                                 # (the default: per-dtype rows with
#                                 # bytes-per-update land in the snapshot)
#
# The stream suite (coalesce-width sweep, DESIGN.md §9) appends to its own
# trajectory file benchmarks/results/BENCH_stream.json, the distributed
# suite (device-scaling + sharded-fleet axis, DESIGN.md §10) to
# BENCH_distributed.json, and the blocktridiag suite (block-size sweep:
# structured bytes-per-update vs the dense fused kernel at matched n,
# DESIGN.md §12 — `--only blocktridiag`) to BENCH_blocktridiag.json;
# everything else shares BENCH_cholupdate.json. Render all of them with
# `python -m benchmarks.report`.
#
# Every record carries platform / device_kind / lowering (ISSUE 7): which
# jax backend ran it, on what accelerator, and which fused-kernel lowering
# resolve('auto') picked there (mosaic on TPU, portable/Triton on GPU).
# Rows additionally tag interpret=0|1 and their own lowering= where they
# pin one — compare trajectories only where these match.
#
# Observability (DESIGN.md §13): every record embeds the run's
# repro.obs registry snapshot (obs= field) — report.py renders flush
# p50/p99, retraces, and ladder occupancy from it. To ALSO capture a
# Chrome trace / metrics dump of the run itself, set the exit toggles:
#
#   REPRO_OBS_TRACE=trace.json scripts/bench.sh --only stream
#       # writes the span ring (flush/drain/checkpoint/warmup spans) as
#       # Chrome trace_event JSON at exit — open in chrome://tracing or
#       # ui.perfetto.dev
#   REPRO_OBS_METRICS=metrics.json scripts/bench.sh
#       # writes the full metrics snapshot (counters/gauges/histograms)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m benchmarks.snapshot "$@"
