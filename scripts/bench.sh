#!/usr/bin/env bash
# Perf-trajectory snapshot (ISSUE 2 CI satellite): run the benchmark suite
# and APPEND one JSON record per run to benchmarks/results/BENCH_cholupdate.json
# so future PRs can diff their numbers against every predecessor.
#
#   scripts/bench.sh              # quick sizes (CI-friendly)
#   scripts/bench.sh --full       # paper-scale sizes
#   scripts/bench.sh --only cholupdate,kernels
#   scripts/bench.sh --dtype float32,bfloat16   # storage-dtype axis
#                                 # (the default: per-dtype rows with
#                                 # bytes-per-update land in the snapshot)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m benchmarks.snapshot "$@"
