#!/usr/bin/env bash
# One-step reproducible tier-1 test run (ROADMAP.md "Tier-1 verify").
#
#   scripts/test.sh            # run the full suite
#   scripts/test.sh --fast     # tier-1 fast split: skips @pytest.mark.slow
#                              # (multi-device subprocesses, large-n sweeps)
#   scripts/test.sh -k fused   # extra args forwarded to pytest
#
# Installs dev deps (hypothesis etc.) when pip is available and the
# environment permits; the suite still runs — with property-based tests
# skipped — when it isn't (tests/hypothesis_compat.py).
set -euo pipefail
cd "$(dirname "$0")/.."

FAST_ARGS=()
if [[ "${1:-}" == "--fast" ]]; then
    shift
    FAST_ARGS=(-m "not slow")
fi

if ! python -c "import hypothesis" 2>/dev/null; then
    echo "[test.sh] hypothesis missing; attempting pip install -r requirements-dev.txt" >&2
    pip install -r requirements-dev.txt 2>/dev/null \
        || echo "[test.sh] install failed/offline — property-based tests will skip" >&2
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# ${arr[@]+...} guard: empty arrays trip `set -u` on bash < 4.4 (macOS 3.2).
exec python -m pytest -x -q ${FAST_ARGS[@]+"${FAST_ARGS[@]}"} "$@"
