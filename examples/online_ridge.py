"""Streaming ridge regression with a sliding window — the classic consumer
of Cholesky up/down-dating (Seeger 2004, cited by the paper).

Maintains the factor of A_t = lambda*I + sum_{s in window} x_s x_s^T and the
solution w_t = A_t^{-1} X^T y over a sliding window of observations as ONE
stateful ``CholFactor``: each step ``.update``s with the newest batch of
rows and ``.downdate``s the batch falling out of the window — never
refactorizing — and reads the solution back with ``.solve``. Compares
against the exact windowed solve.

Two modes:

* single  — one stream, the paper's original workload (serial reference
  backend picked by the registry heuristic).
* batched — a fleet of independent per-user streams advanced in lockstep:
  one batched ``CholFactor`` on the fused single-launch kernel (DESIGN.md
  §5) absorbs every user's modification in one device dispatch, the
  serving-shaped workload the batched factor exists for.

Run:  PYTHONPATH=src python examples/online_ridge.py [--batched] [--users B]
"""
import argparse
import collections

import jax.numpy as jnp
import numpy as np

from repro.core import CholFactor


def run_single(*, d=64, batch=8, window_batches=4, steps=12, lam=1e-1, seed=0):
    rng = np.random.default_rng(seed)
    true_w = rng.normal(size=(d,)).astype(np.float32)
    f = CholFactor.identity(d, scale=lam, backend="reference")
    xty = jnp.zeros((d,))
    window = collections.deque()

    print(f"{'step':>4} {'err_vs_exact':>14} {'w_err':>10}")
    for t in range(steps):
        X = rng.normal(size=(batch, d)).astype(np.float32)
        y = X @ true_w + 0.1 * rng.normal(size=(batch,)).astype(np.float32)
        Xj, yj = jnp.asarray(X), jnp.asarray(y)

        # Rank-`batch` update with the new rows.
        f = f.update(Xj.T)
        xty = xty + Xj.T @ yj
        window.append((Xj, yj))

        # Slide: downdate the expiring batch (the paper's downdate in anger).
        if len(window) > window_batches:
            Xold, yold = window.popleft()
            f = f.downdate(Xold.T)
            xty = xty - Xold.T @ yold

        w = f.solve(xty)

        # Exact windowed solution for comparison.
        Xw = np.concatenate([np.asarray(x) for x, _ in window])
        yw = np.concatenate([np.asarray(y) for _, y in window])
        A_exact = lam * np.eye(d) + Xw.T @ Xw
        w_exact = np.linalg.solve(A_exact, Xw.T @ yw)
        err = float(np.max(np.abs(np.asarray(w) - w_exact)))
        werr = float(np.linalg.norm(np.asarray(w) - true_w)
                     / np.linalg.norm(true_w))
        print(f"{t:4d} {err:14.3e} {werr:10.4f}")

    print("maintained factor tracks the exact sliding-window solution.")


def run_batched(*, users=4, d=64, batch=8, window_batches=4, steps=8,
                lam=1e-1, panel=32, seed=0):
    """A fleet of independent sliding-window ridge streams, one per user.

    ONE batched CholFactor holds every user's statistics; every step issues
    exactly TWO batched device calls for the whole fleet (one update, one
    downdate) instead of 2*users — the launch economics the fused kernel
    brings to serving.
    """
    rng = np.random.default_rng(seed)
    true_w = rng.normal(size=(users, d)).astype(np.float32)
    f = CholFactor.identity(d, scale=lam, batch=users, backend="fused",
                            panel=panel)
    xty = jnp.zeros((users, d))
    window = collections.deque()

    print(f"fleet of {users} users, d={d}, rank-{batch} window slides "
          f"({f!r})")
    print(f"{'step':>4} {'max_err_vs_exact':>18} {'mean_w_err':>12}")
    for t in range(steps):
        X = rng.normal(size=(users, batch, d)).astype(np.float32)
        y = np.einsum("ubd,ud->ub", X, true_w) + 0.1 * rng.normal(
            size=(users, batch)).astype(np.float32)
        Xj, yj = jnp.asarray(X), jnp.asarray(y)

        # One launch updates every user's factor (V is (B, d, batch)).
        f = f.update(jnp.swapaxes(Xj, 1, 2))
        xty = xty + jnp.einsum("ubd,ub->ud", Xj, yj)
        window.append((Xj, yj))

        if len(window) > window_batches:
            Xold, yold = window.popleft()
            f = f.downdate(jnp.swapaxes(Xold, 1, 2))
            xty = xty - jnp.einsum("ubd,ub->ud", Xold, yold)

        w = f.solve(xty)

        # Exact per-user windowed solutions.
        errs, werrs = [], []
        for u in range(users):
            Xw = np.concatenate([np.asarray(x[u]) for x, _ in window])
            yw = np.concatenate([np.asarray(yb[u]) for _, yb in window])
            A_exact = lam * np.eye(d) + Xw.T @ Xw
            w_exact = np.linalg.solve(A_exact, Xw.T @ yw)
            errs.append(float(np.max(np.abs(np.asarray(w[u]) - w_exact))))
            werrs.append(float(np.linalg.norm(np.asarray(w[u]) - true_w[u])
                               / np.linalg.norm(true_w[u])))
        print(f"{t:4d} {max(errs):18.3e} {np.mean(werrs):12.4f}")

    print("every user's maintained factor tracks its exact windowed solution.")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--batched", action="store_true",
                    help="run the fleet-of-users batched mode")
    ap.add_argument("--users", type=int, default=4)
    args = ap.parse_args()
    if args.batched:
        run_batched(users=args.users)
    else:
        run_single()
