"""Streaming ridge regression with a sliding window — the classic consumer
of Cholesky up/down-dating (Seeger 2004, cited by the paper).

Maintains the factor of A_t = lambda*I + sum_{s in window} x_s x_s^T and the
solution w_t = A_t^{-1} X^T y over a sliding window of observations:
each step UPDATES with the newest batch of rows and DOWNDATES the batch
falling out of the window — never refactorizing. Compares against the exact
windowed solve.

Run:  PYTHONPATH=src python examples/online_ridge.py
"""
import collections

import jax.numpy as jnp
import numpy as np

from repro.core import chol_factor, chol_solve, chol_update

rng = np.random.default_rng(0)
d, batch, window_batches, steps = 64, 8, 4, 12
lam = 1e-1

true_w = rng.normal(size=(d,)).astype(np.float32)
L = chol_factor(jnp.eye(d) * lam)  # factor of lambda*I
xty = jnp.zeros((d,))
window = collections.deque()

print(f"{'step':>4} {'err_vs_exact':>14} {'w_err':>10}")
for t in range(steps):
    X = rng.normal(size=(batch, d)).astype(np.float32)
    y = X @ true_w + 0.1 * rng.normal(size=(batch,)).astype(np.float32)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)

    # Rank-`batch` update with the new rows.
    L = chol_update(L, Xj.T, sigma=1, method="reference")
    xty = xty + Xj.T @ yj
    window.append((Xj, yj))

    # Slide: downdate the expiring batch (the paper's downdate in anger).
    if len(window) > window_batches:
        Xold, yold = window.popleft()
        L = chol_update(L, Xold.T, sigma=-1, method="reference")
        xty = xty - Xold.T @ yold

    w = chol_solve(L, xty)

    # Exact windowed solution for comparison.
    Xw = np.concatenate([np.asarray(x) for x, _ in window])
    yw = np.concatenate([np.asarray(y) for _, y in window])
    A_exact = lam * np.eye(d) + Xw.T @ Xw
    w_exact = np.linalg.solve(A_exact, Xw.T @ yw)
    err = float(np.max(np.abs(np.asarray(w) - w_exact)))
    werr = float(np.linalg.norm(np.asarray(w) - true_w) / np.linalg.norm(true_w))
    print(f"{t:4d} {err:14.3e} {werr:10.4f}")

print("maintained factor tracks the exact sliding-window solution.")
