"""Streaming ridge regression with a sliding window — the classic consumer
of Cholesky up/down-dating (Seeger 2004, cited by the paper).

Maintains the factor of A_t = lambda*I + sum_{s in window} x_s x_s^T and the
solution w_t = A_t^{-1} X^T y over a sliding window of observations as ONE
stateful ``CholFactor``: each step ``.update``s with the newest batch of
rows and ``.downdate``s the batch falling out of the window — never
refactorizing — and reads the solution back with ``.solve``. Compares
against the exact windowed solve.

Two modes (plus a placement flag):

* single  — one stream, the paper's original workload (serial reference
  backend picked by the registry heuristic).
* batched — a fleet of independent per-user streams served through the
  ``repro.stream`` subsystem (DESIGN.md §9): per-user rank-1 observations
  are pushed into a ``StreamService``, coalesced in ring buffers to the
  paper's k=16 sweet spot, and absorbed as fused batched rank-k flushes
  over one ``CholFactor`` fleet — with the sliding window handled as
  deferred, coalesced downdates scheduled by the service.
* --sharded — the batched fleet with every member column-sharded over a
  4-way mesh (DESIGN.md §10): the "per-user factor outgrew one device"
  regime, still riding the same coalesced flush path (one kernel launch
  per shard per sign block, independent of the fleet size). Re-execs with
  4 emulated host devices when the machine has only one.

Run:  PYTHONPATH=src python examples/online_ridge.py [--batched|--sharded]
      [--users B]
"""
import argparse
import collections

import jax.numpy as jnp
import numpy as np

from repro.core import CholFactor
from repro.runtime.compat import ensure_host_devices, make_mesh_compat
from repro.stream import FactorStore, StreamService, mutations_issued

SHARDS = 4


def run_single(*, d=64, batch=8, window_batches=4, steps=12, lam=1e-1, seed=0):
    rng = np.random.default_rng(seed)
    true_w = rng.normal(size=(d,)).astype(np.float32)
    f = CholFactor.identity(d, scale=lam, backend="reference")
    xty = jnp.zeros((d,))
    window = collections.deque()

    print(f"{'step':>4} {'err_vs_exact':>14} {'w_err':>10}")
    for t in range(steps):
        X = rng.normal(size=(batch, d)).astype(np.float32)
        y = X @ true_w + 0.1 * rng.normal(size=(batch,)).astype(np.float32)
        Xj, yj = jnp.asarray(X), jnp.asarray(y)

        # Rank-`batch` update with the new rows.
        f = f.update(Xj.T)
        xty = xty + Xj.T @ yj
        window.append((Xj, yj))

        # Slide: downdate the expiring batch (the paper's downdate in anger).
        if len(window) > window_batches:
            Xold, yold = window.popleft()
            f = f.downdate(Xold.T)
            xty = xty - Xold.T @ yold

        w = f.solve(xty)

        # Exact windowed solution for comparison.
        Xw = np.concatenate([np.asarray(x) for x, _ in window])
        yw = np.concatenate([np.asarray(y) for _, y in window])
        A_exact = lam * np.eye(d) + Xw.T @ Xw
        w_exact = np.linalg.solve(A_exact, Xw.T @ yw)
        err = float(np.max(np.abs(np.asarray(w) - w_exact)))
        werr = float(np.linalg.norm(np.asarray(w) - true_w)
                     / np.linalg.norm(true_w))
        print(f"{t:4d} {err:14.3e} {werr:10.4f}")

    print("maintained factor tracks the exact sliding-window solution.")


def run_batched(*, users=4, d=64, batch=8, window_batches=4, steps=8,
                lam=1e-1, panel=32, width=16, seed=0, sharded=False):
    """A fleet of independent sliding-window ridge streams, one per user,
    served through ``repro.stream``.

    Each step produces ``batch`` rank-1 rows per user; the service buffers
    them and flushes every ``width // batch`` steps as ONE fused batched
    rank-k update for the whole fleet (plus, when the window slides, one
    guarded batched downdate) — the coalescing economics the subsystem
    exists for: rows/mutation approaches the paper's k=16 sweet spot
    instead of 2*users*steps separate device calls.

    With ``sharded=True`` every member of the fleet is column-sharded over
    a ``SHARDS``-way mesh (DESIGN.md §10) and the flushes dispatch through
    the fleet-native distributed driver — same service, same coalescer,
    one launch per shard per sign block.
    """
    rng = np.random.default_rng(seed)
    true_w = rng.normal(size=(users, d)).astype(np.float32)
    if sharded:
        import jax

        mesh = make_mesh_compat((SHARDS,), ("model",),
                                devices=jax.devices()[:SHARDS])
        store = FactorStore(d, capacity=users, width=width,
                            panel=min(panel, d // SHARDS),
                            backend="sharded", mesh=mesh, axis="model",
                            init_scale=lam)
    else:
        store = FactorStore(d, capacity=users, width=width, panel=panel,
                            backend="fused", init_scale=lam)
    svc = StreamService(store, window=window_batches, auto_flush=False)
    # AOT-warm the serving rung (DESIGN.md §11): the step loop below only
    # dispatches pre-compiled executables — no first-flush trace stall.
    rep = store.warmup(rungs=(store.capacity,))
    print(f"warmup: {rep.compiled} AOT executables in {rep.seconds:.1f}s "
          f"({rep.cached} already cached)")
    for u in range(users):
        svc.admit(u)

    # Host bookkeeping mirroring the flush reports: rows not yet absorbed,
    # and rows currently inside each user's factor.
    pending = [collections.deque() for _ in range(users)]
    active = [collections.deque() for _ in range(users)]
    xty = np.zeros((users, d), np.float32)

    def absorb(report):
        if report is None or report.empty:
            return
        assert all(report.downdate_ok.values())
        for u, k in report.absorbed.items():
            for _ in range(k):
                x, yv = pending[u].popleft()
                active[u].append((x, yv))
                xty[u] += x * yv
        for u, k in report.downdated.items():
            for _ in range(k):
                x, yv = active[u].popleft()
                xty[u] -= x * yv

    cadence = max(width // batch, 1)
    muts0 = mutations_issued()
    print(f"fleet of {users} users, d={d}, {batch} rank-1 rows/user/step, "
          f"coalesce width {width} ({store.factor!r})")
    print(f"{'step':>4} {'max_err_vs_exact':>18} {'mean_w_err':>12}")
    for t in range(steps):
        absorb(svc.tick())                      # window expiry downdates
        X = rng.normal(size=(users, batch, d)).astype(np.float32)
        y = np.einsum("ubd,ud->ub", X, true_w) + 0.1 * rng.normal(
            size=(users, batch)).astype(np.float32)
        for u in range(users):
            for j in range(batch):
                svc.push(u, X[u, j])
                pending[u].append((X[u, j].copy(), float(y[u, j])))
        if (t + 1) % cadence == 0:
            absorb(svc.flush())

            w = store.factor.solve(jnp.asarray(xty))
            errs, werrs = [], []
            for u in range(users):
                Xw = np.stack([x for x, _ in active[u]])
                yw = np.asarray([yv for _, yv in active[u]])
                A_exact = lam * np.eye(d) + Xw.T @ Xw
                w_exact = np.linalg.solve(A_exact, Xw.T @ yw)
                errs.append(float(np.max(np.abs(np.asarray(w[u]) - w_exact))))
                werrs.append(float(
                    np.linalg.norm(np.asarray(w[u]) - true_w[u])
                    / np.linalg.norm(true_w[u])))
            print(f"{t:4d} {max(errs):18.3e} {np.mean(werrs):12.4f}")

    muts = mutations_issued() - muts0
    rows = users * batch * steps
    print(f"{rows} rank-1 rows absorbed in {muts} batched mutations "
          f"({rows / max(muts, 1):.1f} rows/mutation); every user's "
          f"maintained factor tracks its exact windowed solution.")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--batched", action="store_true",
                    help="run the fleet-of-users batched mode")
    ap.add_argument("--sharded", action="store_true",
                    help="batched fleet with column-sharded members over a "
                         f"{SHARDS}-way mesh (emulated if needed)")
    ap.add_argument("--users", type=int, default=4)
    args = ap.parse_args()
    if args.sharded:
        ensure_host_devices(SHARDS)
        run_batched(users=args.users, sharded=True)
    elif args.batched:
        run_batched(users=args.users)
    else:
        run_single()

    import repro.obs as obs

    print(obs.summary_line())
