"""Batched serving demo: SWA ring-cache decode + per-user personalization.

Two stages, both fleet-shaped:

1. the LM serving path (reduced h2o-danube config) batch-decodes a prompt
   continuation for every user (``repro.launch.serve.generate``);

2. a **personalization sidecar** maintains one batched ``CholFactor`` of
   per-user preference statistics over the generated stream: every decode
   step contributes each user's token embedding as a rank-1 row, absorbed
   for the WHOLE fleet in one batched update on the fused kernel, and a
   sliding window downdates the expiring step — the paper's up/down-dating
   as the online-learning layer of a serving stack. The per-user preference
   weights are read back with ``.solve`` and checked against the exact
   windowed regression.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import collections

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import CholFactor
from repro.data import DataConfig, SyntheticTokens
from repro.launch.serve import generate
from repro.models import init_model, split_params


def personalize(token_stream, *, d_feat=32, window=8, lam=1e-1, panel=16,
                seed=0):
    """Per-user online ridge over the generated tokens, one batched factor.

    token_stream: (B, T) generated token ids. Returns max tracking error of
    the maintained solution vs the exact windowed solve.
    """
    B, T = token_stream.shape
    rng = np.random.default_rng(seed)
    vocab_hash = 4096
    emb = jnp.asarray(
        rng.normal(size=(vocab_hash, d_feat)).astype(np.float32)
        / np.sqrt(d_feat)
    )
    true_pref = jnp.asarray(rng.normal(size=(B, d_feat)).astype(np.float32))

    f = CholFactor.identity(d_feat, scale=lam, batch=B, backend="fused",
                            panel=panel)
    xty = jnp.zeros((B, d_feat))
    ring = collections.deque()

    max_err = 0.0
    for t in range(T):
        phi = emb[token_stream[:, t] % vocab_hash]          # (B, d) features
        reward = jnp.einsum("bd,bd->b", phi, true_pref)     # per-user signal
        # One batched rank-1 update for the whole fleet (single launch on
        # the fused backend), one batched downdate when the window slides.
        f = f.update(phi[:, :, None])
        xty = xty + phi * reward[:, None]
        ring.append((phi, reward))
        if len(ring) > window:
            phi_old, r_old = ring.popleft()
            f = f.downdate(phi_old[:, :, None])
            xty = xty - phi_old * r_old[:, None]
        w = f.solve(xty)                                    # (B, d) prefs

        # exact windowed solve, per user
        Phi = jnp.stack([p for p, _ in ring], axis=1)       # (B, W, d)
        R = jnp.stack([r for _, r in ring], axis=1)         # (B, W)
        A = lam * jnp.eye(d_feat)[None] + jnp.einsum(
            "bwd,bwe->bde", Phi, Phi)
        rhs = jnp.einsum("bwd,bw->bd", Phi, R)
        w_exact = jnp.linalg.solve(A, rhs[..., None])[..., 0]
        max_err = max(max_err, float(jnp.max(jnp.abs(w - w_exact))))
    return max_err


def main():
    cfg = get_config("h2o-danube-1.8b").reduced()
    key = jax.random.PRNGKey(0)
    values, _ = split_params(init_model(key, cfg))
    batch, prompt_len, gen = 8, 32, 64
    data = SyntheticTokens(DataConfig(cfg.vocab_size, prompt_len, batch, seed=2))
    prompts = data.batch_at(0)["tokens"]
    toks, tps = generate(cfg, values, prompts, gen=gen,
                         cache_len=prompt_len + gen, temperature=0.8)
    print(f"generated {toks.shape} tokens at {tps:.1f} tok/s (batch {batch})")

    err = personalize(np.asarray(toks[:, prompt_len:]))
    print(f"personalization sidecar: fleet of {batch} per-user factors, "
          f"max err vs exact windowed solve = {err:.3e}")
    assert tps > 0
    assert err < 1e-2
    return tps


if __name__ == "__main__":
    main()
