"""Batched serving demo: SWA ring-cache decode (reduced h2o-danube config).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    tps = serve_main([
        "--arch", "h2o-danube-1.8b",
        "--batch", "8",
        "--prompt-len", "32",
        "--gen", "64",
        "--temperature", "0.8",
    ])
    assert tps > 0
