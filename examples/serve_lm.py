"""Batched serving demo: SWA ring-cache decode + per-user personalization.

Two stages, both fleet-shaped:

1. the LM serving path (reduced h2o-danube config) batch-decodes a prompt
   continuation for every user (``repro.launch.serve.generate``);

2. a **personalization sidecar** maintains per-user preference statistics
   over the generated stream through ``repro.stream``: every decode step
   contributes each user's token embedding as a rank-1 ``push`` into the
   ``StreamService``, which coalesces the traffic in per-user ring buffers
   and absorbs it in fused rank-k flushes over one batched ``CholFactor``
   fleet — the paper's bandwidth-bound economics (rank-k amortization, ~7x
   at k=16) applied as the online-learning layer of a serving stack. A
   sliding window forgets old steps as *deferred, coalesced downdates*
   scheduled by the service (window expiry), not per-step device calls.
   At every flush boundary the per-user preference weights are read back
   with ``.solve`` and checked against the exact windowed regression.

With ``--sharded`` the sidecar's fleet members are each column-sharded
over a 4-way mesh (DESIGN.md §10) — the regime where one user's
preference statistics outgrow a device — and every flush still costs one
kernel launch per shard per sign block, independent of the batch size.
Re-execs with emulated host devices when the machine has only one.

Run:  PYTHONPATH=src python examples/serve_lm.py [--sharded]
"""
import argparse
import collections

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import DataConfig, SyntheticTokens
from repro.launch.serve import generate
from repro.models import init_model, split_params
from repro.runtime.compat import ensure_host_devices, make_mesh_compat
from repro.stream import FactorStore, StreamService, mutations_issued

SHARDS = 4


def personalize(token_stream, *, d_feat=32, width=8, window=16, lam=1e-1,
                panel=16, seed=0, sharded=False, background=False):
    """Per-user online ridge over the generated tokens, one streamed fleet.

    token_stream: (B, T) generated token ids. Returns (max tracking error
    of the maintained solution vs the exact windowed solve at every flush
    boundary, batched mutations issued, rank-1 rows absorbed). With
    ``sharded=True`` the fleet members are column-sharded over a
    ``SHARDS``-way mesh and flushes dispatch per-shard (DESIGN.md §10).
    With ``background=True`` the flushes run on the service's daemon
    worker (DESIGN.md §11) — pushes return immediately, reports are
    collected via ``drain()`` at each evaluation boundary.
    """
    B, T = token_stream.shape
    rng = np.random.default_rng(seed)
    vocab_hash = 4096
    emb = np.asarray(
        rng.normal(size=(vocab_hash, d_feat)).astype(np.float32)
        / np.sqrt(d_feat)
    )
    true_pref = np.asarray(rng.normal(size=(B, d_feat)).astype(np.float32))

    # The streaming subsystem: one fleet, rank-1 pushes coalesced to
    # width-k flushes, sliding window via scheduled downdates.
    if sharded:
        mesh = make_mesh_compat((SHARDS,), ("model",),
                                devices=jax.devices()[:SHARDS])
        store = FactorStore(d_feat, capacity=B, width=width,
                            panel=min(panel, d_feat // SHARDS),
                            backend="sharded", mesh=mesh, axis="model",
                            init_scale=lam)
    else:
        store = FactorStore(d_feat, capacity=B, width=width, panel=panel,
                            backend="fused", init_scale=lam)
    svc = StreamService(store, window=window, auto_flush=background,
                        background=background)
    # AOT-warm the serving rung before any traffic: everything the loop
    # below dispatches is then a pre-compiled executable (DESIGN.md §11),
    # so the first flush costs the same as the thousandth.
    store.warmup(rungs=(store.capacity,))
    for u in range(B):
        svc.admit(u)

    # Host-side bookkeeping mirroring the service's reports: rows pushed
    # but unflushed, and rows currently inside each user's factor.
    pending = [collections.deque() for _ in range(B)]
    active = [collections.deque() for _ in range(B)]
    xty = np.zeros((B, d_feat), np.float32)

    def absorb(report):
        if report is None or report.empty:
            return
        assert all(report.downdate_ok.values()), "windowed downdate refused"
        for u, k in report.absorbed.items():
            for _ in range(k):
                phi, r = pending[u].popleft()
                active[u].append((phi, r))
                xty[u] += phi * r
        for u, k in report.downdated.items():
            for _ in range(k):
                phi, r = active[u].popleft()
                xty[u] -= phi * r

    muts0, rows_pushed = mutations_issued(), 0
    max_err = 0.0
    for t in range(T):
        absorb(svc.tick())                      # window expiry fires here
        phi = emb[token_stream[:, t] % vocab_hash]          # (B, d)
        reward = np.einsum("bd,bd->b", phi, true_pref)      # per-user signal
        for u in range(B):
            svc.push(u, phi[u])
            pending[u].append((phi[u].copy(), float(reward[u])))
            rows_pushed += 1
        if (t + 1) % width == 0:
            if background:
                # The worker flushed width-triggered rings off-thread;
                # collect its reports, then sweep any ready remainder.
                for rep in svc.drain():
                    absorb(rep)
            absorb(svc.flush())
            # Maintained vs exact windowed solve over the absorbed rows.
            w = store.factor.solve(jnp.asarray(xty))        # (B, d) prefs
            for u in range(B):
                Phi = np.stack([p for p, _ in active[u]])
                R = np.asarray([r for _, r in active[u]])
                A = lam * np.eye(d_feat) + Phi.T @ Phi
                w_exact = np.linalg.solve(A, Phi.T @ R)
                max_err = max(max_err, float(
                    np.max(np.abs(np.asarray(w[u]) - w_exact))))
    if background:
        for rep in svc.drain():
            absorb(rep)
        svc.stop_background()
    return max_err, mutations_issued() - muts0, rows_pushed


def main(*, sharded=False, background=False, stats=False):
    cfg = get_config("h2o-danube-1.8b").reduced()
    key = jax.random.PRNGKey(0)
    values, _ = split_params(init_model(key, cfg))
    batch, prompt_len, gen = 8, 32, 64
    data = SyntheticTokens(DataConfig(cfg.vocab_size, prompt_len, batch, seed=2))
    prompts = data.batch_at(0)["tokens"]
    toks, tps = generate(cfg, values, prompts, gen=gen,
                         cache_len=prompt_len + gen, temperature=0.8)
    print(f"generated {toks.shape} tokens at {tps:.1f} tok/s (batch {batch})")

    err, muts, rows = personalize(np.asarray(toks[:, prompt_len:]),
                                  sharded=sharded, background=background)
    print(f"personalization sidecar: fleet of {batch} per-user factors"
          f"{f' ({SHARDS}-way sharded members)' if sharded else ''}"
          f"{' (background flush worker)' if background else ''}, "
          f"{rows} rank-1 rows coalesced into {muts} batched rank-k "
          f"mutations ({rows / max(muts, 1):.1f} rows/mutation), "
          f"max err vs exact windowed solve = {err:.3e}")
    assert tps > 0
    assert err < 1e-2
    assert muts < rows, "coalescing must batch rank-1 rows into rank-k"
    if stats:
        import repro.obs as obs

        print(obs.summary_line())
    return tps


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--sharded", action="store_true",
                    help="column-shard the sidecar fleet's members over a "
                         f"{SHARDS}-way mesh (emulated if needed)")
    ap.add_argument("--background", action="store_true",
                    help="run sidecar flushes on the service's daemon "
                         "worker (DESIGN.md §11) instead of inline")
    ap.add_argument("--stats", action="store_true",
                    help="print the one-line repro.obs metrics summary "
                         "(flush percentiles, mutations, retraces) at exit")
    args = ap.parse_args()
    if args.sharded:
        ensure_host_devices(SHARDS)
    main(sharded=args.sharded, background=args.background, stats=args.stats)
