"""Quickstart: the rank-k Cholesky up/down-date public API.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (
    CholFactor,
    backends,
    chol_downdate,
    chol_factor,
    chol_solve,
    chol_update,
    modify_error,
    resolve_backend_for,
)

# --- Build an SPD matrix and its upper Cholesky factor (A = L^T L). -------
rng = np.random.default_rng(0)
n, k = 512, 16
B = rng.uniform(size=(n, n)).astype(np.float32)
A = jnp.asarray(B.T @ B + np.eye(n, dtype=np.float32))
L = chol_factor(A)
V = jnp.asarray(rng.uniform(size=(n, k)).astype(np.float32))

# --- Rank-16 update: O(k n^2) instead of refactorizing in O(n^3). ---------
L_up = chol_update(L, V, method="gemm")           # TPU-native panel GEMM
err = modify_error(L_up, L, V, sigma=1)           # paper's error metric
print(f"update:   max|A~ - L~^T L~| = {float(err):.3e}")

# The same result via the paper-faithful element-wise panel path:
L_up2 = chol_update(L, V, method="paper")
print(f"paths agree to {float(jnp.max(jnp.abs(L_up - L_up2))):.3e}")

# --- Downdate: remove V V^T again and recover the original factor. --------
L_back = chol_downdate(L_up, V, method="gemm")
print(f"roundtrip: max|L - L_back| = {float(jnp.max(jnp.abs(L - L_back))):.3e}")

# --- Use the maintained factor: solve A~ x = b without refactorizing. -----
b = jnp.asarray(rng.uniform(size=(n,)).astype(np.float32))
x = chol_solve(L_up, b)
resid = jnp.max(jnp.abs((A + V @ V.T) @ x - b))
print(f"solve:    max residual = {float(resid):.3e}")

# --- Pallas kernel path (interpret mode on CPU, Mosaic on TPU). -----------
L_pal = chol_update(L, V, method="pallas_gemm", panel=128)
print(f"pallas:   max|gemm - pallas| = {float(jnp.max(jnp.abs(L_up - L_pal))):.3e}")

# --- The stateful engine: one CholFactor, every op on the same object. -----
# Backends are a registry ('auto' resolves by device/size heuristics); the
# factor is a pytree, so it jits, vmaps, and lives in optimizer state.
print(f"registered backends: {backends.names()}")
f = CholFactor.from_matrix(A, panel=128)   # backend='auto'
print(f"{f!r} -> auto resolves to {resolve_backend_for(f)!r}")
f = f.update(V)                            # A + V V^T, no refactorization
x2 = f.solve(b)                            # same two triangular solves
print(f"factor:   max|x - x_factor| = {float(jnp.max(jnp.abs(x - x2))):.3e}")
print(f"logdet:   {float(f.logdet()):.2f}")
guarded, ok = f.downdate_guarded(100.0 * V)  # PD guard refuses bad downdates
print(f"guarded downdate of an infeasible V: ok={bool(ok)} (factor unchanged)")
f = f.downdate(V)                          # back to the original statistics
print(f"object roundtrip: max|L - f.data| = "
      f"{float(jnp.max(jnp.abs(L - f.data))):.3e}")
