"""Quickstart: the rank-k Cholesky up/down-date public API.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (
    chol_downdate,
    chol_factor,
    chol_solve,
    chol_update,
    modify_error,
)

# --- Build an SPD matrix and its upper Cholesky factor (A = L^T L). -------
rng = np.random.default_rng(0)
n, k = 512, 16
B = rng.uniform(size=(n, n)).astype(np.float32)
A = jnp.asarray(B.T @ B + np.eye(n, dtype=np.float32))
L = chol_factor(A)
V = jnp.asarray(rng.uniform(size=(n, k)).astype(np.float32))

# --- Rank-16 update: O(k n^2) instead of refactorizing in O(n^3). ---------
L_up = chol_update(L, V, method="gemm")           # TPU-native panel GEMM
err = modify_error(L_up, L, V, sigma=1)           # paper's error metric
print(f"update:   max|A~ - L~^T L~| = {float(err):.3e}")

# The same result via the paper-faithful element-wise panel path:
L_up2 = chol_update(L, V, method="paper")
print(f"paths agree to {float(jnp.max(jnp.abs(L_up - L_up2))):.3e}")

# --- Downdate: remove V V^T again and recover the original factor. --------
L_back = chol_downdate(L_up, V, method="gemm")
print(f"roundtrip: max|L - L_back| = {float(jnp.max(jnp.abs(L - L_back))):.3e}")

# --- Use the maintained factor: solve A~ x = b without refactorizing. -----
b = jnp.asarray(rng.uniform(size=(n,)).astype(np.float32))
x = chol_solve(L_up, b)
resid = jnp.max(jnp.abs((A + V @ V.T) @ x - b))
print(f"solve:    max residual = {float(resid):.3e}")

# --- Pallas kernel path (interpret mode on CPU, Mosaic on TPU). -----------
L_pal = chol_update(L, V, method="pallas_gemm", panel=128)
print(f"pallas:   max|gemm - pallas| = {float(jnp.max(jnp.abs(L_up - L_pal))):.3e}")
