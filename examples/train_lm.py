"""End-to-end LM training with the CholeskyPrecond optimizer (reduced
llama3.2 config on CPU; pass --full on real hardware for the 3B config).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import sys

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--optimizer", default="cholesky_precond")
    args = ap.parse_args()
    losses = train_main([
        "--arch", args.arch,
        "--steps", str(args.steps),
        "--optimizer", args.optimizer,
        "--batch", "8",
        "--seq", "128",
        "--ckpt-dir", "/tmp/repro_train_lm",
    ])
    assert losses[-1] < losses[0], "loss must decrease"
    print("OK: loss decreased", losses[0], "->", losses[-1])
