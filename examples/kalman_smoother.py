"""Information-form Kalman smoother on a block-tridiagonal factor.

The joint posterior over a whole state trajectory x_0..x_{T-1} of a
linear-Gaussian state-space model has a block-tridiagonal precision
matrix: dynamics couple only adjacent states, measurements touch one
state each.  That is exactly the structure DESIGN.md §12's
``blocktridiag`` backend serves — the Cholesky factor is upper
block-bidiagonal, so the smoother runs in O(T·d²) memory where the dense
stack would need O(T²·d²) and refuses to scale past a few thousand
timesteps.

The demo maintains ONE structured ``CholFactor`` of the trajectory
precision:

* the motion prior (tridiagonal by construction) seeds the factor via
  ``CholFactor.from_blocktridiag`` — the block-chain factorization, never
  a dense (n,n) Cholesky;
* each measurement y_t = H x_t + v adds Hᵀ R⁻¹ H to diagonal block t —
  a rank-m update whose V columns live inside block t, i.e. block-local
  in the kernel's contract.  Measurements are coalesced into rank-k
  batches (k = chunk·m, near the paper's k=16 sweet spot) so a chunk of
  timesteps is absorbed in ONE launch per sign block;
* an injected outlier is retracted afterwards with a hyperbolic
  ``downdate`` of just its own columns — the up/down-dating pair in
  anger, no refactorization;
* the smoothed means are read back with ``.solve`` (two block
  substitutions), and the posterior log-determinant (the evidence term)
  with ``.logdet``.

Everything is checked against a dense NumPy solve of the same posterior,
which is only affordable because the demo keeps T small.

Run:  PYTHONPATH=src python examples/kalman_smoother.py [--T 32] [--chunk 8]
      [--method auto|blocktridiag|blocktridiag_ref]
"""
import argparse

import jax.numpy as jnp
import numpy as np

from repro.core import CholFactor
from repro.kernels import blocktridiag as btd_k

# 2D constant-velocity model: state (px, vx, py, vy), positions observed.
D = 4
M = 2
DT = 0.1


def model():
    f1 = np.array([[1.0, DT], [0.0, 1.0]], np.float32)
    F = np.kron(np.eye(2, dtype=np.float32), f1)          # (D, D)
    H = np.zeros((M, D), np.float32)
    H[0, 0] = H[1, 2] = 1.0                               # observe positions
    Q = 0.05 * np.eye(D, dtype=np.float32)                # process noise cov
    R = 0.25 * np.eye(M, dtype=np.float32)                # measurement cov
    P0 = 4.0 * np.eye(D, dtype=np.float32)                # initial state cov
    return F, H, Q, R, P0


def prior_precision_blocks(T, F, Q, P0):
    """Block-tridiagonal precision of the motion prior.

    From the joint negative log-density
      ½ x₀ᵀ P0⁻¹ x₀ + ½ Σ_t (x_{t+1} − F x_t)ᵀ Q⁻¹ (x_{t+1} − F x_t):
    interior diagonal blocks collect Q⁻¹ + Fᵀ Q⁻¹ F, the upper
    off-diagonal blocks are −Fᵀ Q⁻¹.
    """
    Qinv = np.linalg.inv(Q)
    Ad = np.zeros((T, D, D), np.float32)
    Ao = np.zeros((T - 1, D, D), np.float32)
    Ad[0] += np.linalg.inv(P0)
    for t in range(T - 1):
        Ad[t] += F.T @ Qinv @ F
        Ad[t + 1] += Qinv
        Ao[t] = -F.T @ Qinv
    return Ad, Ao


def measurement_columns(T, ts, H, R):
    """V with one block-local column group per measurement time.

    Hᵀ R⁻¹ H = V_t V_tᵀ with V_t = Hᵀ R^{-1/2}: each column is supported
    inside diagonal block t only, so a whole chunk of timesteps rides one
    rank-(chunk·M) update.
    """
    Rinv_half = np.linalg.cholesky(np.linalg.inv(R)).astype(np.float32)
    V = np.zeros((T * D, len(ts) * M), np.float32)
    for c, t in enumerate(ts):
        V[t * D:(t + 1) * D, c * M:(c + 1) * M] = H.T @ Rinv_half
    return V


def simulate(T, F, H, Q, R, P0, seed):
    rng = np.random.default_rng(seed)
    x = rng.multivariate_normal(np.zeros(D), P0).astype(np.float32)
    xs, ys = [], []
    for _ in range(T):
        xs.append(x)
        ys.append((H @ x + rng.multivariate_normal(
            np.zeros(M), R)).astype(np.float32))
        x = (F @ x + rng.multivariate_normal(
            np.zeros(D), Q)).astype(np.float32)
    return np.stack(xs), np.stack(ys), rng


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--T", type=int, default=32, help="trajectory length")
    ap.add_argument("--chunk", type=int, default=8,
                    help="measurement timesteps coalesced per update "
                         "(rank k = chunk*2)")
    ap.add_argument("--method", default="auto",
                    choices=("auto", "blocktridiag", "blocktridiag_ref"),
                    help="structured backend (auto: registry heuristic — "
                         "kernel on TPU/GPU/interpret, scan twin otherwise)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    T, n = args.T, args.T * D

    F, H, Q, R, P0 = model()
    truth, ys, rng = simulate(T, F, H, Q, R, P0, args.seed)
    Ad, Ao = prior_precision_blocks(T, F, Q, P0)

    f = CholFactor.from_blocktridiag(jnp.asarray(Ad), jnp.asarray(Ao),
                                     backend=args.method)
    print(f"trajectory precision: {f!r}")
    sb = btd_k.factor_bytes(T, D, storage_dtype=jnp.float32)
    print(f"factor storage {sb} B vs dense {n * n * 4} B "
          f"({n * n * 4 / sb:.1f}x, grows like T/{2 * D} with T)")

    # Absorb measurements chunk by chunk: each chunk is ONE rank-(chunk*M)
    # block-local update — one kernel launch on the blocktridiag backend.
    eta = np.zeros(n, np.float32)
    Rinv = np.linalg.inv(R)
    for lo in range(0, T, args.chunk):
        ts = range(lo, min(lo + args.chunk, T))
        f = f.update(jnp.asarray(measurement_columns(T, ts, H, R)))
        for t in ts:
            eta[t * D:(t + 1) * D] += H.T @ Rinv @ ys[t]

    # Inject a corrupted observation at mid-trajectory, then retract it
    # with a hyperbolic downdate of exactly its own columns.
    t_bad = T // 2
    y_bad = ys[t_bad] + np.array([25.0, -25.0], np.float32)
    Vbad = measurement_columns(T, [t_bad], H, R)
    f_bad = f.update(jnp.asarray(Vbad))
    eta_bad = eta.copy()
    eta_bad[t_bad * D:(t_bad + 1) * D] += H.T @ Rinv @ y_bad
    xs_bad = np.asarray(f_bad.solve(jnp.asarray(eta_bad))).reshape(T, D)
    assert bool(f_bad.downdate_feasible(jnp.asarray(Vbad)))
    f = f_bad.downdate(jnp.asarray(Vbad))

    # Smoothed means: two block substitutions, never a dense matrix.
    xs = np.asarray(f.solve(jnp.asarray(eta))).reshape(T, D)

    # Dense cross-check of the same posterior (affordable only because the
    # demo keeps T small — the structured path never forms this).
    J = np.zeros((n, n), np.float32)
    for t in range(T):
        J[t * D:(t + 1) * D, t * D:(t + 1) * D] = Ad[t]
    for t in range(T - 1):
        J[t * D:(t + 1) * D, (t + 1) * D:(t + 2) * D] = Ao[t]
        J[(t + 1) * D:(t + 2) * D, t * D:(t + 1) * D] = Ao[t].T
    Vall = measurement_columns(T, range(T), H, R)
    J += Vall @ Vall.T
    xs_exact = np.linalg.solve(J.astype(np.float64),
                               eta.astype(np.float64)).reshape(T, D)
    err = float(np.max(np.abs(xs - xs_exact)))
    sign, ld_exact = np.linalg.slogdet(J.astype(np.float64))
    ld_err = abs(float(f.logdet()) - ld_exact)
    rmse = float(np.sqrt(np.mean((xs[:, [0, 2]] - truth[:, [0, 2]]) ** 2)))
    raw = float(np.sqrt(np.mean((ys - truth[:, [0, 2]]) ** 2)))
    pull = float(np.max(np.abs(xs_bad[t_bad] - xs[t_bad])))
    print(f"T={T} states, {T * M} measurements absorbed in "
          f"{-(-T // args.chunk)} rank-{args.chunk * M} updates")
    print(f"smoothed mean vs dense solve: max |err| = {err:.2e}")
    print(f"logdet vs dense slogdet:      |err| = {ld_err:.2e} "
          f"(sign {sign:+.0f})")
    print(f"position RMSE: smoothed {rmse:.3f} vs raw measurements {raw:.3f}")
    print(f"outlier retracted by downdate (had pulled the mid-trajectory "
          f"state {pull:.2f} away)")
    assert err < 5e-3 and ld_err < 1e-2
    print("structured smoother matches the dense posterior it never formed.")


if __name__ == "__main__":
    main()
