"""Distributed rank-k update scaling (8 virtual devices) + launch accounting.

Benchmarks both sharded strategies: the distributed fused composition (one
Pallas launch per shard per update, DESIGN.md §7) and the per-panel GEMM
driver, with the launch-count instrumentation asserting the one-launch
claim — plus the FLEET axis (DESIGN.md §10): stacked (B, n, n) fleets,
each member column-sharded, absorbing one rank-k update per member, with
``launches_traced`` recorded per fleet size to show launches scale with
shards, never with B. Subprocess with forced device count so the main
bench process keeps its single-device config.

Rows land in ``benchmarks/results/BENCH_distributed.json`` (their axes —
device count and fleet size — would make the shared cholupdate trajectory
unqueryable; see benchmarks/snapshot.py).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import jax, jax.numpy as jnp, numpy as np
from repro.core import ref
from repro.core.distributed import chol_update_sharded
from repro.kernels import sharded as sharded_k
from repro.runtime.compat import make_mesh_compat

out = []
n, k, panel = %(n)d, 16, 64
rng = np.random.default_rng(0)
B = rng.uniform(size=(n, n)).astype(np.float32)
V = rng.uniform(size=(n, k)).astype(np.float32)
A = B.T @ B + np.eye(n, dtype=np.float32)
L = jnp.array(np.linalg.cholesky(A).T); Vj = jnp.array(V)
for strategy in ("fused", "gemm"):
    for shape, axes in [((1,), ("model",)), ((4,), ("model",)), ((8,), ("model",))]:
        mesh = make_mesh_compat(shape, axes)
        before = sharded_k.launches_traced()
        with mesh:
            fn = lambda: chol_update_sharded(L, Vj, sigma=1, mesh=mesh, axis="model", panel=panel, strategy=strategy)
            r = jax.block_until_ready(fn())
            traced = sharded_k.launches_traced() - before
            t0 = time.perf_counter()
            for _ in range(3):
                r = jax.block_until_ready(fn())
            dt = (time.perf_counter() - t0) / 3
        err = float(jnp.max(jnp.abs(r - ref.chol_update_ref(L, Vj, sigma=1))))
        out.append({"strategy": strategy, "devices": shape[0], "us": dt * 1e6,
                    "err": err, "panel": panel, "launches_per_shard": traced,
                    "launches_expected": sharded_k.launch_count_sharded(n, panel, strategy=strategy)})

# --- fleet axis (DESIGN.md S10): stacked sharded fleets, 4 shards ---------
nf = %(nf)d
Bf = rng.uniform(size=(nf, nf)).astype(np.float32)
Af = Bf.T @ Bf + np.eye(nf, dtype=np.float32)
Lf = jnp.array(np.linalg.cholesky(Af).T)
Vf = jnp.array(rng.uniform(size=(nf, k)).astype(np.float32))
mesh4 = make_mesh_compat((4,), ("model",), devices=jax.devices()[:4])
for fleet in (1, 4, 8):
    Lb = jnp.broadcast_to(Lf, (fleet, nf, nf))
    Vb = jnp.broadcast_to(Vf, (fleet, nf, k))
    before = sharded_k.launches_traced()
    with mesh4:
        fn = lambda: chol_update_sharded(Lb, Vb, sigma=1, mesh=mesh4, axis="model", panel=panel, strategy="fused")
        r = jax.block_until_ready(fn())
        traced = sharded_k.launches_traced() - before
        t0 = time.perf_counter()
        for _ in range(3):
            r = jax.block_until_ready(fn())
        dt = (time.perf_counter() - t0) / 3
    err = float(jnp.max(jnp.abs(r[0] - ref.chol_update_ref(Lf, Vf, sigma=1))))
    out.append({"strategy": "fleet_fused", "devices": 4, "fleet": fleet,
                "us": dt * 1e6, "us_per_member": dt * 1e6 / fleet,
                "err": err, "panel": panel, "launches_per_shard": traced,
                "launches_expected": 1})
print(json.dumps(out))
"""


def run(csv_rows, *, quick=False):
    n = 512 if quick else 1024
    nf = 256 if quick else 512  # fleet members are the "outgrew one device" size
    repo = Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{repo / 'src'}:{env.get('PYTHONPATH', '')}"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_CODE % {"n": n, "nf": nf})],
        capture_output=True, text=True, env=env, timeout=900,
    )
    if res.returncode != 0:
        csv_rows.append(("distributed/error", 0.0, res.stderr[-200:]))
        return csv_rows
    rows = json.loads(res.stdout.strip().splitlines()[-1])
    base = {r["strategy"]: r["us"] for r in rows if r["devices"] == 1}
    for r in rows:
        s = r["strategy"]
        if "fleet" in r:
            csv_rows.append(
                (f"distributed/fleet_fused/n{nf}/dev4/B{r['fleet']}", r["us"],
                 f"err={r['err']:.2e} us_per_member={r['us_per_member']:.1f} "
                 f"launches_per_shard={r['launches_per_shard']} "
                 f"expected={r['launches_expected']} "
                 "(launches scale with shards, not B)")
            )
            continue
        csv_rows.append(
            (f"distributed/cholupdate_{s}/n{n}/dev{r['devices']}", r["us"],
             f"err={r['err']:.2e} speedup_vs_1dev={base[s] / r['us']:.2f}x "
             f"launches_per_shard={r['launches_per_shard']} "
             f"expected={r['launches_expected']} "
             f"(per-panel driver analogue: {n // r['panel']})")
        )
    return csv_rows
