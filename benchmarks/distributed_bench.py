"""Distributed rank-k update scaling (8 virtual devices) + optimizer bench.

Subprocess with forced device count so the main bench process keeps its
single-device config.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import jax, jax.numpy as jnp, numpy as np
from repro.core import ref
from repro.core.distributed import chol_update_sharded
from repro.runtime.compat import make_mesh_compat

out = []
n, k, panel = %(n)d, 16, 64
rng = np.random.default_rng(0)
B = rng.uniform(size=(n, n)).astype(np.float32)
V = rng.uniform(size=(n, k)).astype(np.float32)
A = B.T @ B + np.eye(n, dtype=np.float32)
L = jnp.array(np.linalg.cholesky(A).T); Vj = jnp.array(V)
for shape, axes in [((1,), ("model",)), ((4,), ("model",)), ((8,), ("model",))]:
    mesh = make_mesh_compat(shape, axes)
    with mesh:
        fn = lambda: chol_update_sharded(L, Vj, sigma=1, mesh=mesh, axis="model", panel=panel)
        r = jax.block_until_ready(fn())
        t0 = time.perf_counter()
        for _ in range(3):
            r = jax.block_until_ready(fn())
        dt = (time.perf_counter() - t0) / 3
    err = float(jnp.max(jnp.abs(r - ref.chol_update_ref(L, Vj, sigma=1))))
    out.append({"devices": shape[0], "us": dt * 1e6, "err": err})
print(json.dumps(out))
"""


def run(csv_rows, *, quick=False):
    n = 512 if quick else 1024
    repo = Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{repo / 'src'}:{env.get('PYTHONPATH', '')}"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_CODE % {"n": n})],
        capture_output=True, text=True, env=env, timeout=900,
    )
    if res.returncode != 0:
        csv_rows.append(("distributed/error", 0.0, res.stderr[-200:]))
        return csv_rows
    rows = json.loads(res.stdout.strip().splitlines()[-1])
    base = rows[0]["us"]
    for r in rows:
        csv_rows.append(
            (f"distributed/cholupdate/n{n}/dev{r['devices']}", r["us"],
             f"err={r['err']:.2e} speedup_vs_1dev={base / r['us']:.2f}x")
        )
    return csv_rows
