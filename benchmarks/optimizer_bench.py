"""Optimizer-layer bench: the paper's O(k d^2) maintenance claim in situ.

Compares, per step on a (d x d) parameter:
* cholesky_precond (rank-k up/down-dated factor, the paper's primitive),
* the same preconditioner maintained by full refactorization (O(d^3) chol
  of the accumulated statistics — what the paper replaces),
* adamw (first-order floor).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.optim as optim
from repro.core import chol_factor, ref


def run(csv_rows, *, quick=False):
    d = 256 if quick else 1024
    other = 64
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(d, other)).astype(np.float32))
    params = {"w": jnp.zeros((d, other), jnp.float32)}
    grads = {"w": g}

    def bench(opt):
        state = opt.init(params)
        upd = jax.jit(lambda g, s, p: opt.update(g, s, p))
        jax.block_until_ready(upd(grads, state, params))
        t0 = time.perf_counter()
        reps = 5
        for _ in range(reps):
            deltas, state = upd(grads, state, params)
            jax.block_until_ready(deltas)
        return (time.perf_counter() - t0) / reps

    t_chol = bench(optim.cholesky_precond(1e-3, rank=16, block_size=d))
    t_adam = bench(optim.adamw(1e-3))
    csv_rows.append((f"optimizer/cholesky_precond/d{d}", t_chol * 1e6,
                     f"rank16_blocked"))
    csv_rows.append((f"optimizer/adamw/d{d}", t_adam * 1e6, "first-order floor"))

    # Refactorization baseline: accumulate A += V V^T then chol(A) each step.
    A0 = jnp.eye(d) * 1e-2
    om = jnp.asarray(rng.normal(size=(other, 16)).astype(np.float32) / 4.0)

    @jax.jit
    def refact_step(A):
        v = g @ om
        A = A + v @ v.T
        return A, chol_factor(A)

    jax.block_until_ready(refact_step(A0))
    t0 = time.perf_counter()
    A = A0
    for _ in range(5):
        A, C = refact_step(A)
        jax.block_until_ready(C)
    t_ref = (time.perf_counter() - t0) / 5

    @jax.jit
    def update_step(C):
        v = g @ om
        return ref.chol_update_ref(C, v, sigma=1)

    C0 = chol_factor(A0)
    jax.block_until_ready(update_step(C0))
    t0 = time.perf_counter()
    C = C0
    for _ in range(5):
        C = update_step(C)
        jax.block_until_ready(C)
    t_upd = (time.perf_counter() - t0) / 5
    csv_rows.append((f"optimizer/refactorize_chol/d{d}", t_ref * 1e6,
                     "O(d^3) baseline the paper replaces"))
    csv_rows.append((f"optimizer/rank16_update/d{d}", t_upd * 1e6,
                     f"speedup_vs_refact={t_ref / t_upd:.2f}x"))
    return csv_rows
