"""Benchmark harness: one module per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV (deliverable d). ``--quick`` runs
reduced sizes (used by CI/tests); the full run is what EXPERIMENTS.md cites.
Roofline tables (deliverable g) are produced by repro.launch.dryrun and
summarised from benchmarks/results/*.jsonl by benchmarks/report.py.
"""
import argparse
import inspect
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--tiny", action="store_true",
                    help="CI-smoke sizes; suites that support it (stream) "
                         "run only their latency section")
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated subset: cholupdate,kernels,"
                         "distributed,optimizer,stream")
    args = ap.parse_args()

    from benchmarks import (
        cholupdate_bench,
        distributed_bench,
        kernel_bench,
        optimizer_bench,
        stream_bench,
    )

    suites = {
        "cholupdate": cholupdate_bench.run,     # paper Figs 2-3
        "kernels": kernel_bench.run,            # Pallas tiles / VMEM / AI
        "distributed": distributed_bench.run,   # multi-device scaling
        "optimizer": optimizer_bench.run,       # O(kd^2) vs O(d^3) in situ
        "stream": stream_bench.run,             # coalesce-width sweep (§9)
    }
    chosen = args.only.split(",") if args.only else list(suites)
    rows = []
    for name in chosen:
        fn = suites[name]
        kw = {"quick": args.quick or args.tiny}
        if args.tiny and "tiny" in inspect.signature(fn).parameters:
            kw["tiny"] = True
        fn(rows, **kw)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
