"""Paper Figures 2 & 3: rank-k up/down-date timing + error vs n.

The paper's experimental procedure (§5): B, V ~ U[0,1]^{n x n}, n x k;
update test A = B^T B + I; downdate test A = B^T B + I + V V^T; error
metric max_ij |A~ - L~^T L~|. The paper compares LINPACK dchud (CPU, serial
row sweeps) against the panelled GPU kernel. The CPU-container analogue
benchmarked here drives everything through the ``CholFactor`` object API
(so the numbers include the production dispatch path: registry resolution +
the Murray custom-derivative wrapper):

* ``reference``   — serial hyperbolic sweeps (the dchud role),
* ``paper``       — panelled, element-wise panel apply (the GPU kernel's
                    algorithm, bandwidth-bound),
* ``gemm``        — panelled, transform-GEMM panel apply (the TPU-native
                    adaptation; BLAS plays the MXU role on this host),
* ``fused``       — the single-launch pipelined Pallas kernel (DESIGN.md
                    §5), timed against the per-panel kernel cascade with
                    the launch-count delta recorded alongside wall-clock,
                    plus the 1-D indexed grid vs the clamped rectangular
                    grid (the grid-squash satellite).

Derived columns reproduce the paper's claims: the n^2 scaling exponent, the
panelled-vs-serial speedup and its crossover n, rank-16-vs-16x-rank-1
batching gain, and the error metric; plus the fused-vs-cascade launch and
wall-clock deltas and the batched (serving) throughput.

The ``dtypes`` axis (snapshot ``--dtype``, DESIGN.md §8) adds per-storage-
dtype rows for the gemm and fused paths recording bytes-per-update — the
bandwidth-bound quantity the paper says dominates — alongside wall-clock:
bf16 panels move exactly half the bytes of fp32 while the fp32 rotation
state costs no HBM traffic at all.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CholFactor, Precision, backends, ref
from repro.kernels import fused as fused_k
from repro.kernels import ops as kernel_ops


def make_problem(n, k, seed=0, downdate=False):
    rng = np.random.default_rng(seed)
    B = rng.uniform(size=(n, n)).astype(np.float32)
    V = rng.uniform(size=(n, k)).astype(np.float32)
    A = B.T @ B + np.eye(n, dtype=np.float32)
    if downdate:
        A = A + V @ V.T
    L = np.linalg.cholesky(A).T
    return jnp.asarray(L), jnp.asarray(V)


def time_call(fn, *args, reps=3, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps, out


def _reps_for(n):
    return 1 if n >= 2048 else 3


def _factor_update(backend, *, panel=256, interpret=None, precision=None):
    """Object-API update closure: the path every production consumer runs."""

    def fn(L, V, sigma):
        f = CholFactor.from_factor(L, panel=panel, backend=backend,
                                   interpret=interpret, precision=precision)
        return (f.update(V) if sigma == 1 else f.downdate(V)).data

    return fn


def run(csv_rows, *, ns=(512, 1024, 2048, 4096), ks=(16, 1), quick=False,
        dtypes=("float32",)):
    if quick:
        ns = (256, 512)
    # Every row records its execution mode (ISSUE 7): ``interpret=0|1`` so
    # report.py can footnote dispatch-bound interpret wall-clock, and
    # ``lowering=`` for the kernel rows (jnp rows record 'none'). The jnp
    # backends always XLA-compile — interpret only applies to Pallas.
    auto_lowering = backends.resolve_lowering("auto")

    def mode(interp=False, lowering="none"):
        return f"interpret={int(bool(interp))} lowering={lowering}"

    methods = {
        name: _factor_update(name) for name in ("reference", "paper", "gemm")
    }
    times = {}
    for k in ks:
        for n in ns:
            L, V = make_problem(n, k, seed=n + k)
            for name, fn in methods.items():
                if name == "reference" and n > 2048:
                    continue  # serial oracle too slow beyond this on 1 core
                dt, out = time_call(fn, L, V, 1, reps=_reps_for(n))
                err = float(ref.modify_error(out, L, V, sigma=1))
                times[(name, n, k)] = dt
                csv_rows.append(
                    (f"cholupdate/{name}/n{n}/k{k}", dt * 1e6,
                     f"err={err:.2e} {mode()}")
                )
            # downdate error parity (paper fig 2/3 bottom panels)
            L2, V2 = make_problem(n, k, seed=n + k, downdate=True)
            out = methods["gemm"](L2, V2, -1)
            errd = float(ref.modify_error(out, L2, V2, sigma=-1))
            csv_rows.append(
                (f"cholupdate/gemm_downdate/n{n}/k{k}", 0.0,
                 f"err={errd:.2e} {mode()}")
            )

    # Derived: scaling exponent for the gemm path at k=16 (expect ~2: O(kn^2))
    for k in ks:
        pts = [(n, times[("gemm", n, k)]) for n in ns if ("gemm", n, k) in times]
        if len(pts) >= 2:
            (n0, t0), (n1, t1) = pts[0], pts[-1]
            slope = np.log(t1 / t0) / np.log(n1 / n0)
            csv_rows.append(
                (f"cholupdate/scaling_exponent/k{k}", 0.0,
                 f"slope={slope:.2f} {mode()}")
            )
    # Derived: panelled-vs-serial speedup (paper: ~7x at n=5000, k=16)
    for k in ks:
        for n in ns:
            if ("reference", n, k) in times and ("gemm", n, k) in times:
                sp = times[("reference", n, k)] / times[("gemm", n, k)]
                csv_rows.append(
                    (f"cholupdate/speedup_gemm_vs_serial/n{n}/k{k}", 0.0,
                     f"speedup={sp:.2f}x {mode()}")
                )
    # Derived: rank-16 batching vs 16 sequential rank-1 (paper's k>1 motive)
    n = min(ns[-1], 1024)
    L, V = make_problem(n, 16, seed=5)
    gemm_up = _factor_update("gemm")
    t16, _ = time_call(lambda L, V: gemm_up(L, V, 1), L, V, reps=2)

    @jax.jit
    def seq_rank1(L, V):
        f = CholFactor.from_factor(L, panel=256, backend="gemm")
        for m in range(16):
            f = f.update(V[:, m : m + 1])
        return f.data

    tseq, _ = time_call(seq_rank1, L, V, reps=2)
    csv_rows.append(
        (f"cholupdate/rank16_batching_gain/n{n}", t16 * 1e6,
         f"vs_16x_rank1={tseq / t16:.2f}x {mode()}")
    )

    # --- fused single-launch pipeline vs the per-panel kernel cascade ------
    # Interpret mode only when NO lowering compiles here: the portable
    # lowering compiles on GPU too (ISSUE 7), so only pure-CPU hosts fall
    # back to interpret — and the recorded interpret=/lowering= tokens let
    # report.py footnote whichever happened. Wall-clock in interpret mode
    # is dispatch-bound, but the launch-count column is exact either way.
    interpret = backends.default_interpret(lowering="auto")
    fused_ns = (256,) if quick else (256, 512)
    kf = 16
    for n in fused_ns:
        panel_f = 64 if n <= 256 else 128
        L, V = make_problem(n, kf, seed=n + kf)
        fused_up = _factor_update("fused", panel=panel_f, interpret=interpret)
        t_fused, out_f = time_call(
            lambda L, V: fused_up(L, V, 1), L, V, reps=2,
        )
        t_casc, out_c = time_call(
            lambda L, V: kernel_ops.chol_update_pallas(
                L, V, sigma=1, panel=panel_f, strategy="gemm",
                block_w=panel_f, interpret=interpret
            ), L, V, reps=2,
        )
        # grid-squash satellite: 1-D indexed grid vs clamped rectangular —
        # both timed through the SAME direct kernel entry point (no object-
        # API dispatch on either side) so the ratio isolates the grid shape.
        t_idx, _ = time_call(
            lambda L, V: fused_k.chol_update_fused(
                L, V, sigma=1, panel=panel_f, grid_mode="indexed",
                interpret=interpret
            ), L, V, reps=2,
        )
        t_rect, _ = time_call(
            lambda L, V: fused_k.chol_update_fused(
                L, V, sigma=1, panel=panel_f, grid_mode="rect",
                interpret=interpret
            ), L, V, reps=2,
        )
        err_f = float(ref.modify_error(out_f, L, V, sigma=1))
        lc_f = fused_k.launch_count(n, panel_f, method="fused")
        lc_c = fused_k.launch_count(n, panel_f, method="pallas")
        lc_2 = fused_k.launch_count(n, panel_f, method="pallas_2phase")
        gs_i = fused_k.grid_steps(n, panel_f, grid_mode="indexed")
        gs_r = fused_k.grid_steps(n, panel_f, grid_mode="rect")
        csv_rows.append(
            (f"cholupdate/fused/n{n}/k{kf}", t_fused * 1e6,
             f"err={err_f:.2e} launches=1 "
             f"{mode(interpret, auto_lowering)}")
        )
        csv_rows.append(
            (f"cholupdate/fused_vs_cascade/n{n}/k{kf}", t_casc * 1e6,
             f"speedup={t_casc / t_fused:.2f}x "
             f"launches_cascade={lc_c} launches_2phase={lc_2} "
             f"launch_reduction={lc_c}->{lc_f} "
             f"{mode(interpret, auto_lowering)}")
        )
        csv_rows.append(
            (f"cholupdate/fused_grid_squash/n{n}/k{kf}", t_rect * 1e6,
             f"grid_steps={gs_r}->{gs_i} "
             f"rect_vs_indexed={t_rect / t_idx:.2f}x "
             f"{mode(interpret, auto_lowering)}")
        )
        # ISSUE 7: the two lowerings of the ONE fused kernel, timed through
        # the same direct entry point. On real GPU hardware the portable
        # row is the compiled single-launch path the tentpole adds; in
        # interpret mode both are dispatch-bound (the tokens say which).
        t_port, out_p = time_call(
            lambda L, V: fused_k.chol_update_fused(
                L, V, sigma=1, panel=panel_f, lowering="portable",
                interpret=interpret
            ), L, V, reps=2,
        )
        err_port = float(ref.modify_error(out_p, L, V, sigma=1))
        csv_rows.append(
            (f"cholupdate/fused_lowering/portable/n{n}/k{kf}", t_port * 1e6,
             f"err={err_port:.2e} mosaic_vs_portable={t_idx / t_port:.2f}x "
             f"launches=1 {mode(interpret, 'portable')}")
        )
        csv_rows.append(
            (f"cholupdate/fused_lowering/mosaic/n{n}/k{kf}", t_idx * 1e6,
             f"launches=1 {mode(interpret, 'mosaic')}")
        )

    # --- precision axis: storage dtype vs wall-clock AND bytes-per-update --
    # The paper calls the problem bandwidth-bound, so the decisive column is
    # bytes moved per update (exact, from the fused kernel's tile
    # accounting), recorded alongside wall-clock. Off-TPU interpret-mode
    # timing is dispatch-bound; the bytes column is hardware-independent.
    prec_n = 256 if quick else 512
    prec_panel = 64 if quick else 128
    kp = 16
    Lp, Vp = make_problem(prec_n, kp, seed=prec_n + kp)
    for dtype in dtypes:
        precision = None if dtype in ("float32", "f32") else dtype
        policy = Precision.parse(precision)
        storage = jnp.float32 if policy is None else policy.storage
        bytes_upd = fused_k.bytes_per_update(prec_n, prec_panel, kp,
                                             storage_dtype=storage)
        for backend in ("gemm", "fused"):
            upd = _factor_update(backend, panel=prec_panel,
                                 interpret=interpret, precision=precision)
            t_p, out_p = time_call(lambda L, V: upd(L, V, 1), Lp, Vp, reps=2)
            err_p = float(ref.modify_error(
                jnp.asarray(out_p, jnp.float32), Lp, Vp, sigma=1))
            csv_rows.append(
                (f"cholupdate/precision/{backend}/{dtype}/n{prec_n}/k{kp}",
                 t_p * 1e6,
                 f"err={err_p:.2e} bytes_per_update={bytes_upd} "
                 f"out_dtype={jnp.asarray(out_p).dtype} "
                 f"{mode(interpret, auto_lowering if backend == 'fused' else 'none')}")
            )

    # --- batched serving workload: B concurrent per-user updates -----------
    Bsz, nb, kb, panel_b = (4, 128, 8, 32) if quick else (8, 256, 8, 64)
    Ls, Vs = zip(*[make_problem(nb, kb, seed=500 + b) for b in range(Bsz)])
    Lb, Vb = jnp.stack(Ls), jnp.stack(Vs)

    def batched_update(Lb, Vb):
        f = CholFactor.from_factor(Lb, panel=panel_b, backend="fused",
                                   interpret=interpret)
        return f.update(Vb).data

    t_bat, out_b = time_call(batched_update, Lb, Vb, reps=2)

    @jax.jit
    def loop_singles(Lb, Vb):
        return jnp.stack([
            fused_k.chol_update_fused(
                Lb[b], Vb[b], sigma=1, panel=panel_b, interpret=interpret
            )
            for b in range(Bsz)
        ])

    t_loop, _ = time_call(loop_singles, Lb, Vb, reps=2)
    err_b = max(
        float(ref.modify_error(out_b[b], Ls[b], Vs[b], sigma=1))
        for b in range(Bsz)
    )
    csv_rows.append(
        (f"cholupdate/batched_fused/B{Bsz}n{nb}k{kb}", t_bat * 1e6,
         f"err={err_b:.2e} per_update_us={t_bat / Bsz * 1e6:.1f} "
         f"vs_loop_of_singles={t_loop / t_bat:.2f}x "
         f"{mode(interpret, auto_lowering)}")
    )
    return csv_rows
