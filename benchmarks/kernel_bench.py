"""Pallas kernel benches: interpret-mode correctness cost + VMEM accounting.

Wall-clock in interpret mode is not TPU performance; what we report per
kernel is (a) the paper error metric vs the oracle, (b) the BlockSpec VMEM
working set (the quantity that must fit the 16 MiB v5e VMEM and determines
the panel sizes used in the roofline), (c) arithmetic intensity of the
panel kernels — the paper's bandwidth-bound story vs the GEMM adaptation —
and (d) the launch count per up/down-date: the per-panel cascade's
O(n/panel) dispatches vs the fused pipeline's single ``pallas_call``
(DESIGN.md §5).
"""
from __future__ import annotations

import numpy as np

from repro.core import blocked, ref
from repro.kernels import fused as fused_k
from repro.kernels import ops


def vmem_bytes_paper(P, k, bw, dtype_bytes=4):
    # L tile + V^T tile + (c, s) panels resident per grid step
    return (P * bw + k * bw + 2 * P * k) * dtype_bytes


def vmem_bytes_gemm(P, k, bw, dtype_bytes=4):
    return ((P + k) * (P + k) + (P + k) * bw * 2) * dtype_bytes


def vmem_bytes_fused(P, k, n, dtype_bytes=4):
    # L tile (in+out) + the (k, n) V^T INPUT block (its own pallas buffer,
    # constant index map) + the (k, n) V^T scratch + parked T, c, s scratch
    return (2 * P * P + 2 * k * n + (P + k) ** 2 + 2 * P * k) * dtype_bytes


def run(csv_rows, *, quick=False):
    import jax.numpy as jnp

    n, k, panel, bw = (256, 8, 64, 64) if quick else (512, 16, 128, 128)
    rng = np.random.default_rng(0)
    B = rng.uniform(size=(n, n)).astype(np.float32)
    V = rng.uniform(size=(n, k)).astype(np.float32)
    A = B.T @ B + np.eye(n, dtype=np.float32)
    L = jnp.asarray(np.linalg.cholesky(A).T)
    Vj = jnp.asarray(V)
    L_ref = ref.chol_update_ref(L, Vj, sigma=1)
    for strat in ("paper", "gemm"):
        out = ops.chol_update_pallas(L, Vj, sigma=1, panel=panel,
                                     strategy=strat, block_w=bw, interpret=True)
        err = float(np.max(np.abs(np.asarray(out - L_ref))))
        lc = fused_k.launch_count(n, panel, method="pallas")
        csv_rows.append((f"pallas/{strat}/n{n}k{k}", 0.0,
                         f"maxdiff_vs_oracle={err:.2e} launches={lc}"))
    for strat in ("gemm", "paper"):
        out = fused_k.chol_update_fused(L, Vj, sigma=1, panel=panel,
                                        panel_apply=strat, interpret=True)
        err = float(np.max(np.abs(np.asarray(out - L_ref))))
        csv_rows.append((f"pallas/fused_{strat}/n{n}k{k}", 0.0,
                         f"maxdiff_vs_oracle={err:.2e} launches=1"))
    # launch-count scaling: the cascade grows O(n/panel); fused stays 1
    for nn in (1024, 4096, 16384):
        lc_c = fused_k.launch_count(nn, 256, method="pallas")
        lc_2 = fused_k.launch_count(nn, 256, method="pallas_2phase")
        csv_rows.append(
            (f"pallas/launches/n{nn}P256", 0.0,
             f"cascade={lc_c} two_phase={lc_2} fused=1")
        )
    # VMEM working sets for the production tile choices (P=256, bw=512, k=16)
    for P, kk, bw2 in [(256, 16, 512), (128, 16, 1024), (256, 1, 512)]:
        vb_p = vmem_bytes_paper(P, kk, bw2)
        vb_g = vmem_bytes_gemm(P, kk, bw2)
        # arithmetic intensity: flops per HBM byte of the panel tile
        ai_paper = (6.0 * kk * P * bw2) / (2 * (P + kk) * bw2 * 4)
        ai_gemm = (2.0 * (P + kk) ** 2 * bw2) / (2 * (P + kk) * bw2 * 4)
        csv_rows.append(
            (f"pallas/vmem/P{P}k{kk}bw{bw2}", 0.0,
             f"paper={vb_p/2**20:.2f}MiB gemm={vb_g/2**20:.2f}MiB "
             f"AI_paper={ai_paper:.1f} AI_gemm={ai_gemm:.1f}flops/B")
        )
    # fused working set incl. the whole-launch (k, n) V^T input + scratch —
    # bounds the n the fusion can serve. Budget is 14 of the 16 MiB v5e
    # VMEM: ~2 MiB headroom for Mosaic spills and the double-buffered L
    # tiles the element-count model below does not include (DESIGN.md §5).
    vmem_budget = 14 * 2**20
    for P, kk, nn in [(256, 16, 4096), (256, 16, 16384), (128, 16, 65536)]:
        vb_f = vmem_bytes_fused(P, kk, nn)
        csv_rows.append(
            (f"pallas/vmem_fused/P{P}k{kk}n{nn}", 0.0,
             f"fused={vb_f/2**20:.2f}MiB fits_v5e={vb_f < vmem_budget}")
        )
    return csv_rows
