"""Streaming-service benchmark: updates/sec and bytes/row vs coalesce width.

The paper's rank-k amortization claim (~7x at k=16) restated as a serving
metric: a fleet of B users each produces R rank-1 observations; the
``StreamService`` coalesces them in per-user ring buffers and flushes as
fused batched rank-k mutations. Sweeping the coalesce width 1 -> 32 shows

* **updates/sec** — absorbed rank-1 rows per wall-clock second through the
  full production path (ring push, zero-padded block build, donated jitted
  step, registry dispatch). Off-TPU interpret mode is dispatch-bound, so
  the sweep measures exactly what coalescing removes: per-mutation launch
  overhead. width=1 pays one batched mutation per row; width=16 amortizes
  it 16x.
* **bytes/row** — the hardware-independent bandwidth accounting from the
  fused kernel's tile arithmetic (``fused.bytes_per_update(n, panel, k) /
  k``): the whole factor is read+written once per *mutation* regardless of
  k, so bytes per absorbed row falls ~k-fold — the paper's economics.
* **mutations** — the instrumented batched-mutation count
  (``repro.stream.store.mutations_issued``), asserting the coalescing
  ratio rather than inferring it.

The ``dtypes`` axis records the bf16-storage bytes/row halving at the
paper's k=16 sweet spot (DESIGN.md §8), and the ``stream/structured/*``
row drives a blocktridiag fleet through the same loop, recording its
O(n·b) bytes/row and resident factor bytes against a dense fleet at
matched n (DESIGN.md §12). Rows land in
``benchmarks/results/BENCH_stream.json`` via ``scripts/bench.sh``.

The **latency section** (``stream/latency/*``, DESIGN.md §11) measures
what the AOT warmup layer buys: first-flush latency on a cold store
(tracing + XLA compile on the serving path) vs on a ``warmup()``-ed
store (pre-compiled executable dispatch), plus steady-state flush
p50/p99. The trace-stall delta is the paper-scale argument for the
bucket ladder — a multi-millisecond compile against a sub-millisecond
flush. ``tiny=True`` (CI smoke, ``benchmarks.run --tiny``) runs ONLY
this section at minimal sizes.

Every derived field carries ``interpret=0|1``: off-TPU rows run the
fused kernels in Pallas interpret mode, whose wall-clock is
dispatch-bound Python, not kernel performance — the report renderer
tags such rows so they are not misread as hardware measurements.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision import Precision
from repro.kernels import blocktridiag as btd_k
from repro.kernels import fused as fused_k
from repro.obs import metrics as obs_metrics
from repro.stream import FactorStore, StreamService
from repro.stream import store as store_mod


def _flush_hist(snapshot):
    """Merge every ``repro.stream.flush_seconds`` series of a (diffed)
    registry snapshot into one histogram entry; None when empty."""
    merged = None
    for key, h in snapshot.get("histograms", {}).items():
        if not key.startswith("repro.stream.flush_seconds"):
            continue
        if merged is None:
            merged = {"count": 0, "sum": 0.0, "edges": h["edges"],
                      "counts": [0] * len(h["counts"])}
        merged["count"] += h["count"]
        merged["sum"] += h["sum"]
        merged["counts"] = [a + b
                            for a, b in zip(merged["counts"], h["counts"])]
    return merged if merged and merged["count"] else None


def _drive(*, B, n, R, width, panel, interpret, precision=None, seed=0):
    """Push B*R rank-1 rows through a fresh service, flushing every
    ``width`` rows per user; returns (seconds, mutations)."""
    rng = np.random.default_rng(seed)
    rows = (0.1 * rng.normal(size=(R, B, n))).astype(np.float32)
    store = FactorStore(n, capacity=B, width=width, panel=panel,
                        backend="fused", interpret=interpret,
                        precision=precision)
    svc = StreamService(store, auto_flush=False)
    for u in range(B):
        svc.admit(u)
    m0 = store_mod.mutations_issued()
    t0 = time.perf_counter()
    for t in range(R):
        for u in range(B):
            svc.push(u, rows[t, u])
        if (t + 1) % width == 0:
            svc.flush()
    jax.block_until_ready(store.factor.data)
    return time.perf_counter() - t0, store_mod.mutations_issued() - m0


def _percentile(xs, q) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q))


def latency(csv_rows, *, quick=False, tiny=False):
    """First-flush vs steady-state flush latency (cold / warm / p50 / p99).

    Each drive starts from a CLEARED step cache (``_steps_for``), so the
    cold drive pays tracing + compilation inside its first flush exactly
    like a fresh serving process would, and the warm drive pays it inside
    ``warmup()`` instead — the flush loop then only dispatches.
    """
    interpret = jax.default_backend() != "tpu"
    if tiny:
        B, n, width, panel, flushes = 2, 16, 4, 8, 5
    elif quick:
        B, n, width, panel, flushes = 4, 64, 8, 32, 20
    else:
        B, n, width, panel, flushes = 8, 128, 16, 32, 50
    rng = np.random.default_rng(7)
    rows = (0.1 * rng.normal(size=((flushes + 1) * width, B, n))
            ).astype(np.float32)

    def drive(warm):
        store_mod._steps_for.cache_clear()   # fresh-process simulation
        store = FactorStore(n, capacity=B, width=width, panel=panel,
                            backend="fused", interpret=interpret)
        svc = StreamService(store, auto_flush=False)
        if warm:
            store.warmup(rungs=(store.capacity,))
        traces0 = store_mod.traces_counted()
        for u in range(B):
            svc.admit(u)
        lat = []
        for f in range(flushes + 1):
            for j in range(width):
                for u in range(B):
                    svc.push(u, rows[f * width + j, u])
            t0 = time.perf_counter()
            svc.flush(force=True)
            jax.block_until_ready(store.factor.data)
            lat.append((time.perf_counter() - t0) * 1e6)
        return lat, store_mod.traces_counted() - traces0

    cold, cold_traces = drive(warm=False)
    # Diff the process-cumulative registry around the warm drive: the
    # service's OWN flush-latency histogram over exactly these flushes —
    # cross-checking the benchmark's external perf_counter percentiles
    # against the numbers the serving stack reports about itself.
    snap0 = obs_metrics.snapshot()
    warm, warm_traces = drive(warm=True)
    delta = obs_metrics.diff_snapshots(snap0, obs_metrics.snapshot())
    steady = warm[1:]
    p50, p99 = _percentile(steady, 50), _percentile(steady, 99)
    svc = ""
    hist = _flush_hist(delta)
    if hist:
        svc = (f"svc_p50_us="
               f"{obs_metrics.percentile_from(hist, 50) * 1e6:.0f} "
               f"svc_p99_us="
               f"{obs_metrics.percentile_from(hist, 99) * 1e6:.0f} "
               f"svc_flushes={hist['count']} ")
    csv_rows.append(
        (f"stream/latency/first_flush/B{B}n{n}w{width}", warm[0],
         f"cold_first_us={cold[0]:.1f} warm_first_us={warm[0]:.1f} "
         f"trace_stall_us={cold[0] - warm[0]:.1f} "
         f"traces_cold={cold_traces} traces_warm={warm_traces} "
         f"interpret={int(interpret)}")
    )
    csv_rows.append(
        (f"stream/latency/steady/B{B}n{n}w{width}", p50,
         f"steady_p50_us={p50:.1f} steady_p99_us={p99:.1f} {svc}"
         f"warm_first_over_p50={warm[0] / p50:.2f} "
         f"steady_within_2x_first={int(p50 <= 2 * warm[0])} "
         f"interpret={int(interpret)}")
    )
    return csv_rows


def structured(csv_rows, *, quick=False):
    """Structured-fleet axis (ISSUE 10): a blocktridiag fleet through the
    same serving loop, against a dense fleet at matched n.

    The quantities are the modeled-bandwidth accounting the O(n·b) claim
    lives in, not interpret-mode wall-clock: ``bytes_per_row`` from the
    block-chain kernel's tile arithmetic (every diag/off block read+written
    once per mutation, amortized over the coalesce width) vs the dense
    fused kernel's O(n²) traffic, and ``factor_bytes`` — resident (2nb-1)b²
    vs n² per fleet member. The drive itself just proves the structured
    path absorbs real traffic end to end (anchor-keyed rings, batched
    block-chain flush) and reports the mutation count.
    """
    interpret = jax.default_backend() != "tpu"
    B, nb, b, width = (2, 4, 8, 4) if quick else (4, 8, 16, 16)
    n = nb * b
    rng = np.random.default_rng(11)
    R = 2 * width
    # Block-local traffic: each row supported on one adjacent block-row
    # pair {j, j+1} — the coalescer's push-time contract for structured
    # fleets (DESIGN.md §9).
    rows = np.zeros((R, B, n), np.float32)
    for t in range(R):
        for u in range(B):
            j = int(rng.integers(0, max(nb - 1, 1)))
            rows[t, u, j * b:(j + 2) * b] = (
                0.1 * rng.normal(size=min(2 * b, n - j * b)))

    store = FactorStore(n, capacity=B, width=width, panel=b,
                        backend="blocktridiag", interpret=interpret,
                        structure="blocktridiag", block=b)
    svc = StreamService(store, auto_flush=False)
    for u in range(B):
        svc.admit(u)
    m0 = store_mod.mutations_issued()
    t0 = time.perf_counter()
    for t in range(R):
        for u in range(B):
            svc.push(u, rows[t, u])
        if (t + 1) % width == 0:
            svc.flush()
    jax.block_until_ready(jax.tree_util.tree_leaves(store.factor.data))
    dt, muts = time.perf_counter() - t0, store_mod.mutations_issued() - m0

    f32 = jnp.float32
    btd_row = btd_k.bytes_per_update(nb, b, width, storage_dtype=f32) // width
    dense_row = fused_k.bytes_per_update(
        n, b, width, storage_dtype=f32) // width
    btd_factor = btd_k.factor_bytes(nb, b, storage_dtype=f32)
    dense_factor = n * n * 4
    csv_rows.append(
        (f"stream/structured/blocktridiag/B{B}n{n}b{b}w{width}",
         dt / (B * R) * 1e6,
         f"bytes_per_row={btd_row} dense_bytes_per_row={dense_row} "
         f"bytes_ratio={dense_row / btd_row:.2f} "
         f"factor_bytes={btd_factor} dense_factor_bytes={dense_factor} "
         f"factor_ratio={dense_factor / btd_factor:.2f} "
         f"mutations={muts} interpret={int(interpret)}")
    )
    return csv_rows


def run(csv_rows, *, quick=False, dtypes=("float32",), tiny=False):
    if tiny:
        # CI smoke: the latency section alone at minimal sizes.
        return latency(csv_rows, tiny=True)
    interpret = jax.default_backend() != "tpu"
    B, n, R, panel = (4, 64, 32, 32) if quick else (8, 128, 64, 32)
    widths = (1, 2, 4, 8, 16, 32)

    ups = {}
    for width in widths:
        # Warmup drive compiles the jitted steps for this width's shapes
        # (the step cache is shared across stores with equal metadata), so
        # the timed drive measures the serving loop, not tracing.
        _drive(B=B, n=n, R=max(width, 8), width=width, panel=panel,
               interpret=interpret, seed=1)
        dt, muts = _drive(B=B, n=n, R=R, width=width, panel=panel,
                          interpret=interpret, seed=2)
        rows_total = B * R
        ups[width] = rows_total / dt
        bytes_row = fused_k.bytes_per_update(
            n, panel, width, storage_dtype=jnp.float32) // width
        csv_rows.append(
            (f"stream/width{width}/B{B}n{n}", dt / rows_total * 1e6,
             f"updates_per_s={ups[width]:.0f} bytes_per_row={bytes_row} "
             f"mutations={muts} interpret={int(interpret)}")
        )

    # The acceptance headline: coalesced k=16 vs k=1 sequential absorption.
    csv_rows.append(
        (f"stream/coalesce_gain_k16_vs_k1/B{B}n{n}", 0.0,
         f"speedup={ups[16] / ups[1]:.2f}x "
         f"updates_per_s_k16={ups[16]:.0f} updates_per_s_k1={ups[1]:.0f} "
         f"interpret={int(interpret)}")
    )

    # Storage-dtype axis at the paper's sweet spot: bytes/row is the
    # bandwidth-bound quantity; bf16 halves it (DESIGN.md §8).
    for dtype in dtypes:
        precision = None if dtype in ("float32", "f32") else dtype
        policy = Precision.parse(precision)
        storage = jnp.float32 if policy is None else policy.storage
        # Per-precision warmup: each policy is a distinct step-cache entry
        # (and fleet dtype), so the first drive traces — keep it untimed.
        _drive(B=B, n=n, R=16, width=16, panel=panel,
               interpret=interpret, precision=precision, seed=1)
        dt, muts = _drive(B=B, n=n, R=16, width=16, panel=panel,
                          interpret=interpret, precision=precision, seed=3)
        bytes_row = fused_k.bytes_per_update(
            n, panel, 16, storage_dtype=storage) // 16
        csv_rows.append(
            (f"stream/precision/{dtype}/B{B}n{n}k16", dt / (B * 16) * 1e6,
             f"bytes_per_row={bytes_row} mutations={muts} "
             f"interpret={int(interpret)}")
        )

    structured(csv_rows, quick=quick)
    return latency(csv_rows, quick=quick)
