"""Streaming-service benchmark: updates/sec and bytes/row vs coalesce width.

The paper's rank-k amortization claim (~7x at k=16) restated as a serving
metric: a fleet of B users each produces R rank-1 observations; the
``StreamService`` coalesces them in per-user ring buffers and flushes as
fused batched rank-k mutations. Sweeping the coalesce width 1 -> 32 shows

* **updates/sec** — absorbed rank-1 rows per wall-clock second through the
  full production path (ring push, zero-padded block build, donated jitted
  step, registry dispatch). Off-TPU interpret mode is dispatch-bound, so
  the sweep measures exactly what coalescing removes: per-mutation launch
  overhead. width=1 pays one batched mutation per row; width=16 amortizes
  it 16x.
* **bytes/row** — the hardware-independent bandwidth accounting from the
  fused kernel's tile arithmetic (``fused.bytes_per_update(n, panel, k) /
  k``): the whole factor is read+written once per *mutation* regardless of
  k, so bytes per absorbed row falls ~k-fold — the paper's economics.
* **mutations** — the instrumented batched-mutation count
  (``repro.stream.store.mutations_issued``), asserting the coalescing
  ratio rather than inferring it.

The ``dtypes`` axis records the bf16-storage bytes/row halving at the
paper's k=16 sweet spot (DESIGN.md §8). Rows land in
``benchmarks/results/BENCH_stream.json`` via ``scripts/bench.sh``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision import Precision
from repro.kernels import fused as fused_k
from repro.stream import FactorStore, StreamService
from repro.stream import store as store_mod


def _drive(*, B, n, R, width, panel, interpret, precision=None, seed=0):
    """Push B*R rank-1 rows through a fresh service, flushing every
    ``width`` rows per user; returns (seconds, mutations)."""
    rng = np.random.default_rng(seed)
    rows = (0.1 * rng.normal(size=(R, B, n))).astype(np.float32)
    store = FactorStore(n, capacity=B, width=width, panel=panel,
                        backend="fused", interpret=interpret,
                        precision=precision)
    svc = StreamService(store, auto_flush=False)
    for u in range(B):
        svc.admit(u)
    m0 = store_mod.mutations_issued()
    t0 = time.perf_counter()
    for t in range(R):
        for u in range(B):
            svc.push(u, rows[t, u])
        if (t + 1) % width == 0:
            svc.flush()
    jax.block_until_ready(store.factor.data)
    return time.perf_counter() - t0, store_mod.mutations_issued() - m0


def run(csv_rows, *, quick=False, dtypes=("float32",)):
    interpret = jax.default_backend() != "tpu"
    B, n, R, panel = (4, 64, 32, 32) if quick else (8, 128, 64, 32)
    widths = (1, 2, 4, 8, 16, 32)

    ups = {}
    for width in widths:
        # Warmup drive compiles the jitted steps for this width's shapes
        # (the step cache is shared across stores with equal metadata), so
        # the timed drive measures the serving loop, not tracing.
        _drive(B=B, n=n, R=max(width, 8), width=width, panel=panel,
               interpret=interpret, seed=1)
        dt, muts = _drive(B=B, n=n, R=R, width=width, panel=panel,
                          interpret=interpret, seed=2)
        rows_total = B * R
        ups[width] = rows_total / dt
        bytes_row = fused_k.bytes_per_update(
            n, panel, width, storage_dtype=jnp.float32) // width
        csv_rows.append(
            (f"stream/width{width}/B{B}n{n}", dt / rows_total * 1e6,
             f"updates_per_s={ups[width]:.0f} bytes_per_row={bytes_row} "
             f"mutations={muts}")
        )

    # The acceptance headline: coalesced k=16 vs k=1 sequential absorption.
    csv_rows.append(
        (f"stream/coalesce_gain_k16_vs_k1/B{B}n{n}", 0.0,
         f"speedup={ups[16] / ups[1]:.2f}x "
         f"updates_per_s_k16={ups[16]:.0f} updates_per_s_k1={ups[1]:.0f}")
    )

    # Storage-dtype axis at the paper's sweet spot: bytes/row is the
    # bandwidth-bound quantity; bf16 halves it (DESIGN.md §8).
    for dtype in dtypes:
        precision = None if dtype in ("float32", "f32") else dtype
        policy = Precision.parse(precision)
        storage = jnp.float32 if policy is None else policy.storage
        # Per-precision warmup: each policy is a distinct step-cache entry
        # (and fleet dtype), so the first drive traces — keep it untimed.
        _drive(B=B, n=n, R=16, width=16, panel=panel,
               interpret=interpret, precision=precision, seed=1)
        dt, muts = _drive(B=B, n=n, R=16, width=16, panel=panel,
                          interpret=interpret, precision=precision, seed=3)
        bytes_row = fused_k.bytes_per_update(
            n, panel, 16, storage_dtype=storage) // 16
        csv_rows.append(
            (f"stream/precision/{dtype}/B{B}n{n}k16", dt / (B * 16) * 1e6,
             f"bytes_per_row={bytes_row} mutations={muts}")
        )
    return csv_rows
