"""Block-tridiagonal backend bench: the O(n·b) memory story vs dense.

ISSUE 8's acceptance quantity is bytes, not wall-clock: at matched factor
order n, the structured rank-k update moves O(n·b) HBM bytes per sign
block where the dense fused kernel moves O(n²) — the gap IS the paper's
O(n) GPU-memory claim realised, and it widens as 1/b · n. Each row records

* ``bytes_update``  — ``blocktridiag.bytes_per_update`` (diag+off tiles
  read+written once, V^T loaded once) vs ``fused.bytes_per_update`` at the
  same n/k/dtype;
* ``bytes_factor``  — resident factor bytes, (2·nb−1)·b² vs n²;
* wall-clock of the lax.scan twin vs the dense gemm driver (both pure
  jnp, so the comparison is honest on any host), and of the Pallas kernel
  tagged ``interpret=True`` off-accelerator — interpret wall-clock is
  dispatch overhead, not kernel performance (same caveat as every kernel
  bench in this suite).

Sweeps block size b at fixed n: the bytes ratio scales like n/(4b), so
small blocks are where the structured layout pays off hardest.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import api, backends
from repro.core.structure import BlockTriDiagStorage
from repro.kernels import blocktridiag as btd_k
from repro.kernels import fused as fused_k


def _timeit(fn, *, reps=3):
    import jax

    jax.block_until_ready(fn())  # warm (trace + compile)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def _banded(nb, b, k, dtype, seed=0):
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    diag = (np.triu(rng.uniform(0.2, 1.0, size=(nb, b, b)))
            + 2.0 * np.eye(b)).astype(np.float32)
    off = (0.3 * rng.uniform(-1.0, 1.0, size=(nb - 1, b, b))
           ).astype(np.float32)
    n = nb * b
    V = np.zeros((n, k), np.float32)
    for c in range(k):
        j = int(rng.integers(nb - 1))
        V[j * b:(j + 2) * b, c] = 0.4 * rng.normal(size=2 * b)
    S = BlockTriDiagStorage(jnp.asarray(diag), jnp.asarray(off))
    return S.astype(jnp.dtype(dtype)), jnp.asarray(V, jnp.dtype(dtype))


def run(csv_rows, *, quick=False, dtypes=("float32",)):
    import jax.numpy as jnp

    n, k, panel = (512, 4, 64) if quick else (4096, 8, 256)
    blocks = (16, 32, 64) if quick else (32, 64, 128)
    interpret = backends.default_interpret()
    for dtype in dtypes:
        dt = jnp.dtype(dtype)
        dense_up = fused_k.bytes_per_update(n, panel, k, storage_dtype=dt)
        dense_factor = n * n * dt.itemsize
        for b in blocks:
            nb = n // b
            S, V = _banded(nb, b, k, dt)
            bb = btd_k.bytes_per_update(nb, b, k, storage_dtype=dt)
            bf = btd_k.factor_bytes(nb, b, storage_dtype=dt)
            us_kernel = _timeit(lambda: api.chol_update(
                S, V, method="blocktridiag", interpret=interpret))
            us_ref = _timeit(lambda: api.chol_update(
                S, V, method="blocktridiag_ref"))
            csv_rows.append((
                f"blocktridiag/n{n}b{b}k{k}/{dtype}", us_kernel,
                f"bytes_update={bb} dense_update={dense_up} "
                f"ratio={dense_up / bb:.1f}x bytes_factor={bf} "
                f"dense_factor={dense_factor} launches=1 "
                f"interpret={int(interpret)} scan_twin_us={us_ref:.1f}"))
        # The dense wall-clock twin at matched n: the pure-jnp gemm driver
        # (one row per dtype — it has no block-size axis).
        rng = np.random.default_rng(1)
        B = rng.uniform(size=(n, n)).astype(np.float32)
        A = B.T @ B + np.eye(n, dtype=np.float32)
        L = jnp.asarray(np.linalg.cholesky(A).T, dt)
        Vd = jnp.asarray(rng.uniform(size=(n, k)).astype(np.float32), dt)
        us_dense = _timeit(lambda: api.chol_update(
            L, Vd, method="gemm", panel=panel))
        csv_rows.append((
            f"blocktridiag/dense_gemm_twin/n{n}k{k}/{dtype}", us_dense,
            f"bytes_update={dense_up} bytes_factor={dense_factor} "
            f"interpret=0"))
    return csv_rows
