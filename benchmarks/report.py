"""Render the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dryrun JSONL records.

  PYTHONPATH=src python -m benchmarks.report
"""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results"


def load(tag):
    path = RESULTS / f"dryrun_{tag}.jsonl"
    if not path.exists():
        return []
    return [json.loads(l) for l in path.open()]


def fmt_bytes(b):
    if b >= 2**30:
        return f"{b / 2**30:.1f}G"
    return f"{b / 2**20:.0f}M"


def roofline_table(recs):
    lines = [
        "| arch | shape | kind | compute s | memory s | collective s | "
        "bottleneck | MODEL/HLO | args/dev | temp/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                         f"ERROR | — | — | — |")
            continue
        ma = r.get("memory_analysis") or {}
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} | {r['bottleneck']} "
            f"| {r['useful_ratio']:.2f} "
            f"| {fmt_bytes(ma.get('argument_bytes', 0))} "
            f"| {fmt_bytes(ma.get('temp_bytes', 0))} |"
        )
    return "\n".join(lines)


def dryrun_summary(recs, tag):
    ok = [r for r in recs if "error" not in r]
    colls = {}
    for r in ok:
        for k, v in (r.get("collectives") or {}).items():
            colls[k] = colls.get(k, 0.0) + v
    lines = [
        f"**{tag}**: {len(ok)}/{len(recs)} cells lowered+compiled; "
        f"mean compile {sum(r['compile_s'] for r in ok)/max(len(ok),1):.1f}s; "
        f"collective mix (bytes/device summed over cells): "
        + ", ".join(f"{k}={fmt_bytes(v)}" for k, v in sorted(colls.items())
                    if k != "total"),
    ]
    return "\n".join(lines)


def main():
    for tag in ("singlepod", "multipod", "technique"):
        recs = load(tag)
        if not recs:
            continue
        print(f"\n### Mesh: {tag}\n")
        print(dryrun_summary(recs, tag))
        print()
        print(roofline_table(recs))


if __name__ == "__main__":
    main()
