"""Render the EXPERIMENTS.md tables: §Dry-run and §Roofline from the
dryrun JSONL records, plus the perf-trajectory snapshots — the
``--dtype`` precision axis of ``BENCH_cholupdate.json`` (bytes-per-update
vs storage dtype, DESIGN.md §8) and the streaming-service coalesce-width
sweep of ``BENCH_stream.json`` (updates/sec and bytes/row, DESIGN.md §9).

  PYTHONPATH=src python -m benchmarks.report
"""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results"


def load(tag):
    path = RESULTS / f"dryrun_{tag}.jsonl"
    if not path.exists():
        return []
    return [json.loads(l) for l in path.open()]


def fmt_bytes(b):
    if b >= 2**30:
        return f"{b / 2**30:.1f}G"
    return f"{b / 2**20:.0f}M"


def roofline_table(recs):
    lines = [
        "| arch | shape | kind | compute s | memory s | collective s | "
        "bottleneck | MODEL/HLO | args/dev | temp/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                         f"ERROR | — | — | — |")
            continue
        ma = r.get("memory_analysis") or {}
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} | {r['bottleneck']} "
            f"| {r['useful_ratio']:.2f} "
            f"| {fmt_bytes(ma.get('argument_bytes', 0))} "
            f"| {fmt_bytes(ma.get('temp_bytes', 0))} |"
        )
    return "\n".join(lines)


def dryrun_summary(recs, tag):
    ok = [r for r in recs if "error" not in r]
    colls = {}
    for r in ok:
        for k, v in (r.get("collectives") or {}).items():
            colls[k] = colls.get(k, 0.0) + v
    lines = [
        f"**{tag}**: {len(ok)}/{len(recs)} cells lowered+compiled; "
        f"mean compile {sum(r['compile_s'] for r in ok)/max(len(ok),1):.1f}s; "
        f"collective mix (bytes/device summed over cells): "
        + ", ".join(f"{k}={fmt_bytes(v)}" for k, v in sorted(colls.items())
                    if k != "total"),
    ]
    return "\n".join(lines)


def load_snapshot(filename):
    """Line-delimited snapshot records (newest last); [] when absent."""
    path = RESULTS / filename
    if not path.exists():
        return []
    return [json.loads(l) for l in path.open() if l.strip()]


def parse_derived(derived):
    """'err=1e-3 bytes_per_update=42 speedup=2x' -> dict of the pairs."""
    out = {}
    for tok in derived.split():
        if "=" in tok:
            k, v = tok.split("=", 1)
            out[k] = v
    return out


def row_mode(row, rec):
    """'compiled' or '⚠ interpret' for a snapshot row.

    New rows record ``interpret=0|1`` in their derived fields; older
    records predate the tag, so fall back to the snapshot's backend —
    off-TPU/GPU runs execute the Pallas kernels in interpret mode, whose
    wall-clock is dispatch-bound Python. Without the tag a row like
    bf16-slower-than-fp32 reads as a real hardware measurement; it is
    not, and the tables must say so.
    """
    d = parse_derived(row.get("derived", ""))
    if "interpret" in d:
        interp = d["interpret"] == "1"
    else:
        interp = rec.get("backend") not in ("tpu", "gpu")
    return "⚠ interpret" if interp else "compiled"


def row_lowering(row, rec):
    """Which fused-kernel lowering produced a row (ISSUE 7).

    Per-row ``lowering=`` tokens win (rows that pin a lowering, e.g. the
    fused_lowering comparison pair); otherwise the snapshot's top-level
    ``lowering`` field (what resolve('auto') picked on that host); '—'
    for records predating both. jnp-path rows record 'none' — they never
    touch Pallas, so the column stays honest about which rows the
    mosaic/portable split can even affect.
    """
    d = parse_derived(row.get("derived", ""))
    return d.get("lowering", rec.get("lowering", "—"))


def precision_table(rec):
    """The --dtype axis PR 3 added: per-storage-dtype rows of the
    cholupdate suite (previously ignored by this report)."""
    lines = [
        "| backend | dtype | us/update | err | bytes/update | lowering | mode |",
        "|---|---|---|---|---|---|---|",
    ]
    found = False
    for row in rec.get("rows", []):
        parts = row["name"].split("/")
        if len(parts) < 4 or parts[1] != "precision":
            continue
        found = True
        d = parse_derived(row["derived"])
        lines.append(
            f"| {parts[2]} | {parts[3]} | {row['us']:.1f} "
            f"| {d.get('err', '—')} | {d.get('bytes_per_update', '—')} "
            f"| {row_lowering(row, rec)} | {row_mode(row, rec)} |"
        )
    if not found:
        return None
    return "\n".join(lines + ["", _interpret_note(rec)])


def fused_lowering_table(rec):
    """The ISSUE 7 mosaic-vs-portable comparison pair: the SAME fused
    kernel body timed through both lowerings on the same problem sizes.
    Only meaningful compiled (on TPU the portable path would be Triton-
    less anyway; on GPU mosaic doesn't compile) — interpret rows are
    flagged by the mode column like everywhere else."""
    lines = [
        "| row | us | err | mosaic/portable | lowering | mode |",
        "|---|---|---|---|---|---|",
    ]
    found = False
    for row in rec.get("rows", []):
        if not row["name"].startswith("cholupdate/fused_lowering/"):
            continue
        found = True
        d = parse_derived(row["derived"])
        lines.append(
            f"| {row['name']} | {row['us']:.1f} | {d.get('err', '—')} "
            f"| {d.get('mosaic_vs_portable', '—')} "
            f"| {row_lowering(row, rec)} | {row_mode(row, rec)} |"
        )
    if not found:
        return None
    return "\n".join(lines + ["", _interpret_note(rec)])


def _interpret_note(rec):
    return ("⚠ interpret rows run the kernels in Pallas interpret mode "
            "(dispatch-bound Python) — bandwidth/bytes columns are real, "
            "wall-clock is NOT a hardware measurement.")


def stream_table(rec):
    """BENCH_stream.json rows: the coalesce-width sweep + derived gains
    + the stream/latency section (first-flush vs steady-state)."""
    lines = [
        "| row | us/row | updates/s | bytes/row | mutations | mode |",
        "|---|---|---|---|---|---|",
    ]
    extras = []
    for row in rec.get("rows", []):
        d = parse_derived(row["derived"])
        if "speedup" in d or row["name"].startswith("stream/latency/"):
            extras.append(f"**{row['name']}**: {row['derived']}")
            continue
        lines.append(
            f"| {row['name']} | {row['us']:.1f} "
            f"| {d.get('updates_per_s', '—')} | {d.get('bytes_per_row', '—')} "
            f"| {d.get('mutations', '—')} | {row_mode(row, rec)} |"
        )
    return "\n".join(lines + ["", _interpret_note(rec), ""] + extras)


def obs_table(rec):
    """Serving metrics from the registry snapshot a BENCH_stream.json
    record embeds (PR 9): flush latency percentiles per reason, retraces,
    ladder occupancy. Sourced from the SAME ``repro.obs`` registry the
    serving stack measures itself with — this table and the stack's own
    metrics cannot disagree. None for records predating the ``obs``
    field."""
    snap = rec.get("obs")
    if not snap:
        return None
    from repro.obs.metrics import percentile_from

    lines = [
        "| flush reason | flushes | p50 | p99 |",
        "|---|---|---|---|",
    ]
    found = False
    for key, h in sorted(snap.get("histograms", {}).items()):
        if not key.startswith("repro.stream.flush_seconds") or not h["count"]:
            continue
        found = True
        reason = key.split("reason=", 1)[-1].rstrip("}") \
            if "reason=" in key else "—"
        p50 = percentile_from(h, 50) * 1e6
        p99 = percentile_from(h, 99) * 1e6
        lines.append(f"| {reason} | {h['count']} "
                     f"| <={p50:.0f}us | <={p99:.0f}us |")
    if not found:
        return None
    c, g = snap.get("counters", {}), snap.get("gauges", {})

    def _total(name):
        return sum(v for k, v in c.items()
                   if k == name or k.startswith(name + "{"))

    tail = (f"retraces={_total('repro.stream.retraces')} "
            f"guard_rejects={_total('repro.stream.guard_rejects')} "
            f"admissions={_total('repro.stream.admissions')} "
            f"evictions={_total('repro.stream.evictions')} "
            f"promotions={_total('repro.stream.promotions')} "
            f"ladder_occupancy="
            f"{g.get('repro.stream.ladder_occupancy', 0.0):.2f} "
            f"wal_bytes={_total('repro.stream.wal_bytes')}")
    return "\n".join(lines + ["", tail])


def distributed_table(rec):
    """BENCH_distributed.json rows: device scaling + the fleet axis
    (launches per shard vs fleet size, DESIGN.md §10)."""
    lines = [
        "| row | us | err | launches/shard | expected | mode |",
        "|---|---|---|---|---|---|",
    ]
    for row in rec.get("rows", []):
        d = parse_derived(row["derived"])
        lines.append(
            f"| {row['name']} | {row['us']:.1f} | {d.get('err', '—')} "
            f"| {d.get('launches_per_shard', '—')} "
            f"| {d.get('expected', '—')} | {row_mode(row, rec)} |"
        )
    return "\n".join(lines + ["", _interpret_note(rec)])


def blocktridiag_table(rec):
    """BENCH_blocktridiag.json rows: the block-size sweep at matched n.

    The acceptance quantity is the bytes column pair — structured
    bytes-per-update vs the dense fused kernel at the same n/k/dtype —
    so the table leads with the ratio. The dense_gemm_twin rows give the
    dense wall-clock at matched n for context; mode tags interpret rows
    exactly as the other kernel tables do (their wall-clock is
    dispatch-bound, the bytes columns stay real).
    """
    lines = [
        "| row | us | bytes/update | dense bytes | ratio | factor bytes "
        "| launches | mode |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for row in rec.get("rows", []):
        d = parse_derived(row["derived"])
        lines.append(
            f"| {row['name']} | {row['us']:.1f} "
            f"| {d.get('bytes_update', '—')} | {d.get('dense_update', '—')} "
            f"| {d.get('ratio', '—')} | {d.get('bytes_factor', '—')} "
            f"| {d.get('launches', '—')} | {row_mode(row, rec)} |"
        )
    return "\n".join(lines + ["", _interpret_note(rec)])


def _rec_origin(rec):
    """Human tag for where a snapshot record ran (ISSUE 7 fields)."""
    bits = [f"backend={rec['backend']}"]
    if rec.get("device_kind"):
        bits.append(f"device={rec['device_kind']}")
    if rec.get("lowering"):
        bits.append(f"lowering={rec['lowering']}")
    return ", ".join(bits)


def snapshot_sections():
    chol = load_snapshot("BENCH_cholupdate.json")
    for rec in reversed(chol):  # newest record that carries the dtype axis
        table = precision_table(rec)
        if table:
            print(f"\n### Precision axis ({rec['commit']}, "
                  f"{_rec_origin(rec)}, dtypes={rec.get('dtypes')})\n")
            print(table)
            break
    for rec in reversed(chol):  # newest record with the lowering pair
        table = fused_lowering_table(rec)
        if table:
            print(f"\n### Fused lowerings: mosaic vs portable "
                  f"({rec['commit']}, {_rec_origin(rec)})\n")
            print(table)
            break
    stream = load_snapshot("BENCH_stream.json")
    if stream:
        rec = stream[-1]
        print(f"\n### Streaming service ({rec['commit']}, "
              f"{_rec_origin(rec)})\n")
        print(stream_table(rec))
        for rec in reversed(stream):  # newest record carrying a snapshot
            table = obs_table(rec)
            if table:
                print(f"\n### Serving observability ({rec['commit']}, "
                      f"{_rec_origin(rec)})\n")
                print(table)
                break
    dist = load_snapshot("BENCH_distributed.json")
    if dist:
        rec = dist[-1]
        print(f"\n### Distributed / sharded fleets ({rec['commit']}, "
              f"{_rec_origin(rec)})\n")
        print(distributed_table(rec))
    btd = load_snapshot("BENCH_blocktridiag.json")
    if btd:
        rec = btd[-1]
        print(f"\n### Block-tridiagonal factors ({rec['commit']}, "
              f"{_rec_origin(rec)})\n")
        print(blocktridiag_table(rec))


def main():
    for tag in ("singlepod", "multipod", "technique"):
        recs = load(tag)
        if not recs:
            continue
        print(f"\n### Mesh: {tag}\n")
        print(dryrun_summary(recs, tag))
        print()
        print(roofline_table(recs))
    snapshot_sections()


if __name__ == "__main__":
    main()
