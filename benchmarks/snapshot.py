"""Append-only benchmark snapshots: the repo's perf trajectory.

``scripts/bench.sh`` runs the benchmark suites and appends ONE json record
(line-delimited) per snapshot file:

    {"ts": ..., "commit": ..., "backend": ..., "platform": ...,
     "device_kind": ..., "lowering": ..., "quick": ...,
     "rows": [{"name": ..., "us": ..., "derived": ...}, ...]}

The ``platform`` / ``device_kind`` / ``lowering`` triple (ISSUE 7) pins
each record to the hardware and the fused-kernel lowering that produced
it — ``resolve_lowering('auto')``: mosaic on TPU, portable (Triton) on
GPU — so trajectories never silently mix numbers from different
lowerings of the same kernel. Per-row ``interpret=``/``lowering=``
tokens in ``derived`` refine this where a row pins its own mode.

Suites map to snapshot files: the kernel/cholupdate/optimizer suites share
``benchmarks/results/BENCH_cholupdate.json``; the streaming-service suite
lands in ``BENCH_stream.json`` (its axis is coalesce width, not problem
size) and the distributed suite in ``BENCH_distributed.json`` (its axes
are device count and fleet size, DESIGN.md §10) — mixing differently-axed
suites would make every trajectory unqueryable.

Every future PR that touches a hot path runs the same script; each file
then holds the before/after pair (and the whole history), so regressions
are a ``jq`` query instead of archaeology. Interpret-mode wall-clock
off-TPU is dispatch-bound, not kernel performance — compare like against
like via the recorded ``backend`` field.
"""
from __future__ import annotations

import argparse
import inspect
import json
import subprocess
import time
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results"
SNAPSHOT = RESULTS / "BENCH_cholupdate.json"
SNAPSHOT_STREAM = RESULTS / "BENCH_stream.json"
SNAPSHOT_DISTRIBUTED = RESULTS / "BENCH_distributed.json"
# ISSUE 8: the structured-factor suite has its own axes (block size b,
# bytes-per-update vs dense at matched n) — its own trajectory file.
SNAPSHOT_BLOCKTRIDIAG = RESULTS / "BENCH_blocktridiag.json"


def _git_commit() -> str:
    repo = Path(__file__).resolve().parent.parent
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10, cwd=repo,
        ).stdout.strip() or "unknown"
        # A snapshot from uncommitted code must not masquerade as HEAD's —
        # the trajectory file is only comparable when rows attribute truly.
        dirty = subprocess.run(
            ["git", "status", "--porcelain"],
            capture_output=True, text=True, timeout=10, cwd=repo,
        ).stdout.strip()
        return f"{sha}-dirty" if dirty else sha
    except Exception:
        return "unknown"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (default: quick)")
    ap.add_argument("--only", type=str, default="cholupdate,kernels,stream",
                    help="comma-separated suite subset (see benchmarks.run)")
    ap.add_argument("--dtype", type=str, default="float32,bfloat16",
                    help="comma-separated storage-dtype axis for suites that "
                         "support it (DESIGN.md §8): per-dtype rows record "
                         "bytes-per-update alongside wall-clock")
    args = ap.parse_args()

    import jax

    from benchmarks import (
        blocktridiag_bench,
        cholupdate_bench,
        distributed_bench,
        kernel_bench,
        optimizer_bench,
        stream_bench,
    )

    # suite -> (runner, snapshot file): the stream suite's axis (coalesce
    # width) and the distributed suite's axes (device count, fleet size)
    # each get their own trajectory file.
    suites = {
        "cholupdate": (cholupdate_bench.run, SNAPSHOT),
        "kernels": (kernel_bench.run, SNAPSHOT),
        "distributed": (distributed_bench.run, SNAPSHOT_DISTRIBUTED),
        "optimizer": (optimizer_bench.run, SNAPSHOT),
        "stream": (stream_bench.run, SNAPSHOT_STREAM),
        "blocktridiag": (blocktridiag_bench.run, SNAPSHOT_BLOCKTRIDIAG),
    }
    dtypes = tuple(d for d in args.dtype.split(",") if d)
    by_file = {}
    suites_by_file = {}
    for name in args.only.split(","):
        fn, outfile = suites[name]
        rows = by_file.setdefault(outfile, [])
        suites_by_file.setdefault(outfile, []).append(name)
        if "dtypes" in inspect.signature(fn).parameters:
            fn(rows, quick=not args.full, dtypes=dtypes)
        else:
            fn(rows, quick=not args.full)

    from repro.core import backends

    RESULTS.mkdir(parents=True, exist_ok=True)
    commit = _git_commit()
    ts = time.strftime("%Y-%m-%dT%H:%M:%S%z")
    # ISSUE 7: record WHERE the numbers came from. ``platform`` is the jax
    # backend, ``device_kind`` the concrete accelerator (e.g. "TPU v4" /
    # "NVIDIA H100" / "cpu"), ``lowering`` what resolve('auto') picks there
    # — two snapshots are only comparable when all three match, and the
    # lowering field is what separates a mosaic trajectory from a portable
    # one on the same problem sizes.
    try:
        device_kind = jax.devices()[0].device_kind
    except Exception:
        device_kind = "unknown"
    # PR 9: each record embeds the registry snapshot the run accumulated,
    # so report.py renders serving metrics (flush percentiles, retraces,
    # occupancy) from the SAME source the serving stack measures itself
    # with — bench rows and serving metrics can never disagree.
    from repro.obs import metrics as obs_metrics

    obs_snapshot = obs_metrics.snapshot()
    for outfile, rows in by_file.items():
        record = {
            "ts": ts,
            "commit": commit,
            "backend": jax.default_backend(),
            "platform": jax.default_backend(),
            "device_kind": device_kind,
            "lowering": backends.resolve_lowering("auto"),
            "quick": not args.full,
            "suites": ",".join(suites_by_file[outfile]),
            "dtypes": list(dtypes),
            "obs": obs_snapshot,
            "rows": [
                {"name": n, "us": round(us, 1), "derived": derived}
                for n, us, derived in rows
            ],
        }
        with outfile.open("a") as fh:
            fh.write(json.dumps(record) + "\n")
        print(f"appended {len(rows)} rows to {outfile}")
        for n, us, derived in rows:
            print(f"{n},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
