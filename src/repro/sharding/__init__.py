from repro.sharding import rules

__all__ = ["rules"]
