"""Logical-axis -> mesh-axis lowering (DP / FSDP / TP / EP / SP policies).

Every parameter carries a tuple of logical axis names (models/layers.py).
``logical_to_spec`` lowers those to a PartitionSpec under the given mesh with
*divisibility fallback*: a dimension whose size does not divide the assigned
mesh axes is replicated instead (and the event recorded) — this is how the
24-head llama3.2 / 56-head arctic exceptions are handled uniformly rather
than as per-arch hacks (DESIGN.md §7).

Policies:
* TP   — 'heads', 'kv_heads', 'mlp', 'expert_mlp', 'vocab', 'heads_mlp'
         shard over the model axis.
* EP   — 'experts' shards over the model axis (arctic 128/16); when the
         expert count does not divide (mixtral 8e), experts replicate and
         'expert_mlp' still shards (TP-within-expert).
* FSDP — with ``cfg.fsdp``, the 'embed' axis of weight matrices shards over
         the data axes (ZeRO-3-style; XLA SPMD inserts the per-layer
         all-gathers).
* DP/SP— batch shards over ('pod','data'); sequence sharding of activations
         is an optimizer-level constraint (train_step), not a weight spec.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> role
_TP_AXES = ("heads", "kv_heads", "mlp", "expert_mlp", "vocab", "heads_mlp")
_EP_AXES = ("experts",)
_FSDP_AXES = ("embed",)

# Ambient batch-axis assignment for activation constraints inside model code
# (scan carries etc.). Step builders set this to match the batch sharding
# policy before lowering; model code calls constrain_batch_dim.
_BATCH_AXES: Tuple[str, ...] = ("data",)


def set_batch_axes(axes: Tuple[str, ...]):
    global _BATCH_AXES
    _BATCH_AXES = tuple(axes)


def constrain_dims(x, dim_axes):
    """Pin selected dims of ``x`` to mesh axes, others unconstrained.
    ``dim_axes``: {dim_index: axis-name-or-tuple}. No-op without a mesh."""
    spec = [P.UNCONSTRAINED] * x.ndim
    for d, ax in dim_axes.items():
        spec[d] = ax
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x


def constrain_batch_dim(x, dim: int):
    """Pin dimension ``dim`` of ``x`` to the ambient batch axes, leaving the
    other dims unconstrained (auto). No-op without a mesh in context (keeps
    single-device tests unaffected).

    Without this, XLA's auto propagation is free to replicate the carry of a
    long time scan (RWKV/Mamba recurrences) and re-reduce it every step —
    measured as a 40x collective blow-up on rwkv6 train_4k (EXPERIMENTS.md
    §Perf)."""
    axes = _BATCH_AXES
    if x.shape[dim] == 0 or not axes:
        return x
    spec = [P.UNCONSTRAINED] * x.ndim
    spec[dim] = tuple(axes) if len(axes) > 1 else axes[0]
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x


def _mesh_axes_size(mesh: Mesh, names: Sequence[str]) -> int:
    n = 1
    for name in names:
        n *= mesh.shape[name]
    return n


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def model_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("model",) if "model" in mesh.shape else ()


def logical_to_spec(
    axes: Tuple[Optional[str], ...],
    shape: Tuple[int, ...],
    mesh: Mesh,
    *,
    fsdp: bool = False,
    policy: str = "tp",
    notes: Optional[list] = None,
) -> P:
    """Lower one parameter's logical axes to a PartitionSpec.

    policy='tp' (default): TP/EP over the model axis, optional FSDP over the
    data axes. policy='dp': no TP — every device is a data shard and params
    fully shard (ZeRO-3) over data+model; the right choice for models whose
    head/expert counts do not divide the model axis (llama3.2's 24 heads,
    rwkv6's 40 heads) or that are too small to amortise TP collectives.
    """
    tp = model_axes(mesh)
    dp = data_axes(mesh)
    if policy == "dp":
        tp = ()
        dp = data_axes(mesh) + model_axes(mesh)
        fsdp = True
    spec = []
    used = set()
    for ax, dim in zip(axes, shape):
        assign: Tuple[str, ...] = ()
        if ax in _TP_AXES or ax in _EP_AXES:
            assign = tp
        elif ax in _FSDP_AXES and fsdp:
            assign = dp
        if assign and any(a in used for a in assign):
            assign = ()  # one mesh axis may shard only one tensor dim
        if assign:
            size = _mesh_axes_size(mesh, assign)
            if dim % size != 0:
                if notes is not None:
                    notes.append((ax, dim, size))
                assign = ()
        spec.append(assign if assign else None)
        used.update(assign)
    # PartitionSpec wants plain names for single axes.
    return P(*[s[0] if (s and len(s) == 1) else s for s in spec])


def param_specs(axes_tree, values_tree, mesh, *, fsdp=False, policy="tp"):
    """Specs for a whole parameter tree; returns (specs_tree, notes)."""
    notes: list = []
    is_axes = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x
    )
    specs = jax.tree.map(
        lambda a, v: logical_to_spec(
            a, v.shape, mesh, fsdp=fsdp, policy=policy, notes=notes
        ),
        axes_tree,
        values_tree,
        is_leaf=is_axes,
    )
    return specs, notes


def param_shardings(axes_tree, values_tree, mesh, *, fsdp=False):
    specs, notes = param_specs(axes_tree, values_tree, mesh, fsdp=fsdp)
    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )
    return shardings, notes


def batch_spec(mesh: Mesh, ndim: int, *, batch_axis: int = 0) -> P:
    """Shard the batch dimension over the data axes, rest replicated."""
    dp = data_axes(mesh)
    spec = [None] * ndim
    spec[batch_axis] = dp if len(dp) > 1 else (dp[0] if dp else None)
    return P(*spec)


def cache_specs(cache_tree, cfg, mesh):
    """Decode-cache shardings: batch over data axes when divisible, KV heads
    over the model axis; SSM states: heads over model. Replicate otherwise."""
    tp = model_axes(mesh)
    dp = data_axes(mesh)
    dp_size = _mesh_axes_size(mesh, dp) if dp else 1
    tp_size = _mesh_axes_size(mesh, tp) if tp else 1
    dp_name = dp if len(dp) > 1 else (dp[0] if dp else None)
    tp_name = tp[0] if tp else None

    def spec_for(path, leaf):
        name = path[-1] if path else ""
        if leaf.ndim == 0:
            return P()
        batch_dims = {
            # cache array name -> (batch axis index, head axis index or None)
            "k": (1, 3), "v": (1, 3), "xk": (1, 3), "xv": (1, 3),
            "sk": (1, 3), "sv": (1, 3),
            "shift_t": (1, None), "shift_c": (1, None),
            "S": (1, 2), "h": (1, 2), "conv": (1, None),
        }
        if name not in batch_dims:
            return P()
        b_ax, h_ax = batch_dims[name]
        spec = [None] * leaf.ndim
        if dp and leaf.shape[b_ax] % dp_size == 0:
            spec[b_ax] = dp_name
        if h_ax is not None and tp and leaf.shape[h_ax] % tp_size == 0:
            spec[h_ax] = tp_name
        return P(*spec)

    paths_leaves = jax.tree_util.tree_flatten_with_path(cache_tree)[0]
    treedef = jax.tree.structure(cache_tree)
    specs = [
        spec_for(tuple(getattr(k, "key", str(k)) for k in path), leaf)
        for path, leaf in paths_leaves
    ]
    return jax.tree.unflatten(treedef, specs)
