"""Training driver: config-selected arch, sharded step, resilient loop.

On the CPU container this runs the reduced configs end-to-end (the full
configs are exercised by the dry-run); on real hardware the same driver
takes ``--full`` and a production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
      --steps 200 --optimizer cholesky_precond --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

import repro.optim as optim
from repro.configs import get_config
from repro.data import DataConfig, SyntheticTokens, frontend_stub_embeds
from repro.launch import steps as St
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.models import init_model, split_params
from repro.runtime import ResilientLoop, StragglerMonitor
from repro.sharding import rules


def build(cfg, opt, mesh, *, grad_accum=1, seed=0):
    """-> (values, opt_state, jitted step) placed on the mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    key = jax.random.PRNGKey(seed)
    params = init_model(key, cfg)
    values, axes = split_params(params)
    pspecs, _ = rules.param_specs(axes, values, mesh, fsdp=cfg.fsdp)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda x: isinstance(x, P))
    values = jax.tree.map(jax.device_put, values, psh)
    opt_state = opt.init(values)
    step = St.make_train_step(cfg, opt, grad_accum=grad_accum)
    jitted = jax.jit(step, donate_argnums=(0, 1))
    return values, opt_state, jitted


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "sgd", "cholesky_precond"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--full", action="store_true",
                    help="full config on the production mesh (real HW)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
        cfg = dataclasses.replace(cfg, max_seq_len=args.seq)
        mesh = make_mesh((1, 1))
    else:
        mesh = make_production_mesh()

    sched = optim.warmup_cosine(args.lr, warmup_steps=max(args.steps // 10, 1),
                                total_steps=args.steps)
    if args.optimizer == "cholesky_precond":
        opt = optim.cholesky_precond(sched, rank=8, block_size=64)
    else:
        opt = optim.get_optimizer(args.optimizer, sched)

    with mesh:
        values, opt_state, jitted = build(cfg, opt, mesh)
    data = SyntheticTokens(
        DataConfig(cfg.vocab_size, args.seq, args.batch, seed=1)
    )

    def batch_fn(step):
        b = data.batch_at(step)
        if cfg.family == "vlm":
            P = max(1, int(args.seq * cfg.frontend_frac))
            b["embeds"] = frontend_stub_embeds(cfg, args.batch, P, step=step,
                                               dtype=jnp.float32)
        if cfg.family == "encdec":
            b["src_embeds"] = frontend_stub_embeds(
                cfg, args.batch, args.seq, step=step, kind="audio",
                dtype=jnp.float32)
        return b

    state = {"values": values, "opt": opt_state}

    def step_fn(state, batch):
        values, opt_state, metrics = jitted(state["values"], state["opt"], batch)
        return {"values": values, "opt": opt_state}, metrics

    t0 = time.time()
    losses = []

    def on_metrics(step, metrics):
        losses.append(metrics["loss"])
        if step % args.log_every == 0:
            dt = time.time() - t0
            print(f"step {step:5d} loss {metrics['loss']:.4f} "
                  f"gnorm {metrics['grad_norm']:.3f} "
                  f"({step / dt:.2f} steps/s)")

    loop = ResilientLoop(step_fn, batch_fn, args.ckpt_dir,
                         ckpt_every=args.ckpt_every,
                         monitor=StragglerMonitor())
    state, step = loop.run(state, args.steps, on_metrics=on_metrics)
    if losses:
        print(f"done at step {step}; loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    else:
        print(f"already at step {step}; nothing to do (resumed checkpoint)")
    return losses


if __name__ == "__main__":
    main()
