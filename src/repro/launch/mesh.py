"""Production mesh construction.

``make_production_mesh`` is a *function* (never a module-level constant) so
importing this module never touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to build these meshes on the CPU container; on real hardware the same
shapes map onto TPU v5e pods (256 chips/pod).
"""
from __future__ import annotations

from typing import Optional, Tuple

from repro.runtime.compat import make_mesh_compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Optional[Tuple[str, ...]] = None):
    """Arbitrary mesh (elastic restarts re-mesh through this)."""
    if axes is None:
        axes = ("pod", "data", "model")[-len(shape):]
    return make_mesh_compat(shape, axes)


def single_device_mesh():
    return make_mesh((1, 1), ("data", "model"))
