"""Serving driver: batched autoregressive decode with a prefix prompt.

  PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
      --batch 8 --prompt-len 32 --gen 64
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import DataConfig, SyntheticTokens
from repro.models import decode_step, init_cache, init_model, split_params


def generate(cfg, values, prompts, *, gen: int, cache_len: int,
             temperature: float = 0.0, seed: int = 0):
    """prompts: (B, P) int32. Returns (B, P+gen) tokens + tokens/s."""
    B, P = prompts.shape
    cache = init_cache(cfg, B, cache_len, jnp.float32)
    if cfg.family == "encdec":
        raise NotImplementedError("serve driver targets decoder-only archs")
    step = jax.jit(lambda v, c, t: decode_step(v, cfg, c, t))

    toks = prompts
    cur = prompts[:, 0]
    # feed the prompt (teacher-forced), then sample
    for t in range(1, P):
        _, cache = step(values, cache, toks[:, t - 1])
    key = jax.random.PRNGKey(seed)
    cur = toks[:, -1]
    out = [toks]
    t0 = time.perf_counter()
    for t in range(gen):
        logits, cache = step(values, cache, cur)
        logits = logits[:, : cfg.vocab_size]
        if temperature > 0:
            key, sub = jax.random.split(key)
            cur = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            cur = jnp.argmax(logits, axis=-1)
        out.append(cur[:, None].astype(jnp.int32))
    jax.block_until_ready(cur)
    dt = time.perf_counter() - t0
    return jnp.concatenate(out, axis=1), (B * gen) / dt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    if cfg.family == "encdec":
        raise SystemExit("serve driver targets decoder-only archs")
    key = jax.random.PRNGKey(0)
    values, _ = split_params(init_model(key, cfg))
    data = SyntheticTokens(
        DataConfig(cfg.vocab_size, args.prompt_len, args.batch, seed=2)
    )
    prompts = data.batch_at(0)["tokens"]
    cache_len = args.prompt_len + args.gen
    toks, tps = generate(cfg, values, prompts, gen=args.gen,
                         cache_len=cache_len, temperature=args.temperature)
    print(f"generated {toks.shape} tokens at {tps:.1f} tok/s "
          f"(batch {args.batch})")
    print("sample:", toks[0, args.prompt_len:args.prompt_len + 16].tolist())
    return tps


if __name__ == "__main__":
    main()
