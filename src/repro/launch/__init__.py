# NOTE: repro.launch.dryrun intentionally NOT imported here — it sets
# XLA_FLAGS at import time and must only be imported as the main module.
from repro.launch import mesh, steps

__all__ = ["mesh", "steps"]
