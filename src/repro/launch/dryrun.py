import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first init, and the production meshes need 512 placeholder
# devices (2 pods x 16 x 16). Everything else imports below.

"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape) cell and mesh, lower + compile the
appropriate step (train_step / prefill_step / serve_step) with
ShapeDtypeStruct stand-ins (no allocation), print memory/cost analysis, and
record the roofline terms (deliverable g) to a JSONL file.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
  python -m repro.launch.dryrun --all                 # 34 cells, single-pod
  python -m repro.launch.dryrun --all --multi-pod     # 34 cells, 2 pods
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k \
      --optimizer cholesky_precond                    # paper-technique cell
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

import repro.optim as optim
from repro.configs import ARCHS, SHAPES_BY_NAME, cells, get_config
from repro.launch import steps as St
from repro.launch.mesh import make_production_mesh
from repro.roofline import analysis as RA
from repro.sharding import rules

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results"


def _local_bytes(shapes_tree, specs_tree, mesh) -> float:
    """Per-device bytes of a sharded ShapeDtypeStruct tree."""
    from jax.sharding import PartitionSpec as P

    total = 0.0
    flat_shapes = jax.tree.leaves(shapes_tree)
    flat_specs = jax.tree.leaves(specs_tree, is_leaf=lambda x: isinstance(x, P))
    for x, s in zip(flat_shapes, flat_specs):
        denom = 1
        for entry in (s or ()):
            if entry is None:
                continue
            for a in (entry if isinstance(entry, tuple) else (entry,)):
                denom *= mesh.shape[a]
        total += x.size * jnp.dtype(x.dtype).itemsize / denom
    return total


def default_optimizer(cfg, name="adamw"):
    state_dtype = jnp.dtype(cfg.opt_state_dtype)
    if name == "adamw":
        return optim.adamw(3e-4, state_dtype=state_dtype)
    if name == "cholesky_precond":
        return optim.cholesky_precond(3e-4, rank=16, block_size=1024)
    if name == "sgd":
        return optim.sgd(3e-4)
    raise ValueError(name)


def lower_cell(arch: str, shape: str, mesh, *, optimizer="adamw", verbose=True,
               unroll_layers=False, config_patch=None, grad_accum=4,
               policy="tp"):
    """Lower + compile one cell. Returns a result record dict.

    ``unroll_layers`` lowers with the layer loop unrolled so cost_analysis
    counts every layer (XLA does not multiply while-loop bodies); the scanned
    variant stays the memory-proof artifact.
    """
    import dataclasses as _dc

    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = get_config(arch)
    if unroll_layers:
        cfg = _dc.replace(cfg, scan_layers=False)
    if config_patch:
        cfg = _dc.replace(cfg, **config_patch)
    cell = SHAPES_BY_NAME[shape]
    n_chips = 1
    for v in mesh.shape.values():
        n_chips *= v
    t0 = time.time()

    batch_axes = rules.data_axes(mesh)
    if policy == "dp":
        batch_axes = batch_axes + rules.model_axes(mesh)
    rules.set_batch_axes(batch_axes)

    values_shapes, axes = St.param_shapes_and_axes(cfg)
    pspecs, notes = rules.param_specs(axes, values_shapes, mesh, fsdp=cfg.fsdp,
                                      policy=policy)
    psh = jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )
    ins = St.input_specs(cfg, cell)

    analysis_text = None
    with mesh:
        if cell.kind == "train":
            opt = default_optimizer(cfg, optimizer)
            opt_shapes = jax.eval_shape(opt.init, values_shapes)
            ospecs = St.opt_state_specs(opt_shapes, pspecs, mesh)
            osh = jax.tree.map(
                lambda s: NamedSharding(mesh, s), ospecs,
                is_leaf=lambda x: isinstance(x, P),
            )
            bspecs = St.batch_specs(ins, mesh, policy=policy)
            bsh = jax.tree.map(
                lambda s: NamedSharding(mesh, s), bspecs,
                is_leaf=lambda x: isinstance(x, P),
            )

            def jit_step(accum):
                step = St.make_train_step(cfg, opt, grad_accum=accum)
                return jax.jit(
                    step,
                    in_shardings=(psh, osh, bsh),
                    out_shardings=(psh, osh, None),
                    donate_argnums=(0, 1),
                ).lower(values_shapes, opt_shapes, ins)

            lowered = jit_step(grad_accum)
            if grad_accum != 1:
                # FLOPs/collective analysis artifact: accumulation-free
                # (identical totals; avoids XLA loop-fission double counts
                # in the text parser).
                analysis_text = jit_step(1).compile().as_text()
        elif cell.kind == "prefill":
            bspecs = St.batch_specs(ins, mesh, policy=policy)
            bsh = jax.tree.map(
                lambda s: NamedSharding(mesh, s), bspecs,
                is_leaf=lambda x: isinstance(x, P),
            )
            step = St.make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(psh, bsh))
            lowered = jitted.lower(values_shapes, ins)
        else:  # decode
            csh_specs = rules.cache_specs(ins["cache"], cfg, mesh)
            csh = jax.tree.map(
                lambda s: NamedSharding(mesh, s), csh_specs,
                is_leaf=lambda x: isinstance(x, P),
            )
            tspec = St.batch_specs({"tokens": ins["tokens"]}, mesh, policy=policy)["tokens"]
            step = St.make_serve_step(cfg)
            jitted = jax.jit(
                step,
                in_shardings=(psh, csh, NamedSharding(mesh, tspec)),
                out_shardings=(None, csh),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(values_shapes, ins["cache"], ins["tokens"])
        compiled = lowered.compile()

    t_compile = time.time() - t0
    p_local = _local_bytes(values_shapes, pspecs, mesh)
    o_local = 0.0
    if cell.kind == "train":
        o_local = _local_bytes(opt_shapes, ospecs, mesh)
    roof = RA.analyze(
        compiled, cfg, cell, n_chips, hlo_text=analysis_text,
        params_local_bytes=p_local, opt_local_bytes=o_local,
    )
    mem = compiled.memory_analysis()
    rec = {
        "arch": arch,
        "shape": shape,
        "mesh": dict(mesh.shape),
        "chips": n_chips,
        "optimizer": optimizer if cell.kind == "train" else None,
        "policy": policy,
        "kind": cell.kind,
        "compile_s": round(t_compile, 1),
        "flops_per_device": roof.flops,
        "bytes_per_device": roof.bytes_accessed,
        "collective_bytes_per_device": roof.collective_bytes,
        "collectives": roof.collectives,
        "compute_s": roof.compute_s,
        "memory_s": roof.memory_s,
        "collective_s": roof.collective_s,
        "bottleneck": roof.bottleneck,
        "model_flops": roof.model_flops,
        "useful_ratio": roof.useful_ratio,
        "memory_analysis": roof.per_device_memory,
        "replication_notes": [
            {"axis": a, "dim": d, "mesh_size": s} for a, d, s in notes
        ],
    }
    if verbose:
        print(f"== {arch} x {shape} on {dict(mesh.shape)} "
              f"({cell.kind}, compile {t_compile:.1f}s)")
        print("   memory_analysis:", mem)
        print(f"   cost: flops/dev={roof.flops:.3e} bytes/dev={roof.bytes_accessed:.3e} "
              f"coll/dev={roof.collective_bytes:.3e}")
        print(f"   roofline: compute={roof.compute_s*1e3:.2f}ms "
              f"memory={roof.memory_s*1e3:.2f}ms collective={roof.collective_s*1e3:.2f}ms "
              f"-> {roof.bottleneck}-bound; useful_ratio={roof.useful_ratio:.2f}")
        if notes:
            print(f"   replicated (indivisible): {rec['replication_notes']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--optimizer", type=str, default="adamw")
    ap.add_argument("--policy", type=str, default="tp", choices=["tp", "dp"])
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    tag = "multipod" if args.multi_pod else "singlepod"
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    out_path = Path(args.out) if args.out else RESULTS_DIR / f"dryrun_{tag}.jsonl"

    if args.all:
        todo = cells()
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        todo = [(args.arch, args.shape)]

    n_fail = 0
    with open(out_path, "a") as f:
        for arch, shape in todo:
            try:
                rec = lower_cell(arch, shape, mesh, optimizer=args.optimizer,
                                 policy=args.policy)
                f.write(json.dumps(rec) + "\n")
                f.flush()
            except Exception as e:  # a failure here is a bug in the system
                n_fail += 1
                print(f"!! FAILED {arch} x {shape}: {e}")
                traceback.print_exc()
                f.write(json.dumps({"arch": arch, "shape": shape,
                                    "mesh": dict(mesh.shape),
                                    "error": str(e)}) + "\n")
                f.flush()
    print(f"done: {len(todo) - n_fail}/{len(todo)} cells OK -> {out_path}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
