"""Jittable train / prefill / serve steps + per-cell input specs.

These are the functions the dry-run lowers for every (arch x shape x mesh)
cell and the train/serve drivers execute for real. Sharding enters only
through in/out_shardings built from sharding/rules.py — the step bodies are
pure global-view JAX.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

import repro.optim as optim
from repro.configs.base import ModelConfig, ShapeCell
from repro.models import decode_step, init_cache, init_model, loss_fn, split_params
from repro.models import layers as Lyr
from repro.sharding import rules


# ---------------------------------------------------------------------------
# Step bodies.
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, opt: optim.Optimizer, *, clip_norm=1.0,
                    grad_accum: int = 1):
    """One optimizer step; ``grad_accum`` microbatches the global batch
    (activation memory / accum at the cost of an fp32 grad accumulator)."""

    def grads_of(values, batch):
        return jax.value_and_grad(
            lambda v: loss_fn(v, cfg, batch), has_aux=True
        )(values)

    def train_step(values, opt_state, batch):
        if grad_accum == 1:
            (total, metrics), grads = grads_of(values, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape(
                    grad_accum, x.shape[0] // grad_accum, *x.shape[1:]
                ),
                batch,
            )

            def micro(carry, mbi):
                gsum, tsum = carry
                (t, met), g = grads_of(values, mbi)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g
                )
                return (gsum, tsum + t), met

            g0 = jax.tree.map(
                lambda v: jnp.zeros(v.shape, jnp.float32), values
            )
            (gsum, tsum), mets = jax.lax.scan(
                micro, (g0, jnp.zeros((), jnp.float32)), mb
            )
            grads = jax.tree.map(lambda g: g / grad_accum, gsum)
            total = tsum / grad_accum
            metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), mets)
        grads, gnorm = optim.clip_by_global_norm(grads, clip_norm)
        updates, opt_state = opt.update(grads, opt_state, values)
        values = optim.apply_updates(values, updates)
        metrics = dict(metrics, grad_norm=gnorm, loss_total=total)
        return values, opt_state, metrics

    return train_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(values, cache, tokens):
        return decode_step(values, cfg, cache, tokens)

    return serve_step


def make_prefill_step(cfg: ModelConfig):
    """Forward over the full prompt emitting (last-token logits, aux)."""

    def prefill_step(values, batch):
        from repro.models.model import forward

        logits = forward(values, cfg, batch)
        return logits[:, -1]

    return prefill_step


# ---------------------------------------------------------------------------
# Shape specs per cell (ShapeDtypeStruct stand-ins; no allocation).
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, cell: ShapeCell, *, cache_dtype=jnp.bfloat16):
    """Stand-ins for every model input of the cell (weak-type-correct,
    shardable, no device allocation)."""
    B, S = cell.global_batch, cell.seq_len
    if cell.kind in ("train", "prefill"):
        specs = {"tokens": _sds((B, S), jnp.int32)}
        if cell.kind == "train":
            specs["labels"] = _sds((B, S), jnp.int32)
        if cfg.family == "vlm":
            P = int(S * cfg.frontend_frac)
            specs["embeds"] = _sds((B, P, cfg.d_model), jnp.bfloat16)
        if cfg.family == "encdec":
            specs["src_embeds"] = _sds((B, S, cfg.d_model), jnp.bfloat16)
        return specs
    if cell.kind == "decode":
        cache = jax.eval_shape(
            lambda: init_cache(cfg, B, S, cache_dtype)
        )
        return {"tokens": _sds((B,), jnp.int32), "cache": cache}
    raise ValueError(cell.kind)


def param_shapes_and_axes(cfg: ModelConfig, key=None):
    """(values ShapeDtypeStruct tree, logical axes tree) without allocation.

    Shapes come from eval_shape on the full config; axes from a real init of
    the reduced config (identical tree structure, checked)."""
    if key is None:
        key = jax.random.PRNGKey(0)
    values_shapes = jax.eval_shape(
        lambda k: split_params(init_model(k, cfg))[0], key
    )
    _, axes = split_params(init_model(key, cfg.reduced()))
    s1 = jax.tree.structure(jax.tree.map(lambda x: 0, values_shapes))
    s2 = jax.tree.structure(
        jax.tree.map(lambda a: 0, axes, is_leaf=lambda x: isinstance(x, tuple))
    )
    assert s1 == s2, f"axes tree mismatch: {s1} vs {s2}"
    return values_shapes, axes


def opt_state_specs(opt_state_shapes, param_specs_tree, mesh):
    """Shardings for optimizer state: m/v/factors mirror params when the
    subtree structure matches; scalars and everything else replicate."""
    from jax.sharding import PartitionSpec as P

    def mirror(sub):
        try:
            same = jax.tree.structure(
                jax.tree.map(lambda x: 0, sub)
            ) == jax.tree.structure(
                jax.tree.map(lambda x: 0, param_specs_tree,
                             is_leaf=lambda x: isinstance(x, P))
            )
        except Exception:
            same = False
        return same

    out = {}
    for k, sub in opt_state_shapes.items():
        if k in ("m", "v") and mirror(sub):
            out[k] = param_specs_tree
        else:
            out[k] = jax.tree.map(lambda x: P(), sub)
    return out


def batch_specs(specs_tree, mesh, *, policy: str = "tp"):
    """Batch-dim sharding over the data axes (replicate when indivisible).
    Under policy='dp' the model axis joins the data axes; if the batch does
    not divide the combined size, the largest divisible prefix is used."""
    from jax.sharding import PartitionSpec as P

    dp = rules.data_axes(mesh)
    if policy == "dp":
        dp = dp + rules.model_axes(mesh)

    def spec(x):
        if x.ndim == 0:
            return P()
        axes = list(dp)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        while axes and x.shape[0] % size:
            a = axes.pop()  # drop innermost axis until divisible
            size //= mesh.shape[a]
        if not axes:
            return P()
        name = tuple(axes) if len(axes) > 1 else axes[0]
        return P(*([name] + [None] * (x.ndim - 1)))

    return jax.tree.map(spec, specs_tree)
