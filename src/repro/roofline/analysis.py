"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (TPU v5e constants):

    compute    = HLO_FLOPs_per_device / peak_FLOPs_chip
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / link_bw

``compiled.cost_analysis()`` on an SPMD module reports *per-device* FLOPs and
bytes (verified empirically), so no chip division is needed. Collective bytes
are parsed from ``compiled.as_text()``: the result shape of each collective
op, scaled by a per-op ring-cost factor (all-reduce 2x, reduce-scatter x
group size to recover the operand, all-gather/all-to-all/permute 1x).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# TPU v5e hardware constants (per brief).
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # B/s per chip
LINK_BW = 50e9             # B/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"(\w+)\[([\d,]*)\][^=]*?\s(all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute)(?:-start)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> float:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0.0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return float(n * b)


def collective_stats(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes moved, by collective kind, from the compiled HLO."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.groups()
        nbytes = _shape_bytes(dtype, dims)
        g = None
        gm = _GROUPS_RE.search(line)
        if gm:
            g = int(gm.group(2))
        if kind == "all-reduce":
            nbytes *= 2.0  # ring all-reduce moves ~2x the buffer
        elif kind == "reduce-scatter" and g:
            nbytes *= g  # result is 1/g of the reduced operand
        out[kind] = out.get(kind, 0.0) + nbytes
        out["total"] = out.get("total", 0.0) + nbytes
    return out


@dataclasses.dataclass
class Roofline:
    flops: float               # per-device
    bytes_accessed: float      # per-device
    collective_bytes: float    # per-device
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float         # 6*N*D (or 2*N*D inference), whole step, global
    useful_ratio: float        # model_flops / (flops * chips)
    per_device_memory: Optional[dict] = None
    collectives: Optional[dict] = None

    def row(self):
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio,
        }


def model_flops(cfg, cell) -> float:
    """MODEL_FLOPS per step: 6*N_active*tokens (train) / 2*N_active*tokens
    (inference) plus the standard causal-attention term (PaLM-style MFU
    accounting: 2*2*S_kv*H*Dh per token per layer, halved for causality,
    windowed when SWA applies), which dominates 32k+ prefills."""
    n_active = active_params(cfg)
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    mult = 6.0 if cell.kind == "train" else 2.0
    flops = mult * n_active * tokens
    if cfg.attn is not None and cfg.family != "rwkv":
        a = cfg.attn
        n_attn_layers = cfg.num_layers + cfg.enc_layers
        if cfg.shared_attn_every:
            n_attn_layers = cfg.num_layers // cfg.shared_attn_every + 1
        kv_len = cell.seq_len
        causal_half = 0.5
        if a.window and not a.local_global_period:
            kv_len = min(a.window, cell.seq_len)
            causal_half = 1.0 if kv_len < cell.seq_len else 0.5
        if cell.kind == "decode":
            causal_half = 1.0  # one query reads the whole (windowed) cache
        # 2 matmuls (QK^T, PV) x 2 FLOPs/MAC x q_heads x head_dim
        per_tok = 4.0 * kv_len * a.num_heads * a.head_dim * causal_half
        attn = per_tok * tokens * n_attn_layers
        flops += (mult / 2.0) * attn
    return flops


def active_params(cfg) -> float:
    """Analytic active-parameter count from the config."""
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab_padded
    n = v * d  # embeddings
    if not cfg.tie_embeddings:
        n += v * d
    per_layer = 0.0
    if cfg.attn is not None and cfg.family in ("dense", "vlm", "moe", "encdec"):
        a = cfg.attn
        per_layer += d * a.q_dim + 2 * d * a.kv_dim + a.q_dim * d
    gated = cfg.activation in ("swiglu", "geglu")
    ffn = d * ff * (3 if gated else 2)
    if cfg.family == "moe":
        eff = cfg.moe.expert_d_ff or ff
        expert = d * eff * 3
        per_layer += cfg.moe.top_k * expert + d * cfg.moe.num_experts
        if cfg.moe.dense_residual:
            per_layer += ffn
    elif cfg.family == "rwkv":
        per_layer += 6 * d * d  # r,k,v,g,o + cmix gate, approx
        per_layer += d * ff + ff * d
    elif cfg.family == "mamba_hybrid":
        di = cfg.ssm.expand * d
        per_layer += d * (2 * di + 2 * cfg.ssm.state_dim) + di * d
    else:
        per_layer += ffn
    n += cfg.num_layers * per_layer
    if cfg.family == "encdec":
        enc_layer = d * cfg.attn.q_dim * 2 + 2 * d * cfg.attn.kv_dim + ffn
        cross = d * cfg.attn.q_dim * 2 + 2 * d * cfg.attn.kv_dim
        n += cfg.enc_layers * enc_layer + cfg.num_layers * cross
    if cfg.shared_attn_every:
        a = cfg.attn
        n += d * a.q_dim * 2 + 2 * d * a.kv_dim + ffn
    return float(n)


def analytic_memory_bytes(cfg, cell, n_chips, params_local_bytes,
                          opt_local_bytes=0.0):
    """Documented per-device HBM traffic model (EXPERIMENTS.md §Roofline).

    XLA:CPU's ``bytes accessed`` counts unfused-op operands (~40x TPU
    reality), so the memory term uses this transparent estimate instead:

      train:   3 reads of the local params (fwd, bwd, remat-fwd) + grad
               write+read + optimizer state read+write + param write,
               plus ~12 activation-stream touches per layer.
      prefill: 1 param read + ~6 activation touches + KV-cache write.
      decode:  1 param read (weight-streaming dominates) + cache read+write.
    """
    d = cfg.d_model
    L = cfg.num_layers + cfg.enc_layers
    dp = max(1, n_chips // 16)  # data-parallel ways on the production meshes
    tokens_local = cell.global_batch * (
        cell.seq_len if cell.kind != "decode" else 1
    ) / dp
    act = tokens_local * d * 2.0  # bf16 activation stream per layer
    if cell.kind == "train":
        p_traffic = 5.0 * params_local_bytes + 2.0 * opt_local_bytes \
            + params_local_bytes
        a_traffic = 12.0 * act * L
    elif cell.kind == "prefill":
        p_traffic = params_local_bytes
        a_traffic = 6.0 * act * L
    else:  # decode
        p_traffic = params_local_bytes
        cache_bytes = 0.0
        if cfg.attn is not None:
            slots = min(cell.seq_len, cfg.attn.window or cell.seq_len)
            cache_bytes = (
                2.0 * L * cell.global_batch * slots * cfg.attn.kv_dim * 2.0 / dp
            )
        a_traffic = 2.0 * act * L + cache_bytes
    return p_traffic + a_traffic


def analyze(compiled, cfg, cell, n_chips: int, *, hlo_text: Optional[str] = None,
            params_local_bytes: float = 0.0, opt_local_bytes: float = 0.0):
    from repro.roofline import hloparse

    text = hlo_text if hlo_text is not None else compiled.as_text()
    flops, cbytes, colls, _info = hloparse.analyze_hlo(text)
    nbytes = analytic_memory_bytes(
        cfg, cell, n_chips, params_local_bytes, opt_local_bytes
    )
    compute_s = flops / PEAK_FLOPS
    memory_s = nbytes / HBM_BW
    collective_s = cbytes / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, cell)
    useful = mf / (flops * n_chips) if flops else 0.0
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        }
    except Exception:
        pass
    return Roofline(
        flops=flops,
        bytes_accessed=nbytes,
        collective_bytes=cbytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops=mf,
        useful_ratio=useful,
        per_device_memory=mem,
        collectives=colls,
    )
