"""Loop-aware HLO text analysis.

XLA's ``cost_analysis()`` counts a while-loop body once, so a scanned
80-layer model under-reports FLOPs and collective bytes by ~L. This parser
reconstructs the computation call graph from ``compiled.as_text()``,
extracts each while loop's trip count from its condition computation
(``compare(iter, constant), direction=LT``), and multiplies the dot-FLOPs /
collective bytes found in loop bodies by the product of enclosing trip
counts.

Scope: ``dot`` ops dominate FLOPs in every assigned architecture (einsums,
expert GEMMs, recurrence einsums); elementwise/softmax FLOPs are not counted
(a few-percent underestimate, noted in EXPERIMENTS.md). Collectives use the
result-shape cost model (all-reduce 2x ring, reduce-scatter x group).
Models must avoid ``lax.cond`` on the hot path (branch cost is not statically
attributable — the zamba2 shared block is group-scanned instead).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

# %name = dtype[dims]{layout} opcode(...)
_INSTR = re.compile(r"^(?:ROOT )?%?([\w\.\-]+) = (\w+)\[([\d,]*)\]")
_PARAM = re.compile(r"%?([\w\.\-]+): (\w+)\[([\d,]*)\]")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_WHILE = re.compile(r"condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_COLLECTIVE = re.compile(
    r"= (\w+)\[([\d,]*)\][^=]*?\s(all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute)(?:-start)?\("
)
_GROUPS = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONSTANT = re.compile(r"^%?([\w\.\-]+) = s(?:32|64)\[\] constant\((\d+)\)")
_OPERANDS = re.compile(r"\(([^)]*)\)")


def _dims(s: str) -> List[int]:
    return [int(d) for d in s.split(",") if d]


_NAME_REF = re.compile(r"%([\w\.\-]+)")


def _operand_names(s: str) -> List[str]:
    """Bare instruction names from an HLO operand list.

    Scheduled HLO prints typed operands (``f32[8,16]{1,0} %dot.0``) whose
    shapes contain commas, so naive comma-splitting yields shape fragments.
    Prefer the ``%name`` sigil references; fall back to comma tokens for
    sigil-free dumps.
    """
    names = _NAME_REF.findall(s)
    if names:
        return names
    return [tok.strip().split()[-1] for tok in s.split(",") if tok.strip()]


def _nbytes(dtype: str, dims: List[int]) -> float:
    b = _DTYPE_BYTES.get(dtype, 0)
    n = 1
    for d in dims:
        n *= d
    return float(n * b)


@dataclasses.dataclass
class CompStats:
    flops: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    whiles: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    calls: List[str] = dataclasses.field(default_factory=list)
    constants: Dict[str, int] = dataclasses.field(default_factory=dict)
    has_lt_compare_with: List[str] = dataclasses.field(default_factory=list)


def _split_computations(text: str) -> Dict[str, List[str]]:
    """computation name -> [header_line, body lines...]."""
    comps: Dict[str, List[str]] = {}
    cur = None
    for raw in text.splitlines():
        line = raw.strip()
        if cur is None:
            if line.endswith("{") and ("->" in line or line.startswith("ENTRY")):
                m = re.match(r"^(?:ENTRY )?%?([\w\.\-]+)", line)
                if m:
                    cur = m.group(1)
                    comps[cur] = [line]
            continue
        if line.startswith("}"):
            cur = None
            continue
        comps[cur].append(line)
    return comps


def _parse_comp(lines: List[str]) -> CompStats:
    st = CompStats()
    shapes: Dict[str, Tuple[str, List[int]]] = {}
    # header params carry shapes
    for m in _PARAM.finditer(lines[0]):
        shapes[m.group(1)] = (m.group(2), _dims(m.group(3)))
    for line in lines[1:]:
        im = _INSTR.match(line)
        if im:
            shapes[im.group(1)] = (im.group(2), _dims(im.group(3)))
        cm = _CONSTANT.match(line)
        if cm:
            st.constants[cm.group(1)] = int(cm.group(2))
        if " dot(" in line and im:
            out_dims = _dims(im.group(3))
            ops = _OPERANDS.search(line[line.index(" dot(") :])
            contract = 1
            if ops:
                names = _operand_names(ops.group(1))
                lhs = shapes.get(names[0]) if names else None
                ctr = _CONTRACT.search(line)
                if lhs and ctr:
                    for i in _dims(ctr.group(1)):
                        if i < len(lhs[1]):
                            contract *= lhs[1][i]
                elif lhs:
                    contract = lhs[1][-1] if lhs[1] else 1
            f = 2.0 * contract
            for d in out_dims:
                f *= d
            st.flops += f
        colm = _COLLECTIVE.search(line)
        if colm:
            dtype, dims_s, kind = colm.groups()
            nb = _nbytes(dtype, _dims(dims_s))
            gm = _GROUPS.search(line)
            g = int(gm.group(2)) if gm else None
            if kind == "all-reduce":
                nb *= 2.0
            elif kind == "reduce-scatter" and g:
                nb *= g
            st.coll_bytes += nb
            st.coll_by_kind[kind] = st.coll_by_kind.get(kind, 0.0) + nb
        if " while(" in line:
            wm = _WHILE.search(line)
            if wm:
                st.whiles.append((wm.group(1), wm.group(2)))
        elif "fusion(" in line or " call(" in line or "custom-call" in line:
            cm2 = _CALLS.search(line)
            if cm2:
                st.calls.append(cm2.group(1))
        if "compare(" in line and "direction=LT" in line:
            ops = _OPERANDS.search(line[line.index("compare(") :])
            if ops:
                st.has_lt_compare_with.extend(_operand_names(ops.group(1)))
            m = re.search(r"constant\((\d+)\)", line)
            if m:
                st.constants[f"__inline_{len(st.constants)}"] = int(m.group(1))
                st.has_lt_compare_with.append(f"__inline_{len(st.constants)-1}")
    return st


def _trip_count(cond_name: str, stats: Dict[str, CompStats]) -> int:
    """Trip count from a loop condition computation (+ its callees)."""
    st = stats.get(cond_name)
    if st is None:
        return 1
    pool = [st] + [stats[c] for c in st.calls if c in stats]
    for s in pool:
        for operand in s.has_lt_compare_with:
            for s2 in pool:
                if operand in s2.constants:
                    return s2.constants[operand]
    # fallback: any constant in the condition (loop bounds are usually the
    # only integer constants there)
    consts = [v for s in pool for v in s.constants.values()]
    return max(consts) if consts else 1


def analyze_hlo(text: str):
    """Loop-aware totals: (flops, collective_bytes, coll_by_kind, info)."""
    comps = _split_computations(text)
    stats = {name: _parse_comp(lines) for name, lines in comps.items()}
    memo: Dict[str, Tuple[float, float, Dict[str, float]]] = {}

    def total(name: str, depth=0):
        if name in memo:
            return memo[name]
        st = stats.get(name)
        if st is None or depth > 64:
            return (0.0, 0.0, {})
        f, c = st.flops, st.coll_bytes
        kinds = dict(st.coll_by_kind)
        for callee in st.calls:
            tf, tc, tk = total(callee, depth + 1)
            f += tf
            c += tc
            for k, v in tk.items():
                kinds[k] = kinds.get(k, 0.0) + v
        for cond, body in st.whiles:
            trip = _trip_count(cond, stats)
            tf, tc, tk = total(body, depth + 1)
            f += trip * tf
            c += trip * tc
            for k, v in tk.items():
                kinds[k] = kinds.get(k, 0.0) + trip * v
        memo[name] = (f, c, kinds)
        return memo[name]

    entry = None
    for line in text.splitlines():
        m = re.match(r"^ENTRY %?([\w\.\-]+)", line.strip())
        if m:
            entry = m.group(1)
            break
    if entry is None:
        entry = max(comps, key=lambda k: len(comps[k]))
    f, c, kinds, = total(entry)
    kinds = dict(kinds)
    kinds["total"] = c
    return f, c, kinds, {"entry": entry, "n_computations": len(comps)}
