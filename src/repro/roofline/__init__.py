from repro.roofline import analysis, hloparse

__all__ = ["analysis", "hloparse"]
