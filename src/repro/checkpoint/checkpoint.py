"""Pytree checkpointing: atomic on-disk saves, resume, cross-mesh reshard.

Format: one directory per step (``step_000123/``) holding
* ``tree.msgpack`` — the treedef + per-leaf metadata (shape, dtype),
* ``arrays.npz``   — the leaf buffers (gathered to host),
* ``DONE``         — commit marker written last (atomicity: readers ignore
  directories without it; a crash mid-write leaves no valid-looking junk).

Resharding is free at restore: leaves are loaded as host arrays and
``jax.device_put`` with the *new* mesh's shardings — this is what makes
elastic restarts (different pod/slice count) work.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def np_dtype_for(name: str) -> np.dtype:
    """Resolve a stored dtype string, including ml_dtypes names (bfloat16,
    fp8 variants) that numpy alone cannot parse. Shared by ``restore`` and
    the stream WAL codec (``repro.stream.durability``)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", k)) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def save(ckpt_dir, step: int, tree: Any, *, keep: int = 3,
         extra: Optional[dict] = None) -> Path:
    """Atomic save of a pytree at ``step``; prunes to the newest ``keep``.

    ``extra`` is an arbitrary JSON-able dict persisted alongside the leaf
    metadata and returned by ``read_meta`` — the home for non-array aux a
    pytree's treedef carries but raw leaves lose (e.g. a ``CholFactor``
    fleet's backend/panel/precision, which ``repro.stream.durability``
    round-trips through here).
    """
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    names, leaves, _ = _flatten_with_names(tree)
    arrays = {}
    meta = {"step": step, "leaves": [], "extra": extra or {}}
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        key = f"a{i}"
        # npz cannot represent ml_dtypes (bfloat16/fp8): store raw bytes and
        # the dtype string; restore views them back.
        arrays[key] = np.ascontiguousarray(arr).view(np.uint8).reshape(-1)
        meta["leaves"].append(
            {"name": name, "key": key, "shape": list(arr.shape),
             "dtype": str(arr.dtype)}
        )
    np.savez(tmp / "arrays.npz", **arrays)
    (tmp / "tree.json").write_text(json.dumps(meta))
    (tmp / "DONE").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: Path, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(Path(ckpt_dir) / f"step_{s:08d}", ignore_errors=True)


def all_steps(ckpt_dir) -> list:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return []
    out = []
    for p in ckpt_dir.iterdir():
        m = _STEP_RE.match(p.name)
        if m and (p / "DONE").exists():
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def read_meta(ckpt_dir, step: int) -> dict:
    """The committed checkpoint's metadata dict (leaf specs + ``extra``).

    Lets callers recover what ``restore(like=...)`` cannot: the non-array
    aux recorded at save time (see ``save``'s ``extra``). Raises like
    ``restore`` on an uncommitted/missing step.
    """
    path = Path(ckpt_dir) / f"step_{step:08d}"
    if not (path / "DONE").exists():
        raise FileNotFoundError(f"no committed checkpoint at {path}")
    return json.loads((path / "tree.json").read_text())


def restore(ckpt_dir, step: int, like: Any, *, shardings: Any = None) -> Any:
    """Restore into the structure of ``like`` (values ignored). With
    ``shardings`` (same treedef), leaves are device_put with the new mesh's
    shardings — elastic re-mesh happens here."""
    path = Path(ckpt_dir) / f"step_{step:08d}"
    if not (path / "DONE").exists():
        raise FileNotFoundError(f"no committed checkpoint at {path}")
    meta = json.loads((path / "tree.json").read_text())

    with np.load(path / "arrays.npz") as npz:
        by_name = {
            leaf["name"]: npz[leaf["key"]]
            .view(np_dtype_for(leaf["dtype"]))
            .reshape(leaf["shape"])
            for leaf in meta["leaves"]
        }
    names, like_leaves, treedef = _flatten_with_names(like)
    missing = [n for n in names if n not in by_name]
    if missing:
        raise ValueError(f"checkpoint missing leaves: {missing[:5]}...")
    new_leaves = [by_name[n] for n in names]
    restored = jax.tree_util.tree_unflatten(treedef, new_leaves)
    if shardings is not None:
        restored = jax.tree.map(
            lambda x, s: jax.device_put(x, s), restored, shardings
        )
    return restored
