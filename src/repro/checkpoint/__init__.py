from repro.checkpoint.checkpoint import (
    all_steps,
    latest_step,
    np_dtype_for,
    read_meta,
    restore,
    save,
)

__all__ = ["save", "restore", "latest_step", "all_steps", "read_meta",
           "np_dtype_for"]
