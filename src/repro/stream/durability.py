"""Durability for the streaming service: fleet checkpoints + replay log.

Two cooperating pieces make a ``StreamService`` survive a kill:

* **Checkpoint** — ``checkpoint_service`` writes the fleet through
  ``repro.checkpoint.save`` (atomic, DONE-marker committed) with the
  factor's execution metadata (backend, panel, interpret, precision,
  dtype, and — for sharded fleets — the mesh axis names/sizes + column
  axis binding, DESIGN.md §10) and the service/slot state in the
  checkpoint's ``extra`` meta — the aux a bare pytree dump loses.
* **Replay log (WAL)** — every state-changing service call appends one
  JSONL record to ``wal_<step>.jsonl``. The log is rotated at checkpoint
  time and *seeded* with the then-unflushed buffer contents and the
  pending window-downdate schedule (synthetic ``buffer``/``sched``
  records), so the log alone carries everything the checkpoint's arrays do
  not.

``restore_service`` = load the newest committed checkpoint, rebuild the
store/service around its meta, then replay the WAL: buffered rows are
re-buffered and logged ``flush`` events re-issue the *identical* mutation
sequence (replay disables auto-flush triggers, so flush grouping follows
the log, not re-derived heuristics). Restart therefore reproduces the
exact post-flush factor state — allclose at storage dtype — plus the
exact pending buffers, after a crash at any point between records.
"""
from __future__ import annotations

import base64
import json
import os
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.core import CholFactor
from repro.core.precision import Precision
from repro.obs import metrics as obs_metrics
from repro.obs import tracing as obs_tracing
from repro.stream.coalescer import Coalescer
from repro.stream.service import StreamService
from repro.stream.store import FactorStore


# One dtype resolver for everything this module decodes (checkpoint leafs
# use the same one inside ckpt.restore).
_np_dtype = ckpt.np_dtype_for


# -- row codec ---------------------------------------------------------------


def encode_row(v: np.ndarray) -> dict:
    arr = np.ascontiguousarray(np.asarray(v))
    return {"v": base64.b64encode(arr.tobytes()).decode("ascii"),
            "dtype": str(arr.dtype), "shape": list(arr.shape)}


def decode_row(rec: dict) -> np.ndarray:
    raw = base64.b64decode(rec["v"])
    return np.frombuffer(raw, dtype=_np_dtype(rec["dtype"])).reshape(
        rec["shape"]).copy()


def _precision_to_json(p: Optional[Precision]):
    if p is None:
        return None
    return {"storage": None if p.storage is None else str(p.storage),
            "accum": str(p.accum)}


def _precision_from_json(d) -> Optional[Precision]:
    if d is None:
        return None
    return Precision(storage=d["storage"], accum=d["accum"])


# -- mesh codec (sharded fleets, DESIGN.md §10) ------------------------------
#
# A Mesh is a process-local object (it holds live Devices), so the
# checkpoint records what DETERMINES it — axis names and per-axis sizes —
# and restore rebuilds an equivalent mesh on the restoring machine's
# devices through the one compat choke point. Same-machine restarts get
# the identical device assignment (bitwise fleets); elastic restores onto
# a different device count fail loudly in make_mesh_compat rather than
# silently unsharding.


def _mesh_to_json(factor) -> Optional[dict]:
    if factor.backend != "sharded" or factor.mesh is None:
        return None
    mesh = factor.mesh
    axis = factor.axis
    return {
        "axes": [str(a) for a in mesh.axis_names],
        "shape": [int(mesh.shape[a]) for a in mesh.axis_names],
        "axis": axis if isinstance(axis, str) else list(axis),
    }


def _mesh_from_json(d, *, mesh=None):
    """(mesh, axis) from checkpoint meta; ``mesh=`` overrides (elastic)."""
    if d is None:
        if mesh is not None:
            # The caller asked for a sharded placement the checkpoint
            # cannot satisfy (unsharded or pre-§10 fleet): dropping the
            # override silently would hand back a replicated store the
            # caller believes is sharded.
            raise ValueError(
                "mesh= override given, but the checkpoint carries no "
                "sharded-fleet record (unsharded fleet, or saved before "
                "DESIGN.md §10)")
        return None, "model"
    axis = d["axis"] if isinstance(d["axis"], str) else tuple(d["axis"])
    if mesh is None:
        from repro.runtime.compat import make_mesh_compat

        mesh = make_mesh_compat(tuple(d["shape"]), tuple(d["axes"]))
    return mesh, axis


# -- the write-ahead log -----------------------------------------------------


class ReplayLog:
    """Append-only JSONL event log (one record per state-changing call)."""

    def __init__(self, path, *, truncate: bool = False):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._fh = self.path.open("w" if truncate else "a")

    def append(self, record: dict) -> None:
        line = json.dumps(record) + "\n"
        self._fh.write(line)
        # Flush through to the OS per record: a crashed *process* loses
        # nothing (fsync-per-record durability against power loss is the
        # operator's trade to make; the serving-loop default is flush).
        self._fh.flush()
        obs_metrics.counter("repro.stream.wal_records",
                            op=record.get("op", "seed")).inc()
        obs_metrics.counter("repro.stream.wal_bytes").inc(len(line))

    def close(self) -> None:
        self._fh.close()

    @staticmethod
    def read(path) -> list:
        path = Path(path)
        if not path.exists():
            return []
        records = []
        for line in path.open():
            line = line.strip()
            if line:
                records.append(json.loads(line))
        return records


# -- checkpoint / restore ----------------------------------------------------

# One WAL segment per checkpoint ATTEMPT: wal_<step>_<attempt>.jsonl. The
# committed checkpoint's meta records which segment it pairs with, so the
# two-file commit is effectively atomic — the WAL is written in full
# first, and only the (atomic) checkpoint commit publishes it. Re-using a
# step number therefore never truncates the previously committed step's
# segment; a crash mid-attempt leaves an orphan the next _prune_wals
# collects.
_WAL_FMT = "wal_{step:08d}_{attempt}.jsonl"


def _next_wal_path(ckpt_dir, step: int) -> Path:
    # max(existing)+1, NOT a count: pruning earlier attempts must never
    # make a new attempt collide with (and truncate) the still-referenced
    # committed segment.
    attempts = []
    for p in Path(ckpt_dir).glob(f"wal_{step:08d}_*.jsonl"):
        try:
            attempts.append(int(p.stem.rsplit("_", 1)[1]))
        except ValueError:
            continue
    attempt = max(attempts, default=-1) + 1
    return Path(ckpt_dir) / _WAL_FMT.format(step=step, attempt=attempt)


def checkpoint_service(svc: StreamService, ckpt_dir, step: int, *,
                       keep: int = 3) -> Path:
    """Atomic fleet checkpoint + WAL rotation seeded with unflushed state.

    After this returns, ``restore_service(ckpt_dir)`` reproduces ``svc``
    exactly: fleet arrays from the checkpoint, execution metadata and slot
    table from its ``extra`` meta, buffers/schedule from the new WAL's
    head records, and any later traffic from the WAL's tail.
    """
    # The whole snapshot + rotation runs under the service lock: the
    # background flush worker mutates fleet/rings/schedule/WAL under it,
    # so without it a checkpoint taken mid-flush could record torn state —
    # or rotate the WAL such that the in-flight flush's record lands in
    # the NEW segment whose fleet snapshot already includes that flush,
    # and replay double-applies it. The RLock serialises us after any
    # in-flight flush; requests still queued run against (and log after)
    # the rotated segment, which replay applies on top of the snapshot.
    with svc._lock:
        with obs_tracing.span("stream.checkpoint", step=step):
            return _checkpoint_locked(svc, ckpt_dir, step, keep=keep)


def _checkpoint_locked(svc: StreamService, ckpt_dir, step: int, *,
                       keep: int) -> Path:
    store = svc.store
    f = store.factor

    # Seed the NEW WAL segment FIRST — the unflushed ring contents and the
    # pending window schedule, everything the checkpoint's arrays do not
    # carry — and only then commit the checkpoint, whose meta names the
    # segment. A crash before the commit leaves the previous
    # (checkpoint, WAL) pair authoritative; a crash after it finds the
    # seeded segment already complete. The reverse order would open a
    # window where step N is committed but its buffers/schedule are lost.
    wal_path = _next_wal_path(ckpt_dir, step)
    log = ReplayLog(wal_path, truncate=True)
    for u in store.users():
        c = svc._coalescer(u)
        up, down = c.peek()
        first = c.first_tick
        for row in up:
            log.append({"op": "buffer", "user": u, "sign": 1,
                        "first_tick": first, **encode_row(row)})
        for row in down:
            log.append({"op": "buffer", "user": u, "sign": -1,
                        "first_tick": first, **encode_row(row)})
    for due, _, u, row in sorted(svc._schedule):
        log.append({"op": "sched", "user": u, "due": due,
                    **encode_row(row)})

    extra = {"stream": {
        "n": store.n,
        # Storage-kind record (absent in pre-structure checkpoints, which
        # restore as dense — the compat default): a structured fleet's
        # block stacks must never be reinterpreted as a dense (B, n, n)
        # fleet by shape accident, so restore keys the template on this.
        "structure": store.structure,
        "block": store.block,
        "width": store.width,
        "widths": list(store.widths),
        "capacity": store.capacity,
        "ladder": list(store.ladder),
        "panel": f.panel,
        "backend": f.backend,
        "interpret": f.interpret,
        "precision": _precision_to_json(f.precision),
        "mesh": _mesh_to_json(f),
        "dtype": str(np.dtype(f.dtype)),
        "init_scale": store.init_scale,
        "slots": [[u, s] for u, s in sorted(
            store._slot_of.items(), key=lambda kv: kv[1])],
        "empty_slots": list(store.empty_slots),
        "last_used": [[u, t] for u, t in store._last_used.items()],
        "tick": svc.tick_count,
        "window": svc.window,
        "deadline": svc.deadline,
        "auto_flush": svc.auto_flush,
        "ring_capacity": svc._ring_capacity,
        "background": svc.background_active,
        "wal": wal_path.name,
    }}
    path = ckpt.save(ckpt_dir, step, {"fleet": f.data}, keep=keep,
                     extra=extra)

    # Rotate: the previous segment is superseded, live traffic appends to
    # the seeded one from here on.
    if svc._wal is not None:
        svc._wal.close()
    svc.attach_wal(log)
    _prune_wals(ckpt_dir)
    return path


def _prune_wals(ckpt_dir) -> None:
    """Drop WAL segments no committed checkpoint references — pruned
    steps' segments and orphans of crashed checkpoint attempts."""
    referenced = set()
    for step in ckpt.all_steps(ckpt_dir):
        try:
            meta = ckpt.read_meta(ckpt_dir, step)
        except (FileNotFoundError, ValueError):
            continue
        name = meta.get("extra", {}).get("stream", {}).get("wal")
        if name:
            referenced.add(name)
    for p in Path(ckpt_dir).glob("wal_*.jsonl"):
        if p.name not in referenced:
            try:
                os.remove(p)
            except OSError:
                pass


def _apply_record(svc: StreamService, rec: dict) -> None:
    op = rec["op"]
    if op == "buffer":
        svc._coalescer(rec["user"]).push(
            decode_row(rec), sign=rec["sign"],
            tick=rec.get("first_tick") or 0)
    elif op == "sched":
        svc._schedule_row(rec["user"], decode_row(rec), due=rec["due"])
    elif op == "admit":
        svc.admit(rec["user"], scale=rec.get("scale"))
    elif op == "evict":
        svc.evict(rec["user"])
    elif op == "push":
        svc.push(rec["user"], decode_row(rec), sign=rec["sign"])
    elif op == "tick":
        svc.tick()
    elif op == "flush":
        svc.flush(force=rec.get("force", False),
                  reason=rec.get("reason", "manual"))
    elif op == "decay":
        svc.decay(rec["alpha"])
    else:
        raise ValueError(f"unknown replay record op {op!r}")


def restore_service(ckpt_dir, *, step: Optional[int] = None,
                    mesh=None, warm: bool = False) -> StreamService:
    """Rebuild a ``StreamService`` from checkpoint + WAL replay.

    ``mesh``: optional mesh override for a sharded fleet — by default the
    mesh is rebuilt from the checkpoint's recorded axis names/sizes on the
    restoring machine's devices (``FactorStore.from_state`` then re-pins
    the sharded placement before any replayed mutation runs).

    ``warm``: run ``store.warmup()`` (the checkpointed ladder config
    makes every reachable shape enumerable) BEFORE the WAL replay, so
    the replayed mutation sequence — and everything the restored service
    serves afterwards — dispatches pre-compiled executables: restart
    restores a warm store bitwise and replays without re-tracing. In a
    surviving process the executable cache is metadata-shared, so a warm
    restore after warmed serving compiles nothing.
    """
    with obs_tracing.span("stream.restore", warm=warm):
        return _restore_service(ckpt_dir, step=step, mesh=mesh, warm=warm)


def _restore_service(ckpt_dir, *, step, mesh, warm) -> StreamService:
    if step is None:
        step = ckpt.latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    meta = ckpt.read_meta(ckpt_dir, step)
    s = meta.get("extra", {}).get("stream")
    if s is None:
        raise ValueError(
            f"checkpoint step {step} carries no stream meta — was it saved "
            "by checkpoint_service?")

    dtype = _np_dtype(s["dtype"])
    # The fleet template mirrors the recorded storage kind. Checkpoints
    # from before the record restore as dense (compat default); a
    # structured checkpoint read with a dense template — or any stale
    # reader that drops this branch — fails loudly inside ckpt.restore
    # (the block-stack leaf names do not match a dense 'fleet' leaf)
    # instead of reinterpreting block stacks as a dense fleet.
    structure = s.get("structure", "dense")
    cap = s["capacity"]
    if structure == "dense":
        template = {"fleet": np.zeros((cap, s["n"], s["n"]), dtype)}
    elif structure == "blocktridiag":
        from repro.core.structure import BlockTriDiagStorage

        b = int(s["block"])
        nb = s["n"] // b
        template = {"fleet": BlockTriDiagStorage(
            np.zeros((cap, nb, b, b), dtype),
            np.zeros((cap, max(nb - 1, 0), b, b), dtype))}
    else:
        raise ValueError(
            f"checkpoint step {step} records fleet structure "
            f"{structure!r}, which this reader does not support "
            "(supported: 'dense', 'blocktridiag')")
    data = ckpt.restore(ckpt_dir, step, template)["fleet"]
    mesh, axis = _mesh_from_json(s.get("mesh"), mesh=mesh)
    factor = CholFactor.from_factor(
        jax.tree.map(jnp.asarray, data), panel=s["panel"],
        backend=s["backend"], interpret=s["interpret"],
        precision=_precision_from_json(s["precision"]),
        mesh=mesh, axis=axis)
    store = FactorStore.from_state(
        factor, width=s["width"],
        slots={_user_key(u): slot for u, slot in s["slots"]},
        last_used={_user_key(u): t for u, t in s["last_used"]},
        init_scale=s["init_scale"],
        # Pre-ladder checkpoints carry no ladder/widths records:
        # from_state then derives the doubling ladder from the restored
        # capacity (the historical grow schedule) and default buckets.
        ladder=tuple(s["ladder"]) if s.get("ladder") else None,
        widths=tuple(s["widths"]) if s.get("widths") else None,
        # Recorded next-assigned-first; restores the live LIFO admission
        # order (eviction history makes it diverge from any derived one).
        empty_slots=(tuple(s["empty_slots"])
                     if s.get("empty_slots") is not None else None))
    if warm:
        store.warmup()
    svc = StreamService(store, window=s["window"], deadline=s["deadline"],
                        auto_flush=s["auto_flush"],
                        capacity=s["ring_capacity"])
    svc.tick_count = s["tick"]
    for u in store.users():
        # Slots restored from meta never went through svc.admit: hand each
        # already-admitted user its (empty) coalescer directly.
        svc._coalescers[u] = Coalescer(
            store.n, width=store.width, capacity=svc._ring_capacity,
            deadline=svc.deadline, dtype=store.row_dtype,
            block=store.block)

    wal_path = Path(ckpt_dir) / s["wal"]
    svc._replaying = True
    try:
        for rec in ReplayLog.read(wal_path):
            _apply_record(svc, rec)
    finally:
        svc._replaying = False
    svc.attach_wal(ReplayLog(wal_path))  # append-continue the same segment
    if s.get("background"):
        # Replay is strictly synchronous (the log's flush grouping is
        # authoritative); only the LIVE service gets its worker back.
        svc.start_background()
    return svc


def _user_key(u):
    """JSON round-trips int/str user ids natively; leave them as stored."""
    return u
