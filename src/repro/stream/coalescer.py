"""``Coalescer``: per-factor ring buffers turning rank-1 traffic into
rank-k flushes.

The paper's economics are blunt: the modification is bandwidth-bound, so
the only real lever is rank-k amortization (~7x at k=16 in the paper's
measurements) — yet streaming consumers naturally produce *rank-1*
observations, one per event. The coalescer is the missing adapter: it
buffers ``push_update(v)`` / ``push_downdate(v)`` rank-1 rows in fixed-
capacity ring buffers (one per sign) and drains them as full-width blocks
when a ring reaches the coalesce width (default k=16, the paper's sweet
spot), a deadline expires, or an explicit ``flush`` fires.

Flushes are **sign-scheduled**: the update block is absorbed first as ONE
fused rank-k update, then the downdate block through ``downdate_guarded``
— deferred downdates are ordered by the feasibility guard, not arrival
time. The reorder is sound because the target matrix
``A + sum u u^T - sum d d^T`` does not depend on application order and the
Cholesky factor of an SPD matrix with positive diagonal is unique, so any
order that stays SPD ends at the same factor (to rounding); updates-first
is the schedule that *maximises* the set of streams that stay SPD mid-
application. ``tests/test_stream.py`` carries the property-tested proof
against sequential application on SPD-preserving streams.

The coalescer is pure host-side bookkeeping (numpy, no jax imports at
module scope except for the convenience ``flush_into``): the device work
happens in whatever absorbs the drained blocks — ``flush_into`` for a
single ``CholFactor``, ``repro.stream.store.FactorStore`` for a fleet.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

DEFAULT_WIDTH = 16  # the paper's rank-k sweet spot


class RingBuffer:
    """Fixed-capacity FIFO ring of rank-1 rows (host memory, no realloc).

    Rows are stored in a preallocated ``(capacity, n)`` array; ``push``
    appends, ``drain`` removes the oldest ``limit`` rows in arrival order.
    The ring never reallocates in steady state — the serving loop's push
    path is O(n) per row with zero garbage.
    """

    def __init__(self, n: int, capacity: int, dtype=np.float32):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self._buf = np.zeros((capacity, n), dtype=dtype)
        self._head = 0  # index of the oldest row
        self._count = 0

    @property
    def capacity(self) -> int:
        return self._buf.shape[0]

    @property
    def count(self) -> int:
        return self._count

    @property
    def full(self) -> bool:
        return self._count == self.capacity

    def push(self, v) -> None:
        v = np.asarray(v, dtype=self._buf.dtype).reshape(-1)
        if v.shape[0] != self._buf.shape[1]:
            raise ValueError(
                f"row has dim {v.shape[0]}, ring expects {self._buf.shape[1]}"
            )
        if self.full:
            raise OverflowError(
                f"ring buffer full (capacity {self.capacity}); flush before "
                "pushing more"
            )
        tail = (self._head + self._count) % self.capacity
        self._buf[tail] = v
        self._count += 1

    def drain(self, limit: Optional[int] = None) -> np.ndarray:
        """Remove and return the oldest ``limit`` rows, arrival order."""
        m = self._count if limit is None else min(limit, self._count)
        idx = (self._head + np.arange(m)) % self.capacity
        out = self._buf[idx].copy()
        self._head = (self._head + m) % self.capacity
        self._count -= m
        return out

    def peek(self) -> np.ndarray:
        """All buffered rows, arrival order, without removing them."""
        idx = (self._head + np.arange(self._count)) % self.capacity
        return self._buf[idx].copy()


@dataclasses.dataclass
class DrainResult:
    """One sign-scheduled drain: the update block, then the downdate block.

    ``up_anchors``/``down_anchors`` carry each row's anchor block-row
    (``repro.core.structure.anchor_block``) when the coalescer was keyed
    to a structured factor's block size; ``None`` for dense coalescers.
    Anchors ride in ring order, aligned row-for-row with the blocks.
    """

    up: np.ndarray    # (k_up, n) rows, arrival order (may be empty)
    down: np.ndarray  # (k_dn, n) rows, arrival order (may be empty)
    up_anchors: Optional[Tuple[Optional[int], ...]] = None
    down_anchors: Optional[Tuple[Optional[int], ...]] = None

    @property
    def empty(self) -> bool:
        return self.up.shape[0] == 0 and self.down.shape[0] == 0


class Coalescer:
    """Buffer rank-1 observations for ONE factor; drain as rank-k blocks.

    Args:
      n: row dimension (must match the factor).
      width: coalesce width k — a drain returns at most ``width`` rows per
        sign, and ``ready`` fires when either ring holds ``width`` rows.
      capacity: ring capacity per sign (default ``2 * width``: headroom for
        deferred window-downdates landing on top of explicit traffic).
      deadline: optional staleness bound in ticks — ``expired(tick)`` is
        True once the oldest pending row has waited ``deadline`` ticks.
      dtype: host buffer dtype (rows are cast on push).
      block: block size b of the target factor's ``BlockTriDiagStorage``
        (None for dense factors). When set, every pushed row is keyed to
        its anchor block (``repro.core.structure.anchor_block``) at
        ``push()`` time — a row violating the block-local contract raises
        HERE, at ingest, instead of corrupting the storage class inside
        the kernel rounds later. Anchors travel with the drained blocks
        (``DrainResult.up_anchors`` / ``down_anchors``).
    """

    def __init__(self, n: int, *, width: int = DEFAULT_WIDTH,
                 capacity: Optional[int] = None,
                 deadline: Optional[int] = None, dtype=np.float32,
                 block: Optional[int] = None):
        if width < 1:
            raise ValueError(f"width must be >= 1, got {width}")
        if block is not None and (block < 1 or n % int(block)):
            raise ValueError(
                f"block= must divide n={n}, got block={block}")
        self.n = n
        self.width = width
        self.deadline = deadline
        self.block = int(block) if block is not None else None
        cap = 2 * width if capacity is None else capacity
        if cap < width:
            raise ValueError(f"capacity {cap} < width {width}")
        self._up = RingBuffer(n, cap, dtype)
        self._down = RingBuffer(n, cap, dtype)
        # Anchor queues ride beside the rings in the same FIFO order
        # (plain lists: drains pop from the front, pushes append).
        self._up_anchors: list = []
        self._down_anchors: list = []
        self._first_tick: Optional[int] = None

    def _anchor_of(self, v) -> Optional[int]:
        """The row's anchor block under the block-local contract, or None
        when this coalescer feeds a dense factor (no contract to key)."""
        if self.block is None:
            return None
        from repro.core.structure import anchor_block

        return anchor_block(v, self.block)

    # -- push ---------------------------------------------------------------
    def push_update(self, v, *, tick: int = 0) -> None:
        """Buffer a rank-1 update row (``+ v v^T`` at the next flush)."""
        anchor = self._anchor_of(v)  # contract check BEFORE mutating state
        self._up.push(v)
        self._up_anchors.append(anchor)
        if self._first_tick is None:
            self._first_tick = tick

    def push_downdate(self, v, *, tick: int = 0) -> None:
        """Buffer a rank-1 downdate row (``- v v^T`` at the next flush)."""
        anchor = self._anchor_of(v)
        self._down.push(v)
        self._down_anchors.append(anchor)
        if self._first_tick is None:
            self._first_tick = tick

    def push(self, v, *, sign: int = 1, tick: int = 0) -> None:
        if sign == 1:
            self.push_update(v, tick=tick)
        elif sign == -1:
            self.push_downdate(v, tick=tick)
        else:
            raise ValueError(f"sign must be +1 or -1, got {sign}")

    # -- flush policy -------------------------------------------------------
    @property
    def pending(self) -> int:
        return self._up.count + self._down.count

    @property
    def pending_up(self) -> int:
        return self._up.count

    @property
    def pending_down(self) -> int:
        return self._down.count

    @property
    def down_free(self) -> int:
        """Free downdate-ring slots (deferred window rows land here)."""
        return self._down.capacity - self._down.count

    def ready(self) -> bool:
        """Width trigger: either sign block has a full rank-k ready."""
        return (self._up.count >= self.width
                or self._down.count >= self.width)

    def expired(self, tick: int) -> bool:
        """Deadline trigger: the oldest pending row is too stale."""
        return (self.deadline is not None and self.pending > 0
                and self._first_tick is not None
                and tick - self._first_tick >= self.deadline)

    # -- drain --------------------------------------------------------------
    def drain(self, *, tick: int = 0, limit: Optional[int] = None
              ) -> DrainResult:
        """Remove up to ``width`` rows per sign (arrival order per ring).

        Sign scheduling happens at *application* time: callers absorb
        ``up`` first (one fused rank-k update), then ``down`` through the
        feasibility guard. Rows beyond ``width`` stay buffered; the
        staleness clock restarts at ``tick`` when anything remains.
        """
        lim = self.width if limit is None else limit
        up = self._up.drain(lim)
        down = self._down.drain(lim)
        if self.block is None:
            ua = da = None
        else:
            ua = tuple(self._up_anchors[:up.shape[0]])
            da = tuple(self._down_anchors[:down.shape[0]])
        del self._up_anchors[:up.shape[0]]
        del self._down_anchors[:down.shape[0]]
        res = DrainResult(up=up, down=down, up_anchors=ua, down_anchors=da)
        self._first_tick = tick if self.pending else None
        return res

    def peek(self) -> Tuple[np.ndarray, np.ndarray]:
        """Buffered (up_rows, down_rows) without draining — durability uses
        this to write the replay-log head at checkpoint time."""
        return self._up.peek(), self._down.peek()

    @property
    def first_tick(self) -> Optional[int]:
        return self._first_tick

    # -- single-factor convenience ------------------------------------------
    def _pad_sign_block(self, rows: np.ndarray, pad_to: Optional[int],
                        factor_block: Optional[int]) -> np.ndarray:
        """``(k, n)`` rows -> ``(n, >=k)`` V, zero-padded to ``pad_to``
        columns for shape-stable dispatch.

        Padding is storage-aware: the pad is zero COLUMNS of V — exact
        no-ops for both signs and trivially block-local (an all-zero
        column has no support, so it anchors nowhere) — never zero ROWS
        of a densified (n, n) carrier. A structured flush with a
        contract-keyed coalescer therefore pads without leaving the
        storage class; an un-keyed coalescer (``block=None``) flushing a
        structured factor re-validates the REAL columns here so the
        contract still fails at the flush boundary, not in the kernel.
        """
        V = rows.T  # (n, k)
        if factor_block is not None and self.block is None:
            from repro.core.structure import assert_blocklocal

            if V.shape[1]:
                assert_blocklocal(V, factor_block)
        if pad_to is not None and V.shape[1] < pad_to:
            pad = np.zeros((self.n, pad_to - V.shape[1]), V.dtype)
            V = np.concatenate([V, pad], axis=1)
        return V

    def flush_into(self, factor, *, pad_to: Optional[int] = None):
        """Drain and absorb into a single (non-batched) ``CholFactor``.

        Returns ``(factor', ok)``: the update block is applied first as one
        rank-k update, then the downdate block via ``downdate_guarded``
        (``ok`` is True when no downdate was pending). The fleet path lives
        in ``repro.stream.store.FactorStore``; this is the one-factor
        analogue for scripts and tests.

        ``pad_to``: zero-pad each non-empty sign block to this many
        columns (a width bucket) so mixed-width flushes share one
        executable shape. The pad is always zero V-columns — exact no-ops
        and block-local for structured factors (see ``_pad_sign_block``)
        — so shape stabilisation never densifies a structured flush.
        """
        import jax.numpy as jnp

        structured = getattr(factor, "structure", "dense") != "dense"
        fblock = factor.storage.block if structured else None
        blocks = self.drain()
        ok = True
        if blocks.up.shape[0]:
            V = self._pad_sign_block(blocks.up, pad_to, fblock)
            factor = factor.update(jnp.asarray(V))
        if blocks.down.shape[0]:
            V = self._pad_sign_block(blocks.down, pad_to, fblock)
            factor, ok = factor.downdate_guarded(jnp.asarray(V))
        return factor, ok

    def __repr__(self):
        key = f", block={self.block}" if self.block is not None else ""
        return (f"Coalescer(n={self.n}, width={self.width}{key}, "
                f"pending_up={self._up.count}, pending_down={self._down.count})")
