"""``StreamService``: the streaming update service over a factor fleet.

This is the layer between "a factor object" (``repro.core.CholFactor``)
and "a serving system": it owns one ``FactorStore`` fleet plus one
``Coalescer`` per admitted user, and turns per-user rank-1 traffic into
fused rank-k flushes:

* ``push(user, v, sign=+1)`` buffers a rank-1 observation (auto-admitting
  unknown users); with ``auto_flush`` a push that fills a user's ring
  triggers a fleet flush of every ready user.
* ``tick()`` advances the service's logical clock — the serving loop's
  heartbeat. It fires deadline flushes (stale buffers) and window expiry:
  a row absorbed with ``window=W`` is scheduled as a *future downdate* due
  ``W`` ticks later, the sliding-window forgetting of the online-ridge
  consumers, deferred and coalesced like everything else.
* ``flush(force=...)`` drains every selected user and issues at most ONE
  batched rank-k mutation per sign block per round (updates first, then
  guarded downdates — the coalescer's sign schedule), zero-padding
  non-flushing slots so the pre-compiled donated step never re-traces.
* ``decay(alpha)`` is exact exponential forgetting for the whole fleet.

**Background flushing** (``start_background()``): a bounded-queue daemon
worker (MaxText ``JetThread``-style) runs the flushes instead of the
caller. ``push``/``tick`` then only enqueue a flush *request* — the
producer returns immediately while the worker drains rings, builds
blocks and dispatches the donated steps, so host-side coalescing
overlaps device mutations. Every trigger enqueues; at each wake-up the
worker coalesces everything queued into ONE flush (selection recomputes
from the rings, so a burst of triggers is a single drain/apply pass),
and the queue is bounded, so a producer that outruns the device blocks
on ``put`` — backpressure, not unbounded buffering.
``tick()``/``flush()`` stay the synchronous fallback: with no
worker running, behaviour is exactly the pre-worker serving loop. All
state-changing entry points share one lock, so either mode (or both
interleaved) is safe.

Every state-changing call appends one record to the attached write-ahead
``ReplayLog`` (``repro.stream.durability``); checkpoint + log replay
reproduce the exact post-flush state after a crash, because flush events
are logged and replay re-issues the identical mutation sequence
(background flushes log identically — the record is written by whichever
thread runs the flush, under the lock).
"""
from __future__ import annotations

import dataclasses
import heapq
import queue
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import tracing as obs_tracing
from repro.stream.coalescer import Coalescer
from repro.stream.store import FactorStore

_MAX_FLUSH_ROUNDS = 64  # backstop: bounded work per flush call


@dataclasses.dataclass
class FlushReport:
    """What one ``flush`` call did (host-side bookkeeping for consumers).

    Attributes:
      absorbed: user -> number of update rows absorbed (FIFO order).
      downdated: user -> number of downdate rows applied (FIFO order);
        counted even when the guard refused (see ``downdate_ok``).
      downdate_ok: user -> feasibility verdict of that user's downdate
        block (absent when the user had no downdates this flush). A False
        verdict means the block was REFUSED — the slot is unchanged.
      mutations: batched rank-k mutations dispatched (one per sign block
        per round; 1–2 in the steady state).
      rounds: drain/apply rounds (1 unless a ring held > width rows).
      reason: 'width' | 'deadline' | 'manual' | 'force' | 'background'.
      t_coalesce_s: host seconds spent draining rings + building the
        zero-padded blocks (summed over rounds).
      t_mutate_s: host seconds spent inside ``store.apply`` dispatches
        (summed over rounds).
      widths: padded block width (the chosen width bucket) of every
        dispatched sign block, dispatch order.
    """

    absorbed: Dict[object, int] = dataclasses.field(default_factory=dict)
    downdated: Dict[object, int] = dataclasses.field(default_factory=dict)
    downdate_ok: Dict[object, bool] = dataclasses.field(default_factory=dict)
    mutations: int = 0
    rounds: int = 0
    reason: str = "manual"
    t_coalesce_s: float = 0.0
    t_mutate_s: float = 0.0
    widths: Tuple[int, ...] = ()

    @property
    def empty(self) -> bool:
        return not self.absorbed and not self.downdated


class _FlushWorker(threading.Thread):
    """Daemon flush worker (the MaxText ``JetThread`` shape): consumes
    flush requests from a bounded queue and runs them under the service
    lock, coalescing everything queued at wake-up into ONE flush (first
    request's reason, any request's force). An exception is captured, not
    swallowed — it re-raises at the next ``drain()``/``stop_background()``
    and the worker drops (but still acknowledges) later requests until
    the failure is cleared, so a poisoned flush cannot silently drop
    traffic; the dropped requests' rows stay buffered in the rings."""

    _STOP = object()

    def __init__(self, svc: "StreamService", maxsize: int):
        super().__init__(daemon=True, name="stream-flush-worker")
        self._svc = svc
        self.requests: "queue.Queue" = queue.Queue(maxsize=maxsize)
        self.exception: Optional[BaseException] = None

    def run(self) -> None:
        while True:
            batch = [self.requests.get()]
            # Coalesce: one flush serves every request already queued —
            # flush selection recomputes from the rings, so a burst of
            # triggers needs (and gets) a single drain/apply pass.
            while True:
                try:
                    batch.append(self.requests.get_nowait())
                except queue.Empty:
                    break
            stop = self._STOP in batch
            reqs = [r for r in batch if r is not self._STOP]
            try:
                if reqs and self.exception is None:
                    force = any(f for f, _ in reqs)
                    obs_metrics.gauge("repro.stream.queue_depth").set(
                        self.requests.qsize())
                    with obs_tracing.span("stream.background_flush",
                                          requests=len(reqs)):
                        self._svc._flush_sync(force=force, reason=reqs[0][1])
            except BaseException as e:  # noqa: BLE001 — reported at drain
                self.exception = e
            finally:
                for _ in batch:
                    self.requests.task_done()
            if stop:
                return

    def submit(self, force: bool, reason: str) -> None:
        self.requests.put((force, reason))
        obs_metrics.gauge("repro.stream.queue_depth").set(
            self.requests.qsize())

    def stop(self) -> None:
        self.requests.put(self._STOP)
        self.join()


class StreamService:
    """Coalescing streaming-update service over a ``FactorStore`` fleet.

    Args:
      store: the fleet (its ``width`` is the coalesce width).
      window: sliding-window length in ticks — every absorbed update row is
        scheduled as a downdate due ``window`` ticks after its flush (None:
        no forgetting).
      deadline: staleness bound in ticks — pending rows older than this
        force a flush at the next ``tick()`` (None: width/manual only).
      auto_flush: flush automatically when a push fills a user's ring.
      capacity: per-sign ring capacity per user (default ``2 * width``).
      background: start the background flush worker immediately (same as
        calling ``start_background()`` after construction).
      queue_size: bound on pending flush requests. The worker coalesces
        everything queued into one flush per wake-up; producers block on
        enqueue when the bound is hit — backpressure.
    """

    def __init__(self, store: FactorStore, *, window: Optional[int] = None,
                 deadline: Optional[int] = None, auto_flush: bool = True,
                 capacity: Optional[int] = None, background: bool = False,
                 queue_size: int = 64):
        self.store = store
        self.window = window
        self.deadline = deadline
        self.auto_flush = auto_flush
        self._ring_capacity = capacity
        self._queue_size = queue_size
        self.tick_count = 0
        self._coalescers: Dict[object, Coalescer] = {}
        # (due_tick, insertion_order, user, row) — heap by due tick.
        self._schedule: List[Tuple[int, int, object, np.ndarray]] = []
        self._sched_seq = 0
        self._wal = None          # durability.ReplayLog or None
        self._replaying = False   # replay applies logged flushes verbatim
        # One lock for every state-changing entry point: the background
        # worker and the producer thread interleave at call granularity.
        self._lock = threading.RLock()
        self._worker: Optional[_FlushWorker] = None
        self._bg_reports: List[FlushReport] = []
        if background:
            self.start_background()

    # -- background worker ---------------------------------------------------
    @property
    def background_active(self) -> bool:
        return self._worker is not None and self._worker.is_alive()

    def start_background(self) -> None:
        """Start the daemon flush worker (idempotent). From here on,
        flush triggers from ``push``/``tick`` are enqueued and executed
        off-thread; explicit ``flush()`` calls remain synchronous."""
        if self.background_active:
            return
        self._worker = _FlushWorker(self, self._queue_size)
        self._worker.start()

    def stop_background(self) -> None:
        """Stop the worker after it drains its queue; re-raises any
        exception the worker captured (with the pre-failure reports
        attached as ``partial_reports`` and cleared, like ``drain``).
        Pending ring contents stay buffered — they flush on the next
        trigger or ``flush(force=)``."""
        if self._worker is None:
            return
        self._worker.stop()
        exc, self._worker = self._worker.exception, None
        if exc is not None:
            raise self._attach_partial_reports(exc)

    def drain(self) -> Tuple[FlushReport, ...]:
        """Block until every enqueued background flush has run; returns
        (and clears) their reports. A captured worker exception re-raises
        here instead, carrying the reports of the flushes that DID run
        before the failure as ``exc.partial_reports`` (and clearing them,
        so they never leak into a later drain). Requests enqueued after a
        failure are acknowledged but dropped until a drain clears it —
        their rows stay buffered in the rings. No-op (empty tuple)
        without a worker."""
        if self._worker is None:
            return ()
        with obs_tracing.span("stream.drain"):
            self._worker.requests.join()
        if self._worker.exception is not None:
            exc, self._worker.exception = self._worker.exception, None
            raise self._attach_partial_reports(exc)
        with self._lock:
            reports, self._bg_reports = tuple(self._bg_reports), []
        return reports

    def _attach_partial_reports(self, exc: BaseException) -> BaseException:
        with self._lock:
            exc.partial_reports = tuple(self._bg_reports)
            self._bg_reports = []
        return exc

    def _trigger_flush(self, *, force: bool, reason: str
                       ) -> Optional[FlushReport]:
        """Route a flush trigger: enqueue to the worker or run
        synchronously. Every trigger is enqueued (the worker coalesces
        whatever is queued into one flush), so a producer that outruns
        the device fills the bounded queue and blocks on ``put`` —
        genuine backpressure. Called OUTSIDE the service lock, so a
        blocked producer never stalls the worker."""
        if self.background_active:
            self._worker.submit(force, reason)
            return None
        return self._flush_sync(force=force, reason=reason)

    # -- durability plumbing ------------------------------------------------
    def attach_wal(self, wal) -> None:
        """Attach the write-ahead log new events are appended to."""
        self._wal = wal

    def _log(self, record: dict) -> None:
        if self._wal is not None and not self._replaying:
            self._wal.append(record)

    # -- membership ---------------------------------------------------------
    def users(self):
        return self.store.users()

    def _coalescer(self, user) -> Coalescer:
        return self._coalescers[user]

    def admit(self, user, *, scale: Optional[float] = None) -> int:
        """Admit ``user`` into the fleet (idempotent)."""
        with self._lock:
            # Key on SERVICE membership, not store membership: a user
            # admitted directly on the FactorStore still needs its
            # coalescer here.
            known = user in self._coalescers
            slot = self.store.admit(user, scale=scale, tick=self.tick_count)
            if not known:
                # block= keys the ring to the fleet's storage contract: a
                # structured fleet's rows are anchor-validated at push
                # time (None for dense fleets — no contract to enforce).
                self._coalescers[user] = Coalescer(
                    self.store.n, width=self.store.width,
                    capacity=self._ring_capacity, deadline=self.deadline,
                    dtype=self.store.row_dtype, block=self.store.block)
                self._log({"op": "admit", "user": user, "scale": scale})
            return slot

    def evict(self, user) -> None:
        """Remove a user: pending buffer rows and scheduled downdates are
        DROPPED (the slot's statistics go with it — there is nothing left
        to keep consistent)."""
        with self._lock:
            self.store.evict(user)
            del self._coalescers[user]
            self._schedule = [e for e in self._schedule if e[2] != user]
            heapq.heapify(self._schedule)
            self._log({"op": "evict", "user": user})

    def evict_idle(self, *, max_idle: int) -> tuple:
        with self._lock:
            stale = tuple(
                u for u in self.store.users()
                if self.tick_count - self.store.last_used(u) > max_idle)
            for u in stale:
                self.evict(u)
            return stale

    # -- traffic ------------------------------------------------------------
    def push(self, user, v, *, sign: int = 1) -> Optional[FlushReport]:
        """Buffer one rank-1 observation; may auto-flush (report returned
        when the flush ran synchronously; a background worker returns the
        report via ``drain()`` instead).

        ``sign=+1`` is ``push_update``, ``-1`` ``push_downdate`` — the
        deferred mutation lands at the next flush, coalesced into that
        sign's rank-k block.
        """
        with self._lock:
            self.admit(user)
            v = np.asarray(v, self.store.row_dtype).reshape(-1)
            # Buffer BEFORE logging: a push that raises (full ring, wrong
            # dim) is survivable live, so it must not leave a poison
            # record that would re-raise inside every future replay.
            self._coalescers[user].push(v, sign=sign, tick=self.tick_count)
            self._log({"op": "push", "user": user, "sign": sign,
                       **_encode_row(v)})
            ready = (self.auto_flush and not self._replaying
                     and self._coalescers[user].ready())
        if ready:
            return self._trigger_flush(force=False, reason="width")
        return None

    def push_update(self, user, v) -> Optional[FlushReport]:
        return self.push(user, v, sign=1)

    def push_downdate(self, user, v) -> Optional[FlushReport]:
        return self.push(user, v, sign=-1)

    def tick(self) -> Optional[FlushReport]:
        """Advance the logical clock; fire deadline/window flushes."""
        with self._lock:
            self.tick_count += 1
            self._log({"op": "tick"})
            if self._replaying:
                return None
            due = self._schedule and self._schedule[0][0] <= self.tick_count
            expired = any(c.expired(self.tick_count)
                          for c in self._coalescers.values())
        if due or expired:
            return self._trigger_flush(force=False, reason="deadline")
        return None

    def decay(self, alpha) -> None:
        """Exact exponential forgetting across the fleet (``scale``)."""
        with self._lock:
            self._log({"op": "decay", "alpha": float(alpha)})
            self.store.decay(alpha)

    # -- window forgetting ---------------------------------------------------
    def _schedule_row(self, user, v, *, due: int) -> None:
        heapq.heappush(
            self._schedule,
            (due, self._sched_seq, user,
             np.asarray(v, self.store.row_dtype)))
        self._sched_seq += 1

    def scheduled(self) -> int:
        """Rows awaiting their window-expiry downdate."""
        return len(self._schedule)

    # -- the flush -----------------------------------------------------------
    def flush(self, *, force: bool = False, reason: str = "manual"
              ) -> FlushReport:
        """Drain + absorb: the coalescer's sign schedule over the fleet.

        Selection: users whose rings hit the width trigger, whose buffers
        passed the deadline, or who received due window-downdates; with
        ``force`` every user with any pending row. Each round builds one
        zero-padded block per sign and dispatches at most one batched
        mutation per block (updates first, then guarded downdates).
        Always synchronous — the caller's explicit flush runs in the
        caller's thread even when a background worker is active.
        """
        return self._flush_sync(force=force, reason=reason)

    def _flush_sync(self, *, force: bool, reason: str) -> FlushReport:
        with self._lock:
            t0 = time.perf_counter()
            with obs_tracing.span("stream.flush", reason=reason) as ev:
                report = self._flush_locked(force=force, reason=reason)
                ev.labels.update(reason=report.reason,
                                 mutations=report.mutations,
                                 rounds=report.rounds,
                                 empty=report.empty)
            if not report.empty:
                # Empty flushes (nothing selected) are free no-ops; letting
                # them into the histogram would drown the p50 in noise.
                obs_metrics.histogram(
                    "repro.stream.flush_seconds",
                    reason=report.reason).observe(time.perf_counter() - t0)
            if self._worker is not None and threading.current_thread() \
                    is self._worker:
                self._bg_reports.append(report)
            return report

    def _flush_locked(self, *, force: bool, reason: str) -> FlushReport:
        due_ready = bool(self._schedule
                         and self._schedule[0][0] <= self.tick_count)
        trigger = {u for u, c in self._coalescers.items()
                   if (force and c.pending) or c.ready()
                   or c.expired(self.tick_count)}
        report = FlushReport(reason="force" if force else reason)
        if not due_ready and not trigger:
            return report
        # Log BEFORE mutating: a crash mid-flush replays the whole flush
        # (selection recomputes identically from the replayed state).
        self._log({"op": "flush", "force": force, "reason": report.reason})

        # Due window rows become ordinary buffered downdates first, so ONE
        # code path (the ring drain) feeds the mutation — and the WAL
        # replay, which re-runs this method, reproduces it exactly. A
        # backlog of due groups (missed heartbeats) drains rounds early to
        # make ring room rather than overflowing.
        must: set = set()
        while self._schedule and self._schedule[0][0] <= self.tick_count:
            _, _, user, row = heapq.heappop(self._schedule)
            if user not in self._coalescers:
                continue  # evicted after scheduling: nothing left to forget
            c = self._coalescers[user]
            if c.down_free == 0:
                self._run_flush({user}, report)
            c.push_downdate(row, tick=self.tick_count)
            must.add(user)

        return self._run_flush(trigger | must, report)

    def _run_flush(self, selected: set, report: FlushReport) -> FlushReport:
        from repro.stream import store as store_mod

        store = self.store
        pending = set(selected)
        while pending and report.rounds < _MAX_FLUSH_ROUNDS:
            t_co = time.perf_counter()
            up_rows: Dict[int, np.ndarray] = {}
            dn_rows: Dict[int, np.ndarray] = {}
            dn_users: Dict[object, int] = {}
            for u in sorted(pending, key=store.slot):
                blocks = self._coalescers[u].drain(tick=self.tick_count)
                s = store.slot(u)
                if blocks.up.shape[0]:
                    up_rows[s] = blocks.up
                    report.absorbed[u] = (report.absorbed.get(u, 0)
                                          + blocks.up.shape[0])
                    if self.window is not None:
                        for row in blocks.up:
                            self._schedule_row(
                                u, row, due=self.tick_count + self.window)
                if blocks.down.shape[0]:
                    dn_rows[s] = blocks.down
                    dn_users[u] = s
                    report.downdated[u] = (report.downdated.get(u, 0)
                                           + blocks.down.shape[0])
            pending = {u for u in pending if self._coalescers[u].pending}

            Vup = store.pad_block(up_rows) if up_rows else None
            Vdn = store.pad_block(dn_rows) if dn_rows else None
            report.t_coalesce_s += time.perf_counter() - t_co
            if Vup is None and Vdn is None:
                break
            for sign, blk in (("up", Vup), ("down", Vdn)):
                if blk is not None:
                    w = int(blk.shape[-1])
                    report.widths += (w,)
                    obs_metrics.histogram(
                        "repro.stream.coalesce_width",
                        buckets=obs_metrics.WIDTH_BUCKETS,
                        sign=sign).observe(w)
            before = store_mod.mutations_issued()
            traces_before = store_mod.traces_counted()
            t_mu = time.perf_counter()
            ok = store.apply(Vup, Vdn)
            report.t_mutate_s += time.perf_counter() - t_mu
            # A step trace INSIDE flush dispatch means a serving-path shape
            # missed the warmed executables — the event the PR 6 retrace
            # guard exists to forbid. Warmup traces happen outside flushes,
            # so they never land here.
            retraced = store_mod.traces_counted() - traces_before
            if retraced:
                obs_metrics.counter("repro.stream.retraces").inc(retraced)
                obs_tracing.instant("stream.retrace", steps=retraced,
                                    reason=report.reason)
            report.mutations += store_mod.mutations_issued() - before
            report.rounds += 1
            if ok is not None:
                ok_host = np.asarray(ok)
                for u, s in dn_users.items():
                    verdict = bool(ok_host[s])
                    if not verdict:
                        obs_metrics.counter("repro.stream.guard_rejects"
                                            ).inc()
                    report.downdate_ok[u] = bool(
                        report.downdate_ok.get(u, True) and verdict)
        return report

    # -- reads ---------------------------------------------------------------
    def solve(self, user, b):
        """Solve against one user's maintained factor (reflects flushed
        state only — pending buffer rows are not yet absorbed)."""
        with self._lock:
            return self.store.factor_for(user).solve(b)

    def pending(self, user) -> int:
        return self._coalescers[user].pending if user in self._coalescers \
            else 0

    def __repr__(self):
        buffered = sum(c.pending for c in self._coalescers.values())
        return (f"StreamService(users={self.store.active}, "
                f"tick={self.tick_count}, buffered={buffered}, "
                f"scheduled={len(self._schedule)}, window={self.window}, "
                f"background={self.background_active}, "
                f"store={self.store!r})")


def _encode_row(v: np.ndarray) -> dict:
    """WAL row encoding — the codec lives in ``repro.stream.durability``;
    the call-time import avoids the module cycle (durability imports the
    service type for restore)."""
    from repro.stream.durability import encode_row

    return encode_row(v)
