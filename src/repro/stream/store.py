"""``FactorStore``: a managed fleet of per-user Cholesky factors.

One batched ``CholFactor`` of shape ``(capacity, n, n)`` holds every
admitted user's statistics. Capacity moves along a fixed **bucket
ladder** (default rungs double: ``(64, 128, 256, ...)`` at serving
scale): admission assigns slots from an explicit slot map
(``empty_slots`` / ``slot_to_user``) inside the current rung, and only a
*ladder boundary* — the rung filling up — promotes the fleet to the next
rung. Because the rungs are enumerable ahead of time, every executable
the serving path can ever need is compilable ahead of time too:
``warmup()`` (``repro.stream.warmup``) AOT-compiles the donated
up/down/both/scale/slot_set/promote steps for every rung × width bucket,
after which **steady-state serving never traces** — admission, eviction,
flushes and rung promotion all dispatch pre-compiled executables.

Every mutation of the fleet runs through ONE donated-buffer step, so the
serving loop never copies the O(B·n^2) fleet: the update block is
absorbed first as a single fused batched rank-k update, then the
downdate block via the feasibility guard (``downdate_guarded``) — the
sign schedule the coalescer's equivalence proof covers. Exponential
forgetting is ``decay(alpha)`` (the engine's exact ``scale``), also
donated. Blocks are zero-padded to a **width bucket** (default
``{1, width}``, the issue's coalesce-width ladder): zero columns are
exact no-ops for both signs, so traffic shape never changes executable
shape.

Instrumentation, two counters:

* ``mutations_issued()`` — batched rank-k mutations dispatched to the
  engine, ONE per sign block per ``apply`` call regardless of fleet
  size (the streaming analogue of
  ``repro.kernels.sharded.launches_traced``).
* ``traces_counted()`` — Python re-traces of the step functions (each
  step body increments it exactly once per trace). This is the
  compile-counter hook behind the retrace guard
  (``repro.stream.warmup.assert_no_retrace``): after ``warmup()`` a
  serving sequence must move ``mutations_issued`` but NOT
  ``traces_counted`` — any post-warmup trace is a hard test failure.

Sharded placement (DESIGN.md §10): constructed with ``backend='sharded'``
and a ``mesh=``/``axis=`` binding, the fleet's members are each
column-sharded over the mesh and the same donated steps dispatch
per-shard through the fleet-native distributed driver: one kernel launch
per shard per sign block, independent of the fleet size. Warmup lowers
against sharded avals (``jax.ShapeDtypeStruct(..., sharding=...)``), so
the AOT executables are placement-exact.
"""
from __future__ import annotations

import contextlib
import functools
import time
import warnings
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CholFactor
from repro.core import structure as _structure
from repro.core.precision import Precision
from repro.obs import metrics as obs_metrics


@contextlib.contextmanager
def _quiet_donation():
    """Suppress the unusable-donation warning around OUR steps only.

    Donation is best-effort: XLA:CPU cannot donate and warns per compile.
    It is still correct (and load-bearing) on TPU/GPU, where the fleet
    would otherwise be copied once per flush. Scoped here so user code
    keeps seeing the warning for its own jits.
    """
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        yield

# Host-side instrumentation: batched rank-k mutations dispatched to the
# engine (one per sign block per apply), and Python traces of the step
# functions (each step body bumps once per trace — tracing executes the
# body; cached executions do not; the retrace guard reads the latter).
# Since PR 9 both live in the ``repro.obs`` registry
# (``repro.stream.mutations{sign=...}`` / ``repro.stream.step_traces``);
# ``mutations_issued``/``traces_counted`` are thin read-back shims, so the
# registry snapshot and the legacy counters can never disagree.


def mutations_issued() -> int:
    """Cumulative batched mutations dispatched by every store (see above)."""
    return int(obs_metrics.total("repro.stream.mutations"))


def traces_counted() -> int:
    """Cumulative step-function traces across every store — the
    compile-counter the retrace guard (warmup module) asserts against."""
    return int(obs_metrics.total("repro.stream.step_traces"))


def _count_mutation(k: int = 1, *, sign: str = "both") -> None:
    obs_metrics.counter("repro.stream.mutations", sign=sign).inc(k)


def _count_trace(step: str = "unknown") -> None:
    obs_metrics.counter("repro.stream.step_traces", step=step).inc()


# -- the bucket ladder --------------------------------------------------------

#: Serving-scale default rungs (the issue's B ladder). Stores built with
#: a bare ``capacity=`` derive a doubling ladder from it instead, so small
#: test/bench fleets stay small; production configs pass this explicitly.
DEFAULT_LADDER = (64, 128, 256, 512, 1024, 2048)

_DERIVED_RUNGS = 8  # capacity -> (c, 2c, 4c, ... c*2^7)


#: Storage structures the stream stack can hold as fleet members. The
#: coalescer/flush path is layout-agnostic (rows are dense (n,) vectors
#: either way); what a structure needs to qualify is batched storage
#: (4-D block stacks here) plus a batched engine path in
#: ``api.chol_update_batched``.
SUPPORTED_STRUCTURES = ("dense", "blocktridiag")


class UnsupportedStorageError(TypeError):
    """A fleet/storage layout the stream stack does not support.

    Raised UP FRONT — at store construction or ``from_state`` — naming the
    offending layout and the supported set, matching the
    ``backends.resolve`` rejection discipline (a structured fleet must
    never fail deep inside a step trace with a shape error).
    """


class LadderFullError(RuntimeError):
    """Admission refused: the top ladder rung is full.

    The fixed ladder is what makes trace-free serving possible (every
    reachable capacity is pre-compiled), so the store will not silently
    grow past it. Evict idle users, ``compact()``, or construct the
    store with a taller ``ladder=``.
    """


def ladder_from(capacity: int, *, rungs: int = _DERIVED_RUNGS
                ) -> Tuple[int, ...]:
    """The derived doubling ladder rooted at ``capacity``."""
    return tuple(capacity << i for i in range(rungs))


def _validate_ladder(ladder) -> Tuple[int, ...]:
    rungs = tuple(int(c) for c in ladder)
    if not rungs or any(c < 1 for c in rungs):
        raise ValueError(f"ladder rungs must be positive, got {rungs}")
    if any(b <= a for a, b in zip(rungs, rungs[1:])):
        raise ValueError(f"ladder must be strictly increasing, got {rungs}")
    return rungs


def _width_buckets(width: int, widths) -> Tuple[int, ...]:
    """Sorted width buckets; must be able to carry a full-width block."""
    if widths is None:
        buckets = (1, width) if width > 1 else (1,)
    else:
        buckets = tuple(sorted({int(w) for w in widths}))
    if not buckets or any(w < 1 for w in buckets):
        raise ValueError(f"width buckets must be positive, got {buckets}")
    if buckets[-1] < width:
        raise ValueError(
            f"largest width bucket {buckets[-1]} < coalesce width {width}")
    return buckets


def row_dtype_for(factor_dtype) -> np.dtype:
    """Exact host buffer dtype for rank-1 rows of a fleet of this dtype."""
    if np.dtype(jnp.dtype(factor_dtype)) == np.dtype(np.float64):
        return np.dtype(np.float64)
    return np.dtype(np.float32)


def _axis_key(axis):
    """Hashable canonical form of a mesh-axis binding (str/tuple/list) —
    the SAME normalization the sharded driver applies."""
    from repro.core.distributed import axis_tuple

    return axis_tuple(axis)


def fleet_sharding(mesh, axis):
    """The fleet placement: batch replicated, columns sharded over ``axis``."""
    from jax.sharding import NamedSharding, PartitionSpec

    from repro.core.distributed import axis_tuple

    return NamedSharding(mesh, PartitionSpec(None, None, axis_tuple(axis)))


# -- the step set: jitted fallbacks + AOT executable cache -------------------


def _shape_key(args) -> tuple:
    """Hashable (treedef, leaf shape/dtype) signature of concrete args or
    avals. Flattening makes storage pytrees (structured fleets) key by
    their leaves, so an aval-compiled executable and the concrete call
    agree on the same key."""
    leaves, treedef = jax.tree_util.tree_flatten(args)
    return (treedef,) + tuple(
        (tuple(np.shape(a)), jnp.dtype(a.dtype).name) for a in leaves)


class StepSet:
    """Donated mutation steps for one execution-metadata signature.

    Two dispatch tiers share one set of step *functions*:

    * ``jitted`` — ``jax.jit(step, donate_argnums=0)`` callables. Cold
      path: first call at a new shape traces (``traces_counted`` moves).
    * ``compiled`` — AOT executables from
      ``jit(...).lower(avals).compile()``, keyed on the arg shape/dtype
      signature. ``FactorStore.warmup()`` fills this for every ladder
      rung × width bucket; ``call`` prefers it, so a warmed serving path
      never reaches the tracing tier.

    ``cold_dispatches`` counts calls that missed the executable cache —
    a softer diagnostic than the trace counter (a miss may still hit the
    jit cache without tracing).
    """

    def __init__(self, jitted: dict):
        self.jitted = jitted
        self.compiled: Dict[tuple, object] = {}
        self.cold_dispatches = 0

    def call(self, name: str, *args):
        fn = self.compiled.get((name,) + _shape_key(args))
        if fn is None:
            self.cold_dispatches += 1
            tier = "jitted"
            fn = self.jitted[name]
        else:
            tier = "compiled"
        obs_metrics.counter("repro.stream.step_dispatch", tier=tier,
                            step=name).inc()
        with _quiet_donation():
            return fn(*args)

    def compile_step(self, name: str, avals) -> bool:
        """AOT-compile ``name`` for ``avals`` (ShapeDtypeStructs); returns
        True when a new executable was built, False on a cache hit.

        Each build's wall-clock lands in the registry histogram
        ``repro.stream.compile_seconds{step=...,sharded=0|1}`` — the
        per-executable compile times the aggregate ``WarmupReport.seconds``
        used to swallow.
        """
        key = (name,) + _shape_key(avals)
        if key in self.compiled:
            return False
        sharded = int(any(getattr(a, "sharding", None) is not None
                          for a in jax.tree_util.tree_leaves(avals)))
        t0 = time.perf_counter()
        with _quiet_donation():
            self.compiled[key] = self.jitted[name].lower(*avals).compile()
        obs_metrics.histogram("repro.stream.compile_seconds", step=name,
                              sharded=sharded).observe(
                                  time.perf_counter() - t0)
        return True

    @property
    def executables(self) -> int:
        return len(self.compiled)


@functools.lru_cache(maxsize=64)
def _steps_for(panel: int, backend: str, interpret: Optional[bool],
               precision: Optional[Precision], mesh=None, axis="model"
               ) -> StepSet:
    """The donated mutation ``StepSet``, shared across stores with equal
    meta.

    jit caches key on (closure identity, shapes); caching the closures
    here means two stores with the same execution metadata — or a store
    restored after a crash in the same process — share both the jit
    cache AND the AOT executable cache, so a warmed signature stays warm
    across store instances. ``mesh``/``axis`` ride for sharded placements
    (jax Meshes hash by axis names + device ids, so equal meshes share
    one entry): the steps then dispatch per-shard through the
    fleet-native distributed driver, and donation keeps the sharded
    fleet in place.
    """
    meta = dict(panel=panel, backend=backend, interpret=interpret,
                precision=precision, mesh=mesh, axis=axis)

    def up_only(data, vup):
        _count_trace("up")
        return CholFactor.from_factor(data, **meta).update(vup).data

    def down_only(data, vdn):
        _count_trace("down")
        f, ok = CholFactor.from_factor(data, **meta).downdate_guarded(vdn)
        return f.data, ok

    def both(data, vup, vdn):
        _count_trace("both")
        f = CholFactor.from_factor(data, **meta).update(vup)
        f, ok = f.downdate_guarded(vdn)
        return f.data, ok

    def scale(data, alpha):
        _count_trace("scale")
        return CholFactor.from_factor(data, **meta).scale(alpha).data

    def slot_set(data, slot, block):
        # tree.map over the fleet value: one array for a dense fleet, the
        # (diag, off) block stacks for a structured one — each leaf's slot
        # row is replaced by the member block's matching leaf.
        _count_trace("slot_set")
        return jax.tree.map(
            lambda d, b: d.at[slot].set(b.astype(d.dtype)), data, block)

    def promote(data, fresh):
        # Rung promotion: the one amortised O(B n^2) copy, now an AOT
        # step like everything else so a ladder boundary crossed in
        # steady state does not trace.
        _count_trace("promote")
        return jax.tree.map(
            lambda d, f: jnp.concatenate([d, f.astype(d.dtype)]),
            data, fresh)

    donate = dict(donate_argnums=0)
    out = None
    if mesh is not None:
        # Promotion output must land on the fleet placement directly —
        # an eager re-pin after the fact would defeat donation.
        out = fleet_sharding(mesh, axis)
    return StepSet({
        "up": jax.jit(up_only, **donate),
        "down": jax.jit(down_only, **donate),
        "both": jax.jit(both, **donate),
        "scale": jax.jit(scale, **donate),
        "slot_set": jax.jit(slot_set, **donate),
        "promote": jax.jit(promote, out_shardings=out, **donate),
    })


class FactorStore:
    """Fleet manager over one batched ``CholFactor`` (see module docstring).

    Args:
      n: per-user factor dimension.
      capacity: requested initial slot count — snapped UP to the smallest
        ladder rung that holds it.
      ladder: the fixed capacity ladder (strictly increasing). Default:
        a doubling ladder rooted at ``capacity`` (``ladder_from``);
        serving configs pass an explicit one (e.g. ``DEFAULT_LADDER``).
        Admission past the top rung raises ``LadderFullError`` — the
        store never silently grows past its pre-compiled shapes.
      width: coalesce width k — the static max rank of a flush mutation.
      widths: the width buckets blocks are zero-padded to (default
        ``{1, width}``): a flush picks the smallest bucket that carries
        its largest per-slot row count, so near-empty flushes pay k=1
        shapes, full ones k=width — all pre-compiled by ``warmup()``.
      panel / backend / interpret / precision: execution metadata threaded
        onto the fleet's ``CholFactor`` (DESIGN.md §7/§8).
      mesh / axis: sharded placement (DESIGN.md §10) — with
        ``backend='sharded'`` every fleet member is column-sharded
        ``P(None, None, axis)`` over the mesh and the donated steps
        dispatch per-shard (one kernel launch per shard per sign block,
        independent of the fleet size); every membership operation
        (admit / promote / evict / compact / decay) preserves the
        placement.
      init_scale: admitted slots start as the factor of ``init_scale * I``
        (the ridge/eps warm start).
      dtype: logical dtype of the fleet (storage dtype under a precision
        policy).
      structure: member storage layout — 'dense' (default, ``(B, n, n)``)
        or 'blocktridiag' (``(B, nb, b, b)`` block stacks, O(n·b) per
        member; requires ``block=``). Unsupported layouts raise
        ``UnsupportedStorageError`` HERE, before any step traces.
      block: block size b for 'blocktridiag' (must divide n).
    """

    def __init__(self, n: int, *, capacity: int = 8, width: int = 16,
                 ladder: Optional[Tuple[int, ...]] = None,
                 widths: Optional[Tuple[int, ...]] = None,
                 panel: int = 64, backend: str = "auto",
                 interpret: Optional[bool] = None, precision=None,
                 mesh=None, axis="model",
                 init_scale: float = 1.0, dtype=jnp.float32,
                 structure: str = "dense", block: Optional[int] = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if backend == "sharded" and mesh is None:
            raise ValueError("backend='sharded' requires a mesh= placement")
        if backend != "sharded" and mesh is not None:
            # The inverse misconfiguration must fail just as loudly:
            # silently dropping the mesh would leave a fleet sized for
            # multi-device placement fully replicated on one device.
            raise ValueError(
                f"mesh= placement requires backend='sharded' "
                f"(got backend={backend!r})")
        if structure not in SUPPORTED_STRUCTURES:
            raise UnsupportedStorageError(
                f"fleet structure {structure!r} is not supported by the "
                f"stream stack; supported: {SUPPORTED_STRUCTURES}")
        if structure == "blocktridiag":
            if block is None or n % int(block):
                raise ValueError(
                    f"structure='blocktridiag' requires block= dividing "
                    f"n={n}, got block={block}")
            if mesh is not None:
                raise UnsupportedStorageError(
                    "structured fleets do not compose with mesh= placement "
                    "yet (block-chain halo sharding is the open ROADMAP "
                    "item); supported sharded structure: 'dense'")
            # Same up-front rejection funnel as a single structured factor:
            # an explicit dense-only backend must fail here by name, and
            # 'auto' must resolve to a structured-capable method.
            from repro.core import backends as _backends
            _backends.resolve(backend, n=n, panel=panel, interpret=interpret,
                              structure="blocktridiag")
        self.ladder = (_validate_ladder(ladder) if ladder is not None
                       else ladder_from(capacity))
        capacity = self._rung_for(capacity)
        policy = Precision.parse(precision)
        storage = jnp.dtype(dtype) if policy is None else jnp.dtype(
            policy.storage_for(dtype))
        self.n = n
        self.width = width
        self.widths = _width_buckets(width, widths)
        self.init_scale = float(init_scale)
        self._mesh = mesh if backend == "sharded" else None
        self._axis = axis
        self._storage = storage
        self._structure = structure
        self._block = int(block) if structure == "blocktridiag" else None
        self._factor = CholFactor.from_factor(
            self._place(jax.tree.map(jnp.asarray,
                                     self._fresh_blocks(capacity))),
            panel=panel, backend=backend, interpret=interpret,
            precision=policy, mesh=self._mesh, axis=axis)
        self._slot_of: Dict[object, int] = {}
        self._slot_to_user: Dict[int, object] = {}
        self._empty_slots: List[int] = list(range(capacity - 1, -1, -1))
        self._last_used: Dict[object, int] = {}
        self._steps = _steps_for(panel, backend, interpret, policy,
                                 self._mesh, _axis_key(axis))
        self._observe_occupancy()

    # -- observability -------------------------------------------------------
    def _observe_occupancy(self) -> None:
        """Refresh the ladder gauges after any membership/rung change:
        occupancy (active/capacity fraction), active count, capacity."""
        cap = self.capacity
        obs_metrics.gauge("repro.stream.ladder_occupancy").set(
            self.active / cap if cap else 0.0)
        obs_metrics.gauge("repro.stream.active").set(self.active)
        obs_metrics.gauge("repro.stream.capacity").set(cap)

    # -- ladder arithmetic ---------------------------------------------------
    def _rung_for(self, capacity: int) -> int:
        """Smallest ladder rung holding ``capacity`` slots."""
        for rung in self.ladder:
            if rung >= capacity:
                return rung
        raise LadderFullError(
            f"{capacity} slots exceed the top ladder rung "
            f"{self.ladder[-1]} (ladder={self.ladder})")

    def _fresh_member(self, scale: Optional[float] = None):
        """ONE warm-start factor ``sqrt(scale) * I`` in the fleet's member
        layout: an (n, n) eye for dense, the identity's (nb, b, b) /
        (nb-1, b, b) block stacks for blocktridiag — never a densified
        intermediate. Host-side numpy either way."""
        calc = row_dtype_for(self._storage)
        root = np.sqrt(self.init_scale if scale is None else float(scale),
                       dtype=calc)
        if self._structure == "blocktridiag":
            b = self._block
            nb = self.n // b
            eye = (root * np.eye(b, dtype=calc)).astype(self._storage)
            return _structure.BlockTriDiagStorage(
                np.broadcast_to(eye, (nb, b, b)),
                np.zeros((max(nb - 1, 0), b, b), self._storage))
        return (root * np.eye(self.n, dtype=calc)).astype(self._storage)

    def _fresh_blocks(self, count: int):
        """``count`` stacked warm-start factors ``sqrt(init_scale) * I``,
        built host-side: the serving path stays free of eager device ops
        (everything it dispatches is a pre-compiled step). Dense fleets
        get a (count, n, n) eye stack; structured fleets get the member
        block stacks broadcast over a leading fleet axis."""
        # Compute in the fleet's row dtype, not a hardcoded f32: an f64
        # fleet must not round its init scalar through float32 (bf16/f32
        # fleets keep f32 arithmetic — bit-identical to before). Derived
        # from _storage, not the row_dtype property: the constructor calls
        # this before self._factor exists.
        member = self._fresh_member()
        return jax.tree.map(
            lambda m: np.broadcast_to(m, (count,) + m.shape), member)

    # -- sharded placement ---------------------------------------------------
    def _place(self, data):
        """Pin fleet data to the sharded placement (no-op unsharded)."""
        if self._mesh is None:
            return data
        return jax.device_put(data, fleet_sharding(self._mesh, self._axis))

    # -- reconstruction (durability) ----------------------------------------
    @classmethod
    def from_state(cls, factor: CholFactor, *, width: int,
                   slots: Dict[object, int], last_used: Dict[object, int],
                   init_scale: float,
                   ladder: Optional[Tuple[int, ...]] = None,
                   widths: Optional[Tuple[int, ...]] = None,
                   empty_slots: Optional[Tuple[int, ...]] = None
                   ) -> "FactorStore":
        """Rebuild a store around restored fleet data + slot table.

        A sharded fleet rides in on the factor's own mesh/axis aux (the
        durability layer rebuilds the mesh from checkpoint meta before
        calling this), so the restored store re-pins the placement. The
        ladder defaults to a doubling ladder rooted at the restored
        capacity — pre-ladder checkpoints restore with their historical
        grow schedule.

        ``empty_slots``: the live store's free-slot order in
        ``empty_slots``-property convention (next-assigned first). Passing
        it makes restored admission pop the SAME slots the pre-crash
        process would have — required for bitwise kill-and-restart, since
        eviction history makes the LIFO order diverge from any derived
        one. Omitted (pre-slot-map checkpoints), the order falls back to
        descending slot index.
        """
        storage = factor.storage
        if factor.structure not in SUPPORTED_STRUCTURES:
            # Typed, up-front, names the class and the supported set —
            # not a shape error three steps later.
            raise UnsupportedStorageError(
                f"fleet factor holds {type(factor.data).__name__} "
                f"(structure {factor.structure!r}), which the stream "
                f"stack does not support; supported structures: "
                f"{SUPPORTED_STRUCTURES}")
        if not factor.batched:
            raise UnsupportedStorageError(
                f"fleet factor must be batched — (B, n, n) dense or a "
                f"batched BlockTriDiagStorage with (B, nb, b, b) block "
                f"stacks; got {storage.describe()}")
        cap = storage.batch
        self = cls.__new__(cls)
        self.n = factor.n
        self.width = width
        self.widths = _width_buckets(width, widths)
        self.ladder = (_validate_ladder(ladder) if ladder is not None
                       else ladder_from(cap))
        if cap not in self.ladder:
            raise ValueError(
                f"restored capacity {cap} is not a rung of the ladder "
                f"{self.ladder}")
        self.init_scale = float(init_scale)
        self._mesh = factor.mesh if factor.backend == "sharded" else None
        self._axis = factor.axis
        self._storage = jnp.dtype(factor.dtype)
        self._structure = factor.structure
        self._block = (storage.block if factor.structure == "blocktridiag"
                       else None)
        self._factor = factor.replace(data=self._place(factor.data))
        self._slot_of = dict(slots)
        self._slot_to_user = {s: u for u, s in self._slot_of.items()}
        taken = set(self._slot_of.values())
        free = {s for s in range(cap) if s not in taken}
        if empty_slots is None:
            self._empty_slots = sorted(free, reverse=True)
        else:
            if set(empty_slots) != free or len(empty_slots) != len(free):
                raise ValueError(
                    f"restored empty_slots {tuple(empty_slots)} do not "
                    f"match the slots the slot table leaves free "
                    f"({sorted(free)})")
            # Property order is next-assigned FIRST; the internal stack
            # pops from the end.
            self._empty_slots = list(reversed(empty_slots))
        self._last_used = dict(last_used)
        self._steps = _steps_for(factor.panel, factor.backend,
                                 factor.interpret, factor.precision,
                                 self._mesh, _axis_key(factor.axis))
        self._observe_occupancy()
        return self

    # -- views --------------------------------------------------------------
    @property
    def factor(self) -> CholFactor:
        """The live batched fleet factor (read: solve/logdet/diagnostics)."""
        return self._factor

    @property
    def capacity(self) -> int:
        return self._factor.storage.batch

    @property
    def structure(self) -> str:
        """Member storage layout: 'dense' or 'blocktridiag'."""
        return self._structure

    @property
    def block(self) -> Optional[int]:
        """Block size b of a blocktridiag fleet, None for dense. The
        coalescer's block-local contract key (service threads it into
        every per-user ring)."""
        return self._block

    @property
    def empty_slots(self) -> Tuple[int, ...]:
        """Free slots at the current rung, next-assigned first (LIFO)."""
        return tuple(reversed(self._empty_slots))

    @property
    def slot_to_user(self) -> Dict[int, object]:
        """Occupied slot -> user (a copy; admission mutates the real map)."""
        return dict(self._slot_to_user)

    @property
    def steps(self) -> StepSet:
        """The shared step set (executable cache, cold-dispatch counter)."""
        return self._steps

    @property
    def row_dtype(self) -> np.dtype:
        """Host dtype buffered rows are kept in: wide enough to be exact
        for this fleet. f64 fleets buffer f64 (anything narrower would
        silently truncate observations); everything else — f32 and
        narrow-storage policies like bf16 — buffers f32, which the engine
        casts to ``L.dtype`` at dispatch without information loss."""
        return row_dtype_for(self._factor.dtype)

    @property
    def active(self) -> int:
        return len(self._slot_of)

    def users(self):
        return tuple(self._slot_of)

    def slot(self, user) -> int:
        return self._slot_of[user]

    def has(self, user) -> bool:
        return user in self._slot_of

    def last_used(self, user) -> int:
        return self._last_used[user]

    def factor_for(self, user) -> CholFactor:
        """A single-user view (shares the fleet's execution metadata)."""
        s = self.slot(user)
        member = jax.tree.map(lambda x: x[s], self._factor.data)
        return self._factor.replace(data=member)

    # -- aval views (AOT warmup lowers against these) ------------------------
    def fleet_aval(self, capacity: int, *, sharding=None):
        """The aval (pytree of ShapeDtypeStructs) of a ``capacity``-member
        fleet — what the donated steps take as their fleet argument.
        ``sharding`` applies to dense fleets only (structured fleets
        reject mesh placement at construction)."""
        if self._structure == "blocktridiag":
            b = self._block
            nb = self.n // b
            return _structure.BlockTriDiagStorage.tree_unflatten(None, (
                jax.ShapeDtypeStruct((capacity, nb, b, b), self._storage),
                jax.ShapeDtypeStruct((capacity, max(nb - 1, 0), b, b),
                                     self._storage)))
        if sharding is not None:
            return jax.ShapeDtypeStruct((capacity, self.n, self.n),
                                        self._storage, sharding=sharding)
        return jax.ShapeDtypeStruct((capacity, self.n, self.n),
                                    self._storage)

    def member_aval(self):
        """The aval of ONE member block (the ``slot_set`` payload)."""
        if self._structure == "blocktridiag":
            b = self._block
            nb = self.n // b
            return _structure.BlockTriDiagStorage.tree_unflatten(None, (
                jax.ShapeDtypeStruct((nb, b, b), self._storage),
                jax.ShapeDtypeStruct((max(nb - 1, 0), b, b),
                                     self._storage)))
        return jax.ShapeDtypeStruct((self.n, self.n), self._storage)

    # -- warmup (AOT executables) --------------------------------------------
    def warmup(self, **kw):
        """AOT-compile every ladder rung's executables; see
        ``repro.stream.warmup.warmup_store`` for the knobs/report."""
        from repro.stream.warmup import warmup_store

        return warmup_store(self, **kw)

    # -- fleet membership ---------------------------------------------------
    def admit(self, user, *, scale: Optional[float] = None,
              tick: int = 0) -> int:
        """Assign ``user`` a slot warm-started at ``scale * I``, promoting
        to the next ladder rung when the current one is full (raises
        ``LadderFullError`` at the top). Idempotent for already-admitted
        users."""
        if user in self._slot_of:
            self._last_used[user] = tick
            return self._slot_of[user]
        if not self._empty_slots:
            self._promote()
        s = self._empty_slots.pop()
        # Warm-start member in the fleet's own layout (dense eye or
        # identity block stacks) — same init arithmetic as _fresh_blocks.
        member = self._fresh_member(scale)
        new_data = self._steps.call(
            "slot_set", self._factor.data, np.int32(s), member)
        self._factor = self._factor.replace(data=new_data)
        self._slot_of[user] = s
        self._slot_to_user[s] = user
        self._last_used[user] = tick
        obs_metrics.counter("repro.stream.admissions").inc()
        self._observe_occupancy()
        return s

    def evict(self, user) -> int:
        """Free a user's slot (data is reset on the next admit).

        This is the slot-map primitive. A store managed by a
        ``StreamService`` must be evicted through ``service.evict`` /
        ``service.evict_idle`` instead — the service also owns the user's
        coalescer, window schedule and WAL record, which this call cannot
        see.
        """
        s = self._slot_of.pop(user)
        del self._slot_to_user[s]
        del self._last_used[user]
        self._empty_slots.append(s)
        obs_metrics.counter("repro.stream.evictions").inc()
        self._observe_occupancy()
        return s

    def _promote(self) -> None:
        """Cross the ladder boundary: concatenate fresh warm-start blocks
        up to the next rung through the donated AOT ``promote`` step (the
        one amortised O(B n^2) copy; placement-preserving)."""
        cap = self.capacity
        idx = self.ladder.index(cap)
        if idx + 1 >= len(self.ladder):
            raise LadderFullError(
                f"fleet full at the top ladder rung ({cap} slots, "
                f"ladder={self.ladder}); evict users, compact(), or "
                "construct the store with a taller ladder=")
        nxt = self.ladder[idx + 1]
        new_data = self._steps.call(
            "promote", self._factor.data, self._fresh_blocks(nxt - cap))
        self._factor = self._factor.replace(data=new_data)
        self._empty_slots.extend(range(nxt - 1, cap - 1, -1))
        obs_metrics.counter("repro.stream.promotions").inc()
        self._observe_occupancy()

    def compact(self, *, min_capacity: int = 1) -> Dict[object, int]:
        """Shrink the fleet to the smallest rung holding its active slots
        (one gather + remap).

        Returns the new user -> slot mapping. The copy is explicit and
        caller-scheduled — compaction is a maintenance event, not a
        serving-loop step (it is the one membership operation allowed to
        dispatch eagerly).
        """
        order = sorted(self._slot_of.items(), key=lambda kv: kv[1])
        keep = [s for _, s in order]
        new_cap = self._rung_for(max(len(keep), min_capacity))
        idx = keep + [0] * (new_cap - len(keep))  # pad slots: reset on admit
        gather = jnp.asarray(idx, jnp.int32)
        data = jax.tree.map(lambda x: x[gather], self._factor.data)
        self._factor = self._factor.replace(data=self._place(data))
        self._slot_of = {u: i for i, (u, _) in enumerate(order)}
        self._slot_to_user = {i: u for u, i in self._slot_of.items()}
        self._empty_slots = list(range(new_cap - 1, len(keep) - 1, -1))
        obs_metrics.counter("repro.stream.compactions").inc()
        self._observe_occupancy()
        return dict(self._slot_of)

    # -- mutations ----------------------------------------------------------
    def apply(self, Vup=None, Vdn=None):
        """One sign-scheduled flush over the whole fleet.

        Args:
          Vup: (capacity, n, k) zero-padded update block, or None.
          Vdn: (capacity, n, k) zero-padded downdate block, or None.

        Returns:
          (capacity,) bool feasibility verdicts when a downdate block ran
          (slots with all-zero columns report True), else None. Exactly ONE
          batched mutation is dispatched per non-None block — the counter
          ``mutations_issued`` records it.
        """
        data = self._factor.data
        ok = None
        if Vup is not None and Vdn is not None:
            _count_mutation(2, sign="both")
            data, ok = self._steps.call("both", data, Vup, Vdn)
        elif Vup is not None:
            _count_mutation(1, sign="up")
            data = self._steps.call("up", data, Vup)
        elif Vdn is not None:
            _count_mutation(1, sign="down")
            data, ok = self._steps.call("down", data, Vdn)
        else:
            return None
        self._factor = self._factor.replace(data=data)
        return ok

    def decay(self, alpha) -> None:
        """Exponential forgetting: every slot becomes the factor of
        ``alpha^2 A`` (exact, via the engine's ``scale``)."""
        # The multiplier travels in the fleet's row dtype (f64 fleets must
        # not squeeze alpha through f32); warmup builds the 'scale'
        # executable against the same aval.
        scaled = self._steps.call("scale", self._factor.data,
                                  self.row_dtype.type(alpha))
        self._factor = self._factor.replace(data=scaled)

    def bucket_for(self, k: int) -> int:
        """Smallest width bucket that carries ``k`` rows."""
        for w in self.widths:
            if w >= k:
                return w
        raise ValueError(
            f"{k} rows exceed the largest width bucket {self.widths[-1]}")

    def pad_block(self, rows_by_slot: Dict[int, np.ndarray]) -> np.ndarray:
        """Stack per-slot row lists into the static zero-padded
        (capacity, n, bucket) block ``apply`` expects, where ``bucket``
        is the smallest width bucket carrying the largest per-slot row
        count (zero columns are exact no-ops for both signs, so the
        executable shape depends only on the bucket, never on traffic)."""
        k_max = max((rows.shape[0] for rows in rows_by_slot.values()),
                    default=1)
        if k_max > self.width:
            raise ValueError(
                f"{k_max} rows exceed coalesce width {self.width}")
        bucket = self.bucket_for(max(k_max, 1))
        out = np.zeros((self.capacity, self.n, bucket), self.row_dtype)
        for s, rows in rows_by_slot.items():
            k = rows.shape[0]
            if k:
                out[s, :, :k] = rows.T
        return out

    def __repr__(self):
        return (f"FactorStore(n={self.n}, capacity={self.capacity}, "
                f"active={self.active}, width={self.width}, "
                f"ladder={self.ladder}, factor={self._factor!r})")
