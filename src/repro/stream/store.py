"""``FactorStore``: a managed fleet of per-user Cholesky factors.

One batched ``CholFactor`` of shape ``(capacity, n, n)`` holds every
admitted user's statistics; slots are assigned on ``admit`` (growing the
batch axis by doubling when full), returned on ``evict``, reclaimed by
``evict_idle``, and the live set can be ``compact``ed back down. Every
mutation of the fleet runs through ONE donated-buffer jitted step, so the
serving loop never copies the O(B·n^2) fleet: the update block is absorbed
first as a single fused batched rank-k update, then the downdate block via
the feasibility guard (``downdate_guarded``) — the sign schedule the
coalescer's equivalence proof covers. Exponential forgetting is
``decay(alpha)`` (the engine's exact ``scale``), also donated.

Instrumentation: ``mutations_issued()`` counts batched rank-k mutations
dispatched to the engine — ONE per sign block per ``apply`` call,
regardless of fleet size, the streaming analogue of
``repro.kernels.sharded.launches_traced`` (there: pallas_call
constructions per shard; here: batched engine mutations per flush — on the
fused backend each one is a single device launch for the whole fleet,
because vmap folds the batch into the kernel grid). Tests assert the
launch-count story against this counter.

Sharded placement (DESIGN.md §10): constructed with ``backend='sharded'``
and a ``mesh=``/``axis=`` binding, the fleet's members are each
column-sharded over the mesh — per-user factors too big for one device —
and the same donated steps dispatch per-shard through the fleet-native
distributed driver: one kernel launch per shard per sign block,
independent of the fleet size (``kernels.sharded.launches_traced`` is the
counter for that half of the story). admit/grow/evict/compact/decay all
preserve the placement.
"""
from __future__ import annotations

import contextlib
import functools
import warnings
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CholFactor
from repro.core.precision import Precision


@contextlib.contextmanager
def _quiet_donation():
    """Suppress the unusable-donation warning around OUR jitted steps only.

    Donation is best-effort: XLA:CPU cannot donate and warns per compile.
    It is still correct (and load-bearing) on TPU/GPU, where the fleet
    would otherwise be copied once per flush. Scoped here so user code
    keeps seeing the warning for its own jits.
    """
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        yield

# Host-side instrumentation: batched rank-k mutations dispatched to the
# engine (one per sign block per apply). See module docstring.
_MUTATIONS_ISSUED = 0


def mutations_issued() -> int:
    """Cumulative batched mutations dispatched by every store (see above)."""
    return _MUTATIONS_ISSUED


def _count_mutation(k: int = 1) -> None:
    global _MUTATIONS_ISSUED
    _MUTATIONS_ISSUED += k


def row_dtype_for(factor_dtype) -> np.dtype:
    """Exact host buffer dtype for rank-1 rows of a fleet of this dtype."""
    if np.dtype(jnp.dtype(factor_dtype)) == np.dtype(np.float64):
        return np.dtype(np.float64)
    return np.dtype(np.float32)


def _axis_key(axis):
    """Hashable canonical form of a mesh-axis binding (str/tuple/list) —
    the SAME normalization the sharded driver applies."""
    from repro.core.distributed import axis_tuple

    return axis_tuple(axis)


def fleet_sharding(mesh, axis):
    """The fleet placement: batch replicated, columns sharded over ``axis``."""
    from jax.sharding import NamedSharding, PartitionSpec

    from repro.core.distributed import axis_tuple

    return NamedSharding(mesh, PartitionSpec(None, None, axis_tuple(axis)))


@functools.lru_cache(maxsize=64)
def _steps_for(panel: int, backend: str, interpret: Optional[bool],
               precision: Optional[Precision], mesh=None, axis="model"):
    """Donated jitted mutation steps, shared across stores with equal meta.

    jit caches key on (closure identity, shapes); caching the closures here
    means two stores with the same execution metadata — or one store timed
    after a warmup store in the benchmark — share compiled executables.
    ``mesh``/``axis`` ride for sharded placements (jax Meshes hash by axis
    names + device ids, so equal meshes share one entry): the steps then
    dispatch per-shard through the fleet-native distributed driver, and
    donation keeps the sharded fleet in place.
    """
    meta = dict(panel=panel, backend=backend, interpret=interpret,
                precision=precision, mesh=mesh, axis=axis)

    def up_only(data, vup):
        return CholFactor.from_factor(data, **meta).update(vup).data

    def down_only(data, vdn):
        f, ok = CholFactor.from_factor(data, **meta).downdate_guarded(vdn)
        return f.data, ok

    def both(data, vup, vdn):
        f = CholFactor.from_factor(data, **meta).update(vup)
        f, ok = f.downdate_guarded(vdn)
        return f.data, ok

    def scale(data, alpha):
        return CholFactor.from_factor(data, **meta).scale(alpha).data

    def slot_set(data, slot, block):
        return data.at[slot].set(block.astype(data.dtype))

    donate = dict(donate_argnums=0)
    return {
        "up": jax.jit(up_only, **donate),
        "down": jax.jit(down_only, **donate),
        "both": jax.jit(both, **donate),
        "scale": jax.jit(scale, **donate),
        "slot_set": jax.jit(slot_set, **donate),
    }


class FactorStore:
    """Fleet manager over one batched ``CholFactor`` (see module docstring).

    Args:
      n: per-user factor dimension.
      capacity: initial slot count (grows by doubling on demand).
      width: coalesce width k — the static rank of every flush mutation
        (blocks are zero-padded to it, so jit never re-traces on traffic).
      panel / backend / interpret / precision: execution metadata threaded
        onto the fleet's ``CholFactor`` (DESIGN.md §7/§8).
      mesh / axis: sharded placement (DESIGN.md §10) — with
        ``backend='sharded'`` every fleet member is column-sharded
        ``P(None, None, axis)`` over the mesh, the donated jitted steps
        dispatch per-shard through the fleet-native distributed driver
        (one kernel launch per shard per sign block, independent of the
        fleet size), and every membership operation (admit / grow / evict
        / compact / decay) preserves the placement.
      init_scale: admitted slots start as the factor of ``init_scale * I``
        (the ridge/eps warm start).
      dtype: logical dtype of the fleet (storage dtype under a precision
        policy).
    """

    def __init__(self, n: int, *, capacity: int = 8, width: int = 16,
                 panel: int = 64, backend: str = "auto",
                 interpret: Optional[bool] = None, precision=None,
                 mesh=None, axis="model",
                 init_scale: float = 1.0, dtype=jnp.float32):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if backend == "sharded" and mesh is None:
            raise ValueError("backend='sharded' requires a mesh= placement")
        if backend != "sharded" and mesh is not None:
            # The inverse misconfiguration must fail just as loudly:
            # silently dropping the mesh would leave a fleet sized for
            # multi-device placement fully replicated on one device.
            raise ValueError(
                f"mesh= placement requires backend='sharded' "
                f"(got backend={backend!r})")
        policy = Precision.parse(precision)
        storage = jnp.dtype(dtype) if policy is None else jnp.dtype(
            policy.storage_for(dtype))
        self.n = n
        self.width = width
        self.init_scale = float(init_scale)
        self._mesh = mesh if backend == "sharded" else None
        self._axis = axis
        self._eye = jnp.eye(n, dtype=storage)
        data = jnp.float32(np.sqrt(self.init_scale)) * jnp.broadcast_to(
            self._eye, (capacity, n, n))
        self._factor = CholFactor.from_factor(
            self._place(jnp.asarray(data, storage)), panel=panel,
            backend=backend, interpret=interpret, precision=policy,
            mesh=self._mesh, axis=axis)
        self._slot_of: Dict[object, int] = {}
        self._user_of: Dict[int, object] = {}
        self._free: List[int] = list(range(capacity - 1, -1, -1))
        self._last_used: Dict[object, int] = {}
        self._steps = _steps_for(panel, backend, interpret, policy,
                                 self._mesh, _axis_key(axis))

    # -- sharded placement ---------------------------------------------------
    def _place(self, data):
        """Pin fleet data to the sharded placement (no-op unsharded)."""
        if self._mesh is None:
            return data
        return jax.device_put(data, fleet_sharding(self._mesh, self._axis))

    # -- reconstruction (durability) ----------------------------------------
    @classmethod
    def from_state(cls, factor: CholFactor, *, width: int,
                   slots: Dict[object, int], last_used: Dict[object, int],
                   init_scale: float) -> "FactorStore":
        """Rebuild a store around restored fleet data + slot table.

        A sharded fleet rides in on the factor's own mesh/axis aux (the
        durability layer rebuilds the mesh from checkpoint meta before
        calling this), so the restored store re-pins the placement.
        """
        if not factor.batched:
            raise ValueError("fleet factor must be batched (B, n, n)")
        self = cls.__new__(cls)
        self.n = factor.n
        self.width = width
        self.init_scale = float(init_scale)
        self._mesh = factor.mesh if factor.backend == "sharded" else None
        self._axis = factor.axis
        self._eye = jnp.eye(factor.n, dtype=factor.dtype)
        self._factor = factor.replace(data=self._place(factor.data))
        self._slot_of = dict(slots)
        self._user_of = {s: u for u, s in self._slot_of.items()}
        taken = set(self._slot_of.values())
        cap = factor.data.shape[0]
        self._free = [s for s in range(cap - 1, -1, -1) if s not in taken]
        self._last_used = dict(last_used)
        self._steps = _steps_for(factor.panel, factor.backend,
                                 factor.interpret, factor.precision,
                                 self._mesh, _axis_key(factor.axis))
        return self

    # -- views --------------------------------------------------------------
    @property
    def factor(self) -> CholFactor:
        """The live batched fleet factor (read: solve/logdet/diagnostics)."""
        return self._factor

    @property
    def capacity(self) -> int:
        return self._factor.data.shape[0]

    @property
    def row_dtype(self) -> np.dtype:
        """Host dtype buffered rows are kept in: wide enough to be exact
        for this fleet. f64 fleets buffer f64 (anything narrower would
        silently truncate observations); everything else — f32 and
        narrow-storage policies like bf16 — buffers f32, which the engine
        casts to ``L.dtype`` at dispatch without information loss."""
        return row_dtype_for(self._factor.dtype)

    @property
    def active(self) -> int:
        return len(self._slot_of)

    def users(self):
        return tuple(self._slot_of)

    def slot(self, user) -> int:
        return self._slot_of[user]

    def has(self, user) -> bool:
        return user in self._slot_of

    def last_used(self, user) -> int:
        return self._last_used[user]

    def factor_for(self, user) -> CholFactor:
        """A single-user view (shares the fleet's execution metadata)."""
        return self._factor.replace(data=self._factor.data[self.slot(user)])

    # -- fleet membership ---------------------------------------------------
    def admit(self, user, *, scale: Optional[float] = None,
              tick: int = 0) -> int:
        """Assign ``user`` a slot warm-started at ``scale * I`` (grows the
        fleet when full). Idempotent for already-admitted users."""
        if user in self._slot_of:
            self._last_used[user] = tick
            return self._slot_of[user]
        if not self._free:
            self._grow()
        s = self._free.pop()
        block = jnp.float32(np.sqrt(
            self.init_scale if scale is None else float(scale))) * self._eye
        with _quiet_donation():
            new_data = self._steps["slot_set"](
                self._factor.data, jnp.int32(s), block)
        self._factor = self._factor.replace(data=new_data)
        self._slot_of[user] = s
        self._user_of[s] = user
        self._last_used[user] = tick
        return s

    def evict(self, user) -> int:
        """Free a user's slot (data is reset on the next admit).

        This is the slot-table primitive. A store managed by a
        ``StreamService`` must be evicted through ``service.evict`` /
        ``service.evict_idle`` instead — the service also owns the user's
        coalescer, window schedule and WAL record, which this call cannot
        see.
        """
        s = self._slot_of.pop(user)
        del self._user_of[s]
        del self._last_used[user]
        self._free.append(s)
        return s

    def _grow(self) -> None:
        """Double the batch axis (the one amortised O(B n^2) copy);
        re-pins the sharded placement on the grown fleet."""
        cap = self.capacity
        fresh = jnp.float32(np.sqrt(self.init_scale)) * jnp.broadcast_to(
            self._eye, (cap, self.n, self.n))
        new_data = jnp.concatenate(
            [self._factor.data, jnp.asarray(fresh, self._factor.dtype)])
        self._factor = self._factor.replace(data=self._place(new_data))
        self._free.extend(range(2 * cap - 1, cap - 1, -1))

    def compact(self, *, min_capacity: int = 1) -> Dict[object, int]:
        """Shrink the fleet to its active slots (one gather + remap).

        Returns the new user -> slot mapping. The copy is explicit and
        caller-scheduled — compaction is a maintenance event, not a serving-
        loop step.
        """
        order = sorted(self._slot_of.items(), key=lambda kv: kv[1])
        keep = [s for _, s in order]
        new_cap = max(len(keep), min_capacity)
        idx = keep + [0] * (new_cap - len(keep))  # pad slots: reset on admit
        data = self._factor.data[jnp.asarray(idx, jnp.int32)]
        self._factor = self._factor.replace(data=self._place(data))
        self._slot_of = {u: i for i, (u, _) in enumerate(order)}
        self._user_of = {i: u for u, i in self._slot_of.items()}
        self._free = list(range(new_cap - 1, len(keep) - 1, -1))
        return dict(self._slot_of)

    # -- mutations ----------------------------------------------------------
    def apply(self, Vup=None, Vdn=None):
        """One sign-scheduled flush over the whole fleet.

        Args:
          Vup: (capacity, n, k) zero-padded update block, or None.
          Vdn: (capacity, n, k) zero-padded downdate block, or None.

        Returns:
          (capacity,) bool feasibility verdicts when a downdate block ran
          (slots with all-zero columns report True), else None. Exactly ONE
          batched mutation is dispatched per non-None block — the counter
          ``mutations_issued`` records it.
        """
        data = self._factor.data
        ok = None
        with _quiet_donation():
            if Vup is not None and Vdn is not None:
                _count_mutation(2)
                data, ok = self._steps["both"](
                    data, jnp.asarray(Vup), jnp.asarray(Vdn))
            elif Vup is not None:
                _count_mutation(1)
                data = self._steps["up"](data, jnp.asarray(Vup))
            elif Vdn is not None:
                _count_mutation(1)
                data, ok = self._steps["down"](data, jnp.asarray(Vdn))
            else:
                return None
        self._factor = self._factor.replace(data=data)
        return ok

    def decay(self, alpha) -> None:
        """Exponential forgetting: every slot becomes the factor of
        ``alpha^2 A`` (exact, via the engine's ``scale``)."""
        with _quiet_donation():
            scaled = self._steps["scale"](self._factor.data,
                                          jnp.float32(alpha))
        self._factor = self._factor.replace(data=scaled)

    def pad_block(self, rows_by_slot: Dict[int, np.ndarray]) -> np.ndarray:
        """Stack per-slot row lists into the static (capacity, n, width)
        zero-padded block ``apply`` expects (zero columns are exact no-ops
        for both signs, so the jitted step never re-traces on traffic)."""
        out = np.zeros((self.capacity, self.n, self.width), self.row_dtype)
        for s, rows in rows_by_slot.items():
            k = rows.shape[0]
            if k > self.width:
                raise ValueError(
                    f"slot {s}: {k} rows exceed coalesce width {self.width}")
            if k:
                out[s, :, :k] = rows.T
        return out

    def __repr__(self):
        return (f"FactorStore(n={self.n}, capacity={self.capacity}, "
                f"active={self.active}, width={self.width}, "
                f"factor={self._factor!r})")
