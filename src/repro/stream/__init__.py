"""``repro.stream``: streaming update service over a managed factor fleet.

The layer between the ``CholFactor`` engine and a serving system
(DESIGN.md §9/§11): ``Coalescer`` buffers per-user rank-1 traffic in
ring buffers and drains it as sign-scheduled rank-k blocks (paper sweet
spot k=16); ``FactorStore`` manages the batched fleet those blocks
mutate through donated AOT-compiled steps over a fixed capacity
**bucket ladder** with an explicit slot map; ``warmup`` pre-compiles
every ladder rung's executables so steady-state serving never traces
(``assert_no_retrace`` is the enforcement hook); ``StreamService`` ties
them together with window forgetting, deadline flushes, decay and an
optional background flush worker; ``durability`` makes the whole thing
survive a kill via checkpoint + replay-log restore (ladder config and
slot map ride in the checkpoint meta, so a restart restores warm).
"""
from repro.stream.coalescer import Coalescer, DrainResult, RingBuffer
from repro.stream.durability import (
    ReplayLog,
    checkpoint_service,
    decode_row,
    encode_row,
    restore_service,
)
from repro.stream.service import FlushReport, StreamService
from repro.stream.store import (
    DEFAULT_LADDER,
    FactorStore,
    LadderFullError,
    ladder_from,
    mutations_issued,
    traces_counted,
)
from repro.stream.warmup import (
    RetraceError,
    WarmupReport,
    assert_no_retrace,
    warmup_service,
    warmup_store,
    watch_traces,
)

__all__ = [
    "Coalescer",
    "DrainResult",
    "RingBuffer",
    "DEFAULT_LADDER",
    "FactorStore",
    "LadderFullError",
    "ladder_from",
    "FlushReport",
    "StreamService",
    "ReplayLog",
    "checkpoint_service",
    "restore_service",
    "encode_row",
    "decode_row",
    "mutations_issued",
    "traces_counted",
    "RetraceError",
    "WarmupReport",
    "assert_no_retrace",
    "warmup_service",
    "warmup_store",
    "watch_traces",
]
