"""``repro.stream``: streaming update service over a managed factor fleet.

The layer between the ``CholFactor`` engine and a serving system
(DESIGN.md §9): ``Coalescer`` buffers per-user rank-1 traffic in ring
buffers and drains it as sign-scheduled rank-k blocks (paper sweet spot
k=16); ``FactorStore`` manages the batched fleet those blocks mutate
through one donated-buffer jitted step; ``StreamService`` ties them
together with window forgetting, deadline flushes and decay;
``durability`` makes the whole thing survive a kill via checkpoint +
replay-log restore.
"""
from repro.stream.coalescer import Coalescer, DrainResult, RingBuffer
from repro.stream.durability import (
    ReplayLog,
    checkpoint_service,
    decode_row,
    encode_row,
    restore_service,
)
from repro.stream.service import FlushReport, StreamService
from repro.stream.store import FactorStore, mutations_issued

__all__ = [
    "Coalescer",
    "DrainResult",
    "RingBuffer",
    "FactorStore",
    "FlushReport",
    "StreamService",
    "ReplayLog",
    "checkpoint_service",
    "restore_service",
    "encode_row",
    "decode_row",
    "mutations_issued",
]
