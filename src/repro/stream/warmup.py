"""AOT warmup + retrace guard: trace-free serving over the bucket ladder.

The source paper's premise is that rank-k modification is bandwidth-bound
and launch-dominated — every microsecond of host overhead on the serving
path is a real fraction of the work. Tracing + XLA compilation inside a
flush is *milliseconds to seconds*, and the grow-by-doubling fleet used
to guarantee those stalls kept arriving as traffic ramped. The fix is
the MaxText offline-inference pattern: because the ``FactorStore``'s
capacity ladder and width buckets are FIXED and enumerable, every
executable the serving path can ever dispatch is compilable ahead of
time.

``warmup_store(store)`` walks ``store.ladder`` × ``store.widths`` and
``jax.jit(step, donate_argnums=0).lower(avals).compile()``s the donated
up / down / both / scale / slot_set executables for each rung, plus the
``promote`` executable for each rung boundary — from abstract
``ShapeDtypeStruct``s, so warmup allocates **no** fleet-sized device
memory. Sharded placements lower against sharded avals
(``ShapeDtypeStruct(..., sharding=...)``), so the executables are
placement-exact (single, batched, and sharded fleets all warm the same
way). The executables land in the metadata-shared ``StepSet`` cache that
``FactorStore`` dispatch prefers, so after warmup the serving loop —
admit, flush, evict, readmit, decay, rung promotion — never reaches the
tracing tier.

The **retrace guard** is the contract's teeth: every step function body
bumps ``repro.stream.store.traces_counted()`` exactly once per Python
trace (tracing executes the body; cached executions do not — the
compile-counter hook). ``assert_no_retrace()`` brackets a serving
sequence and raises ``RetraceError`` if the counter moved, making any
post-warmup trace a hard test failure rather than a silent latency
spike. ``tests/test_stream_warmup.py`` drives an
admit/push/flush/evict/readmit/checkpoint/restore/flush sequence across
two ladder rungs under the guard.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Dict, Optional, Tuple

import jax
import numpy as np

from repro.obs import tracing as obs_tracing
from repro.stream import store as store_mod
from repro.stream.store import FactorStore, fleet_sharding


class RetraceError(AssertionError):
    """A step function re-traced inside an ``assert_no_retrace`` block."""


@dataclasses.dataclass
class TraceWatch:
    """Live view of the trace counter inside a guard block."""

    start: int

    @property
    def traces(self) -> int:
        return store_mod.traces_counted() - self.start


@contextlib.contextmanager
def watch_traces():
    """Count step traces across a block (no failure — diagnostics)."""
    yield TraceWatch(start=store_mod.traces_counted())


@contextlib.contextmanager
def assert_no_retrace(what: str = "serving sequence"):
    """Hard retrace guard: raise ``RetraceError`` if any step function
    traces inside the block. Wrap post-warmup serving sequences with this
    in tests — a trace on the warm path is a bug, not a slow request."""
    watch = TraceWatch(start=store_mod.traces_counted())
    yield watch
    if watch.traces:
        raise RetraceError(
            f"{watch.traces} step trace(s) inside {what!r} — the warm "
            "serving path must dispatch pre-compiled executables only "
            "(did warmup() cover this rung/width/dtype signature?)")


@dataclasses.dataclass
class WarmupReport:
    """What one ``warmup_store`` call compiled.

    Attributes:
      compiled: executables built by THIS call.
      cached: signatures that were already in the shared executable cache
        (a restored store in a live process re-warms for free).
      rungs: ladder rungs covered.
      widths: width buckets covered.
      seconds: wall-clock spent lowering + compiling.
      compile_seconds: per-executable-kind wall-clock breakdown, keyed by
        step name with a ``[sharded]`` suffix for sharded-aval builds
        (e.g. ``'both'``, ``'promote[sharded]'``). Only builds THIS call
        performed appear — cache hits cost (and record) nothing. The same
        timings land in the registry histogram
        ``repro.stream.compile_seconds{step=...,sharded=0|1}``, recorded
        by ``StepSet.compile_step`` itself so cold serving-path compiles
        are measured identically.
      lowering: the fused-kernel lowering the compiled executables baked in
        ('mosaic'/'portable') — resolved per device kind at warmup time
        (DESIGN.md §5), so a GPU-kind warmup compiles the portable spec.
    """

    compiled: int = 0
    cached: int = 0
    rungs: Tuple[int, ...] = ()
    widths: Tuple[int, ...] = ()
    seconds: float = 0.0
    compile_seconds: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    lowering: str = "mosaic"


def _aval(shape, dtype, sharding=None):
    if sharding is not None:
        return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)
    return jax.ShapeDtypeStruct(shape, dtype)


def warmup_store(store: FactorStore, *,
                 rungs: Optional[Tuple[int, ...]] = None,
                 widths: Optional[Tuple[int, ...]] = None) -> WarmupReport:
    """AOT-compile the store's full executable ladder.

    Args:
      store: the fleet to warm. Executables key on the store's execution
        metadata and land in the metadata-shared ``StepSet``, so every
        store (and every restored store) with equal metadata shares them.
      rungs: ladder subset to warm (default: the whole ladder — compact
        can move DOWN a rung, so lower rungs stay reachable).
      widths: width-bucket subset (default: the store's buckets).

    Returns a ``WarmupReport``. Warmup is the one phase allowed to trace;
    bracket everything after it with ``assert_no_retrace``.
    """
    rungs = store.ladder if rungs is None else tuple(rungs)
    widths = store.widths if widths is None else tuple(widths)
    for r in rungs:
        if r not in store.ladder:
            raise ValueError(f"rung {r} is not on the ladder {store.ladder}")
    n = store.n
    row_dt = store.row_dtype
    sharding = (fleet_sharding(store._mesh, store._axis)
                if store._mesh is not None else None)
    steps = store.steps
    # The step traces resolve the fused lowering per device kind at trace
    # time, so the executables compiled here bake it in; record which one.
    from repro.core import backends

    report = WarmupReport(rungs=tuple(rungs), widths=tuple(widths),
                          lowering=backends.resolve_lowering(
                              getattr(store.factor, "lowering", None)))
    t0 = time.perf_counter()

    def build(name, avals):
        # Label per executable kind, sharded-aval builds separately: the
        # sharded lowerings are the expensive ones (SPMD partitioning),
        # and the aggregate ``seconds`` used to be the only place their
        # cost survived.
        key = name + ("[sharded]" if any(
            getattr(a, "sharding", None) is not None
            for a in jax.tree_util.tree_leaves(avals))
            else "")
        t = time.perf_counter()
        if steps.compile_step(name, avals):
            report.compiled += 1
            report.compile_seconds[key] = (
                report.compile_seconds.get(key, 0.0)
                + time.perf_counter() - t)
        else:
            report.cached += 1

    with obs_tracing.span("stream.warmup", rungs=len(rungs),
                          widths=len(widths)) as ev:
        for cap in rungs:
            # The fleet aval comes from the store — a dense (cap, n, n)
            # array or a structured pytree of block-stack avals — so one
            # warmup loop covers every storage layout the store supports.
            data = store.fleet_aval(cap, sharding=sharding)
            for w in widths:
                vw = _aval((cap, n, w), row_dt)
                build("up", (data, vw))
                build("down", (data, vw))
                for w2 in widths:
                    build("both", (data, vw, _aval((cap, n, w2), row_dt)))
            # decay's alpha travels in the fleet's row dtype (store.decay).
            build("scale", (data, _aval((), row_dt)))
            build("slot_set", (data, _aval((), np.int32),
                               store.member_aval()))
        for cap, nxt in zip(store.ladder, store.ladder[1:]):
            if cap in rungs or nxt in rungs:
                build("promote", (store.fleet_aval(cap, sharding=sharding),
                                  store.fleet_aval(nxt - cap)))
        ev.labels.update(compiled=report.compiled, cached=report.cached)

    report.seconds = time.perf_counter() - t0
    return report


def warmup_service(svc) -> WarmupReport:
    """Warm a ``StreamService``'s store (the service adds no executables
    of its own — flush, tick and the background worker all dispatch
    through the store's step set)."""
    return warmup_store(svc.store)
