"""Decoder-only LM assembly: one scanned block system covering the dense /
moe / rwkv / mamba-hybrid / vlm families.

Layers are homogeneous within a family, so parameters are stacked with a
leading ``layers`` axis and the stack is driven by ``lax.scan`` (compact HLO
for 80-layer configs, mandatory for dry-run compile times). Per-layer
heterogeneity is data, not structure:

* gemma2's local/global alternation scans a per-layer ``window`` scalar into
  a shared body (traced window, see attention.flash_attention);
* zamba2's shared attention block is closure-captured (one parameter set) and
  applied every ``shared_attn_every`` layers behind a ``lax.cond``.

``decode_step`` mirrors the same scan with per-layer cache slices as scan
xs/ys; SWA caches are ring buffers (O(window) memory for 500k streams).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S


# ---------------------------------------------------------------------------
# Per-layer init/apply by family.
# ---------------------------------------------------------------------------


def _layer_init(key, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    fam = cfg.family
    if fam in ("dense", "vlm"):
        p = {
            "ln1": L.norm_init(cfg.norm, cfg.d_model, dtype),
            "attn": A.attention_init(ks[0], cfg.attn, cfg.d_model, dtype),
            "ln2": L.norm_init(cfg.norm, cfg.d_model, dtype),
            "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype, activation=cfg.activation),
        }
        if cfg.post_norm:
            p["ln1_post"] = L.norm_init(cfg.norm, cfg.d_model, dtype)
            p["ln2_post"] = L.norm_init(cfg.norm, cfg.d_model, dtype)
        return p
    if fam == "moe":
        return {
            "ln1": L.norm_init(cfg.norm, cfg.d_model, dtype),
            "attn": A.attention_init(ks[0], cfg.attn, cfg.d_model, dtype),
            "ln2": L.norm_init(cfg.norm, cfg.d_model, dtype),
            "moe": M.moe_init(ks[1], cfg.d_model, cfg.moe, cfg.d_ff, dtype),
        }
    if fam == "rwkv":
        return {
            "ln1": L.norm_init(cfg.norm, cfg.d_model, dtype),
            "tmix": S.rwkv_init(ks[0], cfg.d_model, cfg.rwkv, cfg.d_ff, dtype),
            "ln2": L.norm_init(cfg.norm, cfg.d_model, dtype),
            "cmix": S.rwkv_channel_mix_init(ks[1], cfg.d_model, cfg.d_ff, dtype),
        }
    if fam == "mamba_hybrid":
        return {
            "ln1": L.norm_init(cfg.norm, cfg.d_model, dtype),
            "mamba": S.mamba_init(ks[0], cfg.d_model, cfg.ssm, dtype),
        }
    raise ValueError(fam)


def _shared_block_init(key, cfg):
    """zamba2's shared attention+MLP block (single parameter set)."""
    dtype = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.norm_init(cfg.norm, cfg.d_model, dtype),
        "attn": A.attention_init(k1, cfg.attn, cfg.d_model, dtype),
        "ln2": L.norm_init(cfg.norm, cfg.d_model, dtype),
        "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, dtype, activation=cfg.activation),
    }


def _stack(trees):
    is_p = lambda x: isinstance(x, L.Param)
    return jax.tree.map(
        lambda *ps: L.Param(
            jnp.stack([p.value for p in ps]), ("layers",) + ps[0].axes
        ),
        *trees,
        is_leaf=is_p,
    )


def init_lm(key, cfg):
    """Full parameter tree (Param leaves, logical axes attached)."""
    dtype = jnp.dtype(cfg.param_dtype)
    k_embed, k_layers, k_head, k_shared = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    params = {
        "embed": L.embed_init(k_embed, cfg.vocab_padded, cfg.d_model, dtype),
        "layers": _stack([_layer_init(k, cfg) for k in layer_keys]),
        "final_norm": L.norm_init(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w": L.param(k_head, (cfg.d_model, cfg.vocab_padded),
                         ("embed", "vocab"), dtype=dtype)
        }
    if cfg.shared_attn_every:
        params["shared"] = _shared_block_init(k_shared, cfg)
    return params


# ---------------------------------------------------------------------------
# Per-layer static schedules (data, not structure).
# ---------------------------------------------------------------------------


def layer_windows(cfg):
    """(L,) int32 per-layer SWA window; 0 disables."""
    w = jnp.zeros((cfg.num_layers,), jnp.int32)
    if cfg.attn and cfg.attn.window:
        if cfg.attn.local_global_period:
            pat = jnp.arange(cfg.num_layers) % cfg.attn.local_global_period == 0
            w = jnp.where(pat, cfg.attn.window, 0)
        else:
            w = jnp.full((cfg.num_layers,), cfg.attn.window, jnp.int32)
    return w


def shared_flags(cfg):
    if not cfg.shared_attn_every:
        return jnp.zeros((cfg.num_layers,), bool)
    return jnp.arange(cfg.num_layers) % cfg.shared_attn_every == 0


# ---------------------------------------------------------------------------
# Forward (train / prefill).
# ---------------------------------------------------------------------------


def _apply_shared_block(shared, x, positions, cfg):
    y = A.attn_block(
        shared["attn"], L.apply_norm(cfg.norm, shared["ln1"], x), positions,
        cfg.attn, causal=True, window=cfg.attn.window,
    )
    x = x + y
    x = x + L.mlp(shared["mlp"], L.apply_norm(cfg.norm, shared["ln2"], x),
                  activation=cfg.activation)
    return x


def _layer_fwd(lp, x, positions, cfg, window, shared_vals, shared_flag,
               collect_cache: bool):
    """One layer. Returns (x, (aux, cache_kv))."""
    from repro.sharding import rules as _rules

    fam = cfg.family
    # Pin the residual stream to the batch axes at every layer boundary so
    # XLA's propagation can never replicate activations inside the scanned
    # loop (measured: 65 GB/layer of backward all-gathers on rwkv6 without
    # this — EXPERIMENTS.md §Perf).
    if cfg.pin_batch:
        x = _rules.constrain_batch_dim(x, 0)
    aux = {"load_balance": jnp.zeros((), jnp.float32),
           "router_z": jnp.zeros((), jnp.float32)}
    cache = None
    if fam in ("dense", "vlm", "moe"):
        h = L.apply_norm(cfg.norm, lp["ln1"], x)
        q, k, v = A.qkv(lp["attn"], h, positions, cfg.attn)
        o = A.flash_attention(
            q, k, v, causal=True, window=window, cap=cfg.attn.softcap
        )
        y = jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
        if cfg.post_norm:
            y = L.apply_norm(cfg.norm, lp["ln1_post"], y)
        x = x + y
        h = L.apply_norm(cfg.norm, lp["ln2"], x)
        if fam == "moe":
            y, aux = M.moe_block(lp["moe"], h, cfg.moe, activation=cfg.activation)
        else:
            y = L.mlp(lp["mlp"], h, activation=cfg.activation)
        if cfg.post_norm:
            y = L.apply_norm(cfg.norm, lp["ln2_post"], y)
        x = x + y
        if collect_cache:
            cache = (k, v)
    elif fam == "rwkv":
        h = L.apply_norm(cfg.norm, lp["ln1"], x)
        if collect_cache:
            y, tstate = S.rwkv_time_mix(lp["tmix"], h, cfg.rwkv, return_state=True)
        else:
            y = S.rwkv_time_mix(lp["tmix"], h, cfg.rwkv)
            tstate = None
        x = x + y
        h = L.apply_norm(cfg.norm, lp["ln2"], x)
        if collect_cache:
            y, cstate = S.rwkv_channel_mix(lp["cmix"], h, return_state=True)
            cache = (tstate, cstate)
        else:
            y = S.rwkv_channel_mix(lp["cmix"], h)
        x = x + y
    elif fam == "mamba_hybrid":
        h = L.apply_norm(cfg.norm, lp["ln1"], x)
        if collect_cache:
            y, mstate = S.mamba_block(lp["mamba"], h, cfg.ssm, return_state=True)
            cache = mstate
        else:
            y = S.mamba_block(lp["mamba"], h, cfg.ssm)
        x = x + y
    else:
        raise ValueError(fam)
    return x, (aux, cache)


def hybrid_groups(cfg):
    """(n_groups, group_size, tail) for the shared-block group scan.

    The zamba2 pattern — shared attention before layers 0, every, 2*every, …
    — is expressed as a scan over groups of ``every`` mamba layers, each
    preceded by the shared block, plus an explicit tail. No lax.cond: FLOPs
    stay statically attributable (roofline/hloparse.py)."""
    every = cfg.shared_attn_every
    n_groups = cfg.num_layers // every
    tail = cfg.num_layers - n_groups * every
    return n_groups, every, tail


def _group_layers(values_layers, cfg):
    n_groups, every, tail = hybrid_groups(cfg)
    main = jax.tree.map(
        lambda a: a[: n_groups * every].reshape(n_groups, every, *a.shape[1:]),
        values_layers,
    )
    tailp = jax.tree.map(lambda a: a[n_groups * every :], values_layers)
    return main, tailp


def _forward_hybrid(values, cfg, x, positions, collect_cache):
    """zamba2: (shared block + ``every`` mamba layers) x n_groups + tail."""
    shared_vals = values["shared"]
    main, tailp = _group_layers(values["layers"], cfg)
    n_groups, every, tail = hybrid_groups(cfg)

    def inner(x, lp):
        return _layer_fwd(lp, x, positions, cfg, None, None, None,
                          collect_cache)

    if cfg.remat:
        inner = jax.checkpoint(inner)

    def group(x, gp):
        x = _apply_shared_block(shared_vals, x, positions, cfg)
        return jax.lax.scan(inner, x, gp,
                            unroll=1 if cfg.scan_layers else every)

    x, (aux, caches_main) = jax.lax.scan(
        group, x, main, unroll=1 if cfg.scan_layers else n_groups
    )
    caches_tail = None
    if tail:
        x = _apply_shared_block(shared_vals, x, positions, cfg)
        x, (aux_t, caches_tail) = jax.lax.scan(inner, x, tailp)
        aux = jax.tree.map(lambda a, b: jnp.concatenate([a.reshape(-1), b]),
                           aux, aux_t)
    return x, aux, (caches_main, caches_tail)


def forward_lm(values, cfg, tokens, *, embeds=None, collect_cache=False,
               return_hidden=False):
    """values: plain-array tree (Param.value). tokens: (B, S) int32.

    ``embeds``: optional (B, P, D) precomputed frontend embeddings (vision /
    audio stub) that replace the first P token positions.
    Returns (logits fp32 (B, S, vocab_padded), aux dict[, cache]); with
    ``return_hidden`` the first element is the final hidden state instead
    (callers chunk the vocab projection themselves — see ``lm_loss``).
    """
    B, S = tokens.shape
    x = L.embed_lookup(values["embed"], tokens)
    if cfg.family == "vlm" and embeds is not None:
        P = embeds.shape[1]
        x = jnp.concatenate([embeds.astype(x.dtype), x[:, P:]], axis=1)
    if cfg.embed_scale:
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    if cfg.family == "mamba_hybrid" and cfg.shared_attn_every:
        x, aux, caches = _forward_hybrid(values, cfg, x, positions,
                                         collect_cache)
    else:
        windows = layer_windows(cfg)

        def body(x, xs):
            lp, window = xs
            return _layer_fwd(lp, x, positions, cfg, window, None, None,
                              collect_cache)

        if cfg.remat:
            body = jax.checkpoint(body)
        x, (aux, caches) = jax.lax.scan(
            body, x, (values["layers"], windows),
            unroll=1 if cfg.scan_layers else cfg.num_layers,
        )
    aux = jax.tree.map(jnp.sum, aux)
    x = L.apply_norm(cfg.norm, values["final_norm"], x)
    if return_hidden:
        return x, aux
    logits = project_logits(values, cfg, x)
    if collect_cache:
        return logits, aux, caches
    return logits, aux


def project_logits(values, cfg, x):
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, values["embed"]["tokens"])
    else:
        logits = x @ values["lm_head"]["w"]
    return L.softcap(logits.astype(jnp.float32), cfg.logit_softcap)


def chunked_xent(values, cfg, x, labels):
    """Next-token cross-entropy scanning sequence chunks, so the (tokens,
    vocab) logits tensor never materialises beyond one chunk (the 1.07 TB
    fp32 logits of gemma2 at train_4k become ~34 GB peak global)."""
    B, S, D = x.shape
    c = min(cfg.loss_chunk, S)
    n_chunks = S // c if S % c == 0 else 1
    if S % c != 0:
        c = S
    xc = x.reshape(B, n_chunks, c, D).swapaxes(0, 1)  # (n, B, c, D)
    lc = labels.reshape(B, n_chunks, c).swapaxes(0, 1)

    def chunk(carry, xs):
        from repro.sharding import rules as _rules

        xi, li = xs
        if cfg.pin_batch:
            # Batch-sharded logits: without the pin XLA may all-reduce the
            # *global* (tokens, vocab) chunk (2^37 bytes on rwkv6/dp).
            xi = _rules.constrain_batch_dim(xi, 0)
        logits = project_logits(values, cfg, xi)
        if cfg.pin_batch:
            logits = _rules.constrain_batch_dim(logits, 0)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, li[..., None], axis=-1)[..., 0]
        mask = li >= 0
        s = carry[0] - jnp.sum(jnp.where(mask, ll, 0.0))
        n = carry[1] + jnp.sum(mask)
        return (s, n), None

    (s, n), _ = jax.lax.scan(
        chunk, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)), (xc, lc)
    )
    return s / jnp.maximum(n, 1)


def lm_loss(values, cfg, tokens, labels, *, embeds=None):
    """Mean next-token cross-entropy (fp32, vocab-chunked) + aux losses."""
    x, aux = forward_lm(values, cfg, tokens, embeds=embeds, return_hidden=True)
    loss = chunked_xent(values, cfg, x, labels)
    total = loss + aux["load_balance"] + aux["router_z"]
    metrics = {"loss": loss, **aux}
    return total, metrics


# ---------------------------------------------------------------------------
# Decode (single token against per-layer caches).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CacheSpec:
    """Static description of the decode cache for (cfg, batch, slots)."""
    batch: int
    slots: int          # KV slots: window size for ring caches
    ring: bool


def cache_spec(cfg, batch: int, seq_len: int) -> CacheSpec:
    ring = bool(
        cfg.attn and cfg.attn.window and not cfg.attn.local_global_period
    )
    slots = min(cfg.attn.window, seq_len) if ring else seq_len
    if cfg.family in ("rwkv",):
        slots = 0
    return CacheSpec(batch=batch, slots=slots, ring=ring)


def init_cache(cfg, spec: CacheSpec, dtype=jnp.bfloat16):
    B = spec.batch
    Lc = cfg.num_layers
    fam = cfg.family
    cache = {"pos": jnp.zeros((), jnp.int32)}
    if fam in ("dense", "vlm", "moe"):
        kvs = (Lc, B, spec.slots, cfg.attn.num_kv_heads, cfg.attn.head_dim)
        cache["k"] = jnp.zeros(kvs, dtype)
        cache["v"] = jnp.zeros(kvs, dtype)
    elif fam == "rwkv":
        hd = cfg.rwkv.head_dim
        nh = cfg.d_model // hd
        cache["shift_t"] = jnp.zeros((Lc, B, cfg.d_model), dtype)
        cache["shift_c"] = jnp.zeros((Lc, B, cfg.d_model), dtype)
        cache["S"] = jnp.zeros((Lc, B, nh, hd, hd), dtype)
    elif fam == "mamba_hybrid":
        d_inner = cfg.ssm.expand * cfg.d_model
        nh = d_inner // cfg.ssm.head_dim
        conv_c = d_inner + 2 * cfg.ssm.state_dim
        cache["conv"] = jnp.zeros((Lc, B, cfg.ssm.conv_width - 1, conv_c), dtype)
        cache["h"] = jnp.zeros((Lc, B, nh, cfg.ssm.head_dim, cfg.ssm.state_dim), dtype)
        n_groups, _, tail = hybrid_groups(cfg)
        n_occ = n_groups + (1 if tail else 0)
        w = min(cfg.attn.window or spec.slots, spec.slots) if cfg.attn else spec.slots
        kvs = (n_occ, B, w, cfg.attn.num_kv_heads, cfg.attn.head_dim)
        cache["sk"] = jnp.zeros(kvs, dtype)
        cache["sv"] = jnp.zeros(kvs, dtype)
    return cache


def decode_step(values, cfg, cache, tokens):
    """One decode step. tokens: (B,) int32. Returns (logits (B, V), cache)."""
    B = tokens.shape[0]
    pos = cache["pos"]
    x = L.embed_lookup(values["embed"], tokens)  # (B, D)
    if cfg.embed_scale:
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype)
    windows = layer_windows(cfg)
    flags = shared_flags(cfg)
    fam = cfg.family
    new_cache = dict(cache)

    if fam in ("dense", "vlm", "moe"):
        ring = bool(cfg.attn.window and not cfg.attn.local_global_period
                    and cache["k"].shape[2] <= cfg.attn.window)
        slots = cache["k"].shape[2]
        write_at = jnp.mod(pos, slots) if ring else jnp.minimum(pos, slots - 1)

        def body(x, xs):
            lp, ck, cv, window = xs
            h = L.apply_norm(cfg.norm, lp["ln1"], x)
            o, k1, v1 = A.decode_attn(
                lp["attn"], h, ck, cv, pos, cfg.attn,
                window=window, ring=ring,
            )
            if cfg.post_norm:
                o = L.apply_norm(cfg.norm, lp["ln1_post"], o)
            x = x + o
            h = L.apply_norm(cfg.norm, lp["ln2"], x)
            if fam == "moe":
                y, _ = M.moe_block(lp["moe"], h[:, None], cfg.moe,
                                   activation=cfg.activation)
                y = y[:, 0]
            else:
                y = L.mlp(lp["mlp"], h, activation=cfg.activation)
            if cfg.post_norm:
                y = L.apply_norm(cfg.norm, lp["ln2_post"], y)
            x = x + y
            ck = jax.lax.dynamic_update_index_in_dim(
                ck, k1.astype(ck.dtype), write_at, axis=1
            )
            cv = jax.lax.dynamic_update_index_in_dim(
                cv, v1.astype(cv.dtype), write_at, axis=1
            )
            return x, (ck, cv)

        x, (k_new, v_new) = jax.lax.scan(
            body, x, (values["layers"], cache["k"], cache["v"], windows)
        )
        new_cache["k"], new_cache["v"] = k_new, v_new

    elif fam == "rwkv":

        def body(x, xs):
            lp, sh_t, Sst, sh_c = xs
            h = L.apply_norm(cfg.norm, lp["ln1"], x)[:, None]
            y, (sh_t2, S2) = S.rwkv_time_mix(
                lp["tmix"], h, cfg.rwkv, state=(sh_t, Sst), return_state=True
            )
            x = x + y[:, 0]
            h = L.apply_norm(cfg.norm, lp["ln2"], x)[:, None]
            y, sh_c2 = S.rwkv_channel_mix(lp["cmix"], h, state=sh_c, return_state=True)
            x = x + y[:, 0]
            return x, (sh_t2.astype(sh_t.dtype), S2.astype(Sst.dtype),
                       sh_c2.astype(sh_c.dtype))

        x, (sh_t, Sst, sh_c) = jax.lax.scan(
            body, x, (values["layers"], cache["shift_t"], cache["S"], cache["shift_c"])
        )
        new_cache["shift_t"], new_cache["S"], new_cache["shift_c"] = sh_t, Sst, sh_c

    elif fam == "mamba_hybrid":
        shared_vals = values["shared"]
        w_slots = cache["sk"].shape[2]
        write_at = jnp.mod(pos, w_slots)
        n_groups, every, tail = hybrid_groups(cfg)

        def shared_step(x, ck, cv):
            h = L.apply_norm(cfg.norm, shared_vals["ln1"], x)
            o, k1, v1 = A.decode_attn(
                shared_vals["attn"], h, ck, cv, pos, cfg.attn, ring=True
            )
            x = x + o
            x = x + L.mlp(shared_vals["mlp"],
                          L.apply_norm(cfg.norm, shared_vals["ln2"], x),
                          activation=cfg.activation)
            ck = jax.lax.dynamic_update_index_in_dim(ck, k1.astype(ck.dtype), write_at, axis=1)
            cv = jax.lax.dynamic_update_index_in_dim(cv, v1.astype(cv.dtype), write_at, axis=1)
            return x, ck, cv

        def mamba_step(x, xs):
            lp, conv_st, h_st = xs
            h = L.apply_norm(cfg.norm, lp["ln1"], x)[:, None]
            y, (conv2, h2) = S.mamba_block(
                lp["mamba"], h, cfg.ssm, state=(conv_st, h_st), return_state=True
            )
            x = x + y[:, 0]
            return x, (conv2.astype(conv_st.dtype), h2.astype(h_st.dtype))

        main_p, tail_p = _group_layers(values["layers"], cfg)
        conv_main, conv_tail = (
            cache["conv"][: n_groups * every].reshape(
                n_groups, every, *cache["conv"].shape[1:]
            ),
            cache["conv"][n_groups * every :],
        )
        h_main, h_tail = (
            cache["h"][: n_groups * every].reshape(
                n_groups, every, *cache["h"].shape[1:]
            ),
            cache["h"][n_groups * every :],
        )

        def group(x, xs):
            gp, conv_g, h_g, ck, cv = xs
            x, ck, cv = shared_step(x, ck, cv)
            x, (conv2, h2) = jax.lax.scan(mamba_step, x, (gp, conv_g, h_g))
            return x, (conv2, h2, ck, cv)

        sk_main, sk_tail = cache["sk"][:n_groups], cache["sk"][n_groups:]
        sv_main, sv_tail = cache["sv"][:n_groups], cache["sv"][n_groups:]
        x, (conv_new, h_new, sk_new, sv_new) = jax.lax.scan(
            group, x, (main_p, conv_main, h_main, sk_main, sv_main)
        )
        conv_new = conv_new.reshape(-1, *conv_new.shape[2:])
        h_new = h_new.reshape(-1, *h_new.shape[2:])
        if tail:
            x, ck_t, cv_t = shared_step(x, sk_tail[0], sv_tail[0])
            x, (conv_t, h_t) = jax.lax.scan(
                mamba_step, x, (tail_p, conv_tail, h_tail)
            )
            conv_new = jnp.concatenate([conv_new, conv_t], axis=0)
            h_new = jnp.concatenate([h_new, h_t], axis=0)
            sk_new = jnp.concatenate([sk_new, ck_t[None]], axis=0)
            sv_new = jnp.concatenate([sv_new, cv_t[None]], axis=0)
        new_cache["conv"], new_cache["h"] = conv_new, h_new
        new_cache["sk"], new_cache["sv"] = sk_new, sv_new

    else:
        raise ValueError(fam)

    x = L.apply_norm(cfg.norm, values["final_norm"], x)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bd,vd->bv", x, values["embed"]["tokens"])
    else:
        logits = x @ values["lm_head"]["w"]
    logits = L.softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    new_cache["pos"] = pos + 1
    return logits, new_cache
