from repro.models.model import (
    decode_step,
    forward,
    init_cache,
    init_model,
    loss_fn,
    param_count,
    split_params,
)

__all__ = [
    "init_model",
    "split_params",
    "loss_fn",
    "forward",
    "init_cache",
    "decode_step",
    "param_count",
]
