"""Parameter substrate + elementary layers (no flax: functional init/apply).

Every parameter is created through ``param(...)`` which records a tuple of
*logical axis names* alongside the array. ``split(tree)`` separates values
from axes; ``sharding/rules.py`` lowers axes to ``PartitionSpec``s.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class Param:
    value: jnp.ndarray
    axes: Tuple[Optional[str], ...]


def param(key, shape, axes, *, dtype, scale: Optional[float] = None, init="normal"):
    if len(axes) != len(shape):
        raise ValueError(f"axes {axes} vs shape {shape}")
    if init == "zeros":
        v = jnp.zeros(shape, dtype)
    elif init == "ones":
        v = jnp.ones(shape, dtype)
    else:
        if scale is None:
            scale = 1.0 / jnp.sqrt(shape[0] if len(shape) > 1 else shape[-1])
        v = (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
    return Param(v, tuple(axes))


def split(tree):
    """Param tree -> (values tree, axes tree)."""
    is_p = lambda x: isinstance(x, Param)
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=is_p)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=is_p)
    return values, axes


# ---------------------------------------------------------------------------
# Norms (fp32 internals regardless of activation dtype).
# ---------------------------------------------------------------------------


def rmsnorm_init(d, dtype):
    return {"scale": Param(jnp.ones((d,), dtype), ("embed",))}


def rmsnorm(p, x, *, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(x.dtype)


def layernorm_init(d, dtype):
    return {
        "scale": Param(jnp.ones((d,), dtype), ("embed",)),
        "bias": Param(jnp.zeros((d,), dtype), ("embed",)),
    }


def layernorm(p, x, *, eps=1e-6):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def norm_init(kind, d, dtype):
    return rmsnorm_init(d, dtype) if kind == "rmsnorm" else layernorm_init(d, dtype)


def apply_norm(kind, p, x):
    return rmsnorm(p, x) if kind == "rmsnorm" else layernorm(p, x)


# ---------------------------------------------------------------------------
# Linear / embedding.
# ---------------------------------------------------------------------------


def linear_init(key, d_in, d_out, axes, dtype, *, scale=None):
    return {"w": param(key, (d_in, d_out), axes, dtype=dtype, scale=scale)}


def linear(p, x):
    return x @ p["w"]


def embed_init(key, vocab, d, dtype):
    return {
        "tokens": param(key, (vocab, d), ("vocab", "embed"), dtype=dtype, scale=1.0)
    }


def embed_lookup(p, tokens):
    return jnp.take(p["tokens"], tokens, axis=0)


# ---------------------------------------------------------------------------
# Activations / gated MLP.
# ---------------------------------------------------------------------------


def _act(name, x):
    if name in ("swiglu", "silu"):
        return jax.nn.silu(x)
    if name in ("geglu", "gelu"):
        return jax.nn.gelu(x)
    raise ValueError(name)


def mlp_init(key, d, d_ff, dtype, *, activation="swiglu", gated=True):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "wi": param(k1, (d, d_ff), ("embed", "mlp"), dtype=dtype),
        "wo": param(k3, (d_ff, d), ("mlp", "embed"), dtype=dtype),
    }
    if gated:
        p["wg"] = param(k2, (d, d_ff), ("embed", "mlp"), dtype=dtype)
    return p


def mlp(p, x, *, activation="swiglu"):
    if "wg" in p:
        h = _act(activation, x @ p["wg"]) * (x @ p["wi"])
    else:
        h = _act(activation, x @ p["wi"])
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# Rotary position embedding.
# ---------------------------------------------------------------------------


def rope(x, positions, *, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = jnp.exp(
        -jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap
