"""Attention-free sequence mixers: RWKV6 (Finch) and Mamba2 (SSD).

Both are implemented as exact linear recurrences scanned over time (compact
HLO for the dry-run; a chunked matmul formulation is a recorded §Perf
candidate). Decode is a single recurrence step against an O(1) state — this
is what makes the ``long_500k`` cell runnable for the ssm/hybrid archs.

RWKV6 per-head state: S in R^{hd x hd} with data-dependent per-channel decay
    S_t = diag(w_t) S_{t-1} + k_t v_t^T,
    y_t = r_t (S_{t-1} + diag(u) k_t v_t^T)           (Finch, arXiv:2404.05892)

Mamba2 per-head state: h in R^{hd x N} with scalar-per-head decay
    h_t = a_t h_{t-1} + dt_t * x_t B_t^T,   a_t = exp(-exp(A_log) dt_t)
    y_t = h_t C_t + D x_t                              (SSD, arXiv:2405.21060)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


# ---------------------------------------------------------------------------
# RWKV6
# ---------------------------------------------------------------------------


def rwkv_init(key, d_model, rwkv_cfg, d_ff, dtype):
    hd = rwkv_cfg.head_dim
    nh = d_model // hd
    ks = jax.random.split(key, 12)
    lora = rwkv_cfg.mix_lora
    dl = rwkv_cfg.decay_lora
    p = {
        # data-dependent token-shift mixing (ddlerp)
        "mu_base": L.param(ks[0], (5, d_model), (None, "embed"), dtype=dtype, init="zeros"),
        "mix_a": L.param(ks[1], (d_model, 5 * lora), ("embed", "mlp"), dtype=dtype, scale=0.01),
        "mix_b": L.param(ks[2], (5, lora, d_model), (None, "mlp", "embed"), dtype=dtype, scale=0.01),
        # projections
        "wr": L.param(ks[3], (d_model, d_model), ("embed", "heads_mlp"), dtype=dtype),
        "wk": L.param(ks[4], (d_model, d_model), ("embed", "heads_mlp"), dtype=dtype),
        "wv": L.param(ks[5], (d_model, d_model), ("embed", "heads_mlp"), dtype=dtype),
        "wg": L.param(ks[6], (d_model, d_model), ("embed", "heads_mlp"), dtype=dtype),
        "wo": L.param(ks[7], (d_model, d_model), ("heads_mlp", "embed"), dtype=dtype),
        # data-dependent decay (the Finch contribution)
        "w0": L.param(ks[8], (d_model,), ("embed",), dtype=dtype, init="zeros"),
        "decay_a": L.param(ks[9], (d_model, dl), ("embed", "mlp"), dtype=dtype, scale=0.01),
        "decay_b": L.param(ks[10], (dl, d_model), ("mlp", "embed"), dtype=dtype, scale=0.01),
        "u": L.param(ks[11], (nh, hd), ("heads", "head_dim"), dtype=dtype, init="zeros"),
        "ln_x": L.param(jax.random.fold_in(key, 99), (d_model,), ("embed",), init="ones", dtype=dtype),
    }
    return p


def rwkv_time_mix(p, x, rwkv_cfg, *, state=None, return_state=False):
    """x: (B, S, D). state: optional (shift (B, D), S (B, nh, hd, hd))."""
    B, S, D = x.shape
    hd = rwkv_cfg.head_dim
    nh = D // hd
    lora = p["mix_a"].shape[1] // 5

    if state is None:
        shift_in = jnp.zeros((B, D), x.dtype)
    else:
        shift_in = state[0]
    xprev = jnp.concatenate([shift_in[:, None], x[:, :-1]], axis=1)
    xx = xprev - x

    l = jnp.tanh(x @ p["mix_a"]).reshape(B, S, 5, lora)
    mixed = []
    for i in range(5):
        mix = p["mu_base"][i].astype(jnp.float32) + jnp.einsum(
            "bsl,ld->bsd", l[:, :, i], p["mix_b"][i].astype(jnp.float32)
        )
        mixed.append(x + xx * mix.astype(x.dtype))
    x_r, x_k, x_v, x_w, x_g = mixed

    r = (x_r @ p["wr"]).reshape(B, S, nh, hd)
    k = (x_k @ p["wk"]).reshape(B, S, nh, hd)
    v = (x_v @ p["wv"]).reshape(B, S, nh, hd)
    g = x_g @ p["wg"]
    # Data-dependent decay in fp32: w in (0, 1).
    dec = p["w0"].astype(jnp.float32) + jnp.tanh(
        x_w.astype(jnp.float32) @ p["decay_a"].astype(jnp.float32)
    ) @ p["decay_b"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(dec.clip(-8.0, 8.0))).reshape(B, S, nh, hd)

    u = p["u"].astype(jnp.float32)

    def step(Sst, xs):
        r_t, k_t, v_t, w_t = xs  # (B, nh, hd) each
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, Sst + u[None, :, :, None] * kv)
        Sst = w_t[..., None] * Sst + kv
        return Sst, y

    S0 = (
        jnp.zeros((B, nh, hd, hd), jnp.float32)
        if state is None
        else state[1].astype(jnp.float32)
    )
    # Pin the recurrence to the batch axes: the carry must stay local to the
    # batch shard or XLA re-reduces the (B, nh, hd, hd) state every step.
    from repro.sharding import rules as _rules

    S0 = _rules.constrain_batch_dim(S0, 0)
    xs = tuple(
        _rules.constrain_batch_dim(t, 1)
        for t in (
            r.transpose(1, 0, 2, 3).astype(jnp.float32),
            k.transpose(1, 0, 2, 3).astype(jnp.float32),
            v.transpose(1, 0, 2, 3).astype(jnp.float32),
            w.transpose(1, 0, 2, 3),
        )
    )
    S_end, ys = jax.lax.scan(step, S0, xs)
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, D)  # (B, S, D)
    # Per-head group norm, then gate.
    y = y.reshape(B, S, nh, hd)
    y = (y - y.mean(-1, keepdims=True)) * jax.lax.rsqrt(y.var(-1, keepdims=True) + 1e-5)
    y = y.reshape(B, S, D) * p["ln_x"].astype(jnp.float32)
    y = (y.astype(x.dtype) * jax.nn.silu(g)) @ p["wo"]
    if return_state:
        return y, (x[:, -1], S_end.astype(x.dtype))
    return y


def rwkv_channel_mix_init(key, d_model, d_ff, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "mu_k": L.param(k1, (d_model,), ("embed",), dtype=dtype, init="zeros"),
        "wk": L.param(k2, (d_model, d_ff), ("embed", "mlp"), dtype=dtype),
        "wv": L.param(k3, (d_ff, d_model), ("mlp", "embed"), dtype=dtype),
        "wr": L.param(k4, (d_model, d_model), ("embed", "heads_mlp"), dtype=dtype),
    }


def rwkv_channel_mix(p, x, *, state=None, return_state=False):
    B, S, D = x.shape
    shift_in = jnp.zeros((B, D), x.dtype) if state is None else state
    xprev = jnp.concatenate([shift_in[:, None], x[:, :-1]], axis=1)
    xk = x + (xprev - x) * p["mu_k"].astype(x.dtype)
    h = jnp.square(jax.nn.relu(xk @ p["wk"]))
    out = jax.nn.sigmoid(x @ p["wr"]) * (h @ p["wv"])
    if return_state:
        return out, x[:, -1]
    return out


# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------


def mamba_init(key, d_model, ssm_cfg, dtype):
    hd = ssm_cfg.head_dim
    n = ssm_cfg.state_dim
    d_inner = ssm_cfg.expand * d_model
    nh = d_inner // hd
    ks = jax.random.split(key, 6)
    return {
        # in_proj emits [z, x, B, C, dt]
        "w_in": L.param(
            ks[0], (d_model, 2 * d_inner + 2 * n + nh), ("embed", "mlp"), dtype=dtype
        ),
        "conv": L.param(
            ks[1], (ssm_cfg.conv_width, d_inner + 2 * n), (None, "mlp"),
            dtype=dtype, scale=0.5,
        ),
        "a_log": L.param(ks[2], (nh,), ("heads",), dtype=jnp.float32, init="zeros"),
        "dt_bias": L.param(ks[3], (nh,), ("heads",), dtype=jnp.float32, init="zeros"),
        "d_skip": L.param(ks[4], (nh,), ("heads",), dtype=jnp.float32, init="ones"),
        "norm": L.param(ks[5], (d_inner,), ("mlp",), dtype=dtype, init="ones"),
        "w_out": L.param(
            jax.random.fold_in(key, 7), (d_inner, d_model), ("mlp", "embed"), dtype=dtype
        ),
    }


def _causal_conv(x, w, conv_state=None):
    """Depthwise causal conv. x: (B, S, C); w: (K, C); state: (B, K-1, C)."""
    K = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(K)
    )
    new_state = xp[:, -(K - 1) :] if K > 1 else pad
    return out, new_state


def mamba_block(p, x, ssm_cfg, *, state=None, return_state=False):
    """x: (B, S, D). state: (conv_state (B, K-1, C), h (B, nh, hd, N))."""
    B, S, D = x.shape
    hd = ssm_cfg.head_dim
    n = ssm_cfg.state_dim
    d_inner = ssm_cfg.expand * D
    nh = d_inner // hd

    zxbcdt = x @ p["w_in"]
    z, xc, b, c, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + n, 2 * d_inner + 2 * n], axis=-1
    )
    conv_in = jnp.concatenate([xc, b, c], axis=-1)
    conv_state = None if state is None else state[0]
    conv_out, conv_state_new = _causal_conv(conv_in, p["conv"], conv_state)
    conv_out = jax.nn.silu(conv_out)
    xc, b, c = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, S, nh)
    a = jnp.exp(-jnp.exp(p["a_log"].clip(-8.0, 8.0)) * dt)  # (B, S, nh) in (0,1)
    xh = xc.reshape(B, S, nh, hd).astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    c32 = c.astype(jnp.float32)

    def step(h, xs):
        a_t, dtx_t, b_t, c_t = xs
        # h: (B, nh, hd, N)
        h = a_t[..., None, None] * h + jnp.einsum(
            "bhd,bn->bhdn", dtx_t, b_t
        )
        y = jnp.einsum("bhdn,bn->bhd", h, c_t)
        return h, y

    h0 = (
        jnp.zeros((B, nh, hd, n), jnp.float32)
        if state is None
        else state[1].astype(jnp.float32)
    )
    from repro.sharding import rules as _rules

    h0 = _rules.constrain_batch_dim(h0, 0)
    xs = tuple(
        _rules.constrain_batch_dim(t, 1)
        for t in (
            a.transpose(1, 0, 2),
            (dt[..., None] * xh).transpose(1, 0, 2, 3),
            b32.transpose(1, 0, 2),
            c32.transpose(1, 0, 2),
        )
    )
    h_end, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2, 3)  # (B, S, nh, hd)
    y = y + p["d_skip"][None, None, :, None] * xh
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    # Gated RMS norm (mamba2's norm-before-out).
    y = y * jax.nn.silu(z)
    y32 = y.astype(jnp.float32)
    y = (y32 * jax.lax.rsqrt(jnp.mean(jnp.square(y32), -1, keepdims=True) + 1e-6))
    y = (y * p["norm"].astype(jnp.float32)).astype(x.dtype)
    out = y @ p["w_out"]
    if return_state:
        return out, (conv_state_new, h_end.astype(x.dtype))
    return out
