"""Encoder-decoder transformer (seamless-m4t backbone).

The multimodal frontend is a stub per the assignment: ``input_specs()``
supplies pre-computed (B, S_src, D) frame embeddings to the encoder. The
decoder is a standard causal stack with cross-attention into the encoder
output; decode caches both the decoder self-attention KV and the (static)
cross-attention KV computed once at prefill.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L


def _gated(cfg):
    return cfg.activation in ("swiglu", "geglu")


def _enc_layer_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.norm_init(cfg.norm, cfg.d_model, dtype),
        "attn": A.attention_init(k1, cfg.attn, cfg.d_model, dtype),
        "ln2": L.norm_init(cfg.norm, cfg.d_model, dtype),
        "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, dtype,
                          activation=cfg.activation, gated=_gated(cfg)),
    }


def _dec_layer_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": L.norm_init(cfg.norm, cfg.d_model, dtype),
        "self_attn": A.attention_init(k1, cfg.attn, cfg.d_model, dtype),
        "ln_x": L.norm_init(cfg.norm, cfg.d_model, dtype),
        "cross_attn": A.attention_init(k2, cfg.attn, cfg.d_model, dtype),
        "ln2": L.norm_init(cfg.norm, cfg.d_model, dtype),
        "mlp": L.mlp_init(k3, cfg.d_model, cfg.d_ff, dtype,
                          activation=cfg.activation, gated=_gated(cfg)),
    }


def init_encdec(key, cfg):
    from repro.models.transformer import _stack

    dtype = jnp.dtype(cfg.param_dtype)
    ke, kl1, kl2, kh = jax.random.split(key, 4)
    enc_keys = jax.random.split(kl1, cfg.enc_layers)
    dec_keys = jax.random.split(kl2, cfg.num_layers)
    params = {
        "embed": L.embed_init(ke, cfg.vocab_padded, cfg.d_model, dtype),
        "enc_layers": _stack([_enc_layer_init(k, cfg, dtype) for k in enc_keys]),
        "enc_norm": L.norm_init(cfg.norm, cfg.d_model, dtype),
        "dec_layers": _stack([_dec_layer_init(k, cfg, dtype) for k in dec_keys]),
        "final_norm": L.norm_init(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w": L.param(kh, (cfg.d_model, cfg.vocab_padded), ("embed", "vocab"),
                         dtype=dtype)
        }
    return params


def encode(values, cfg, src_embeds):
    """src_embeds: (B, Ss, D) frontend-stub frame embeddings."""
    B, Ss, _ = src_embeds.shape
    x = src_embeds.astype(jnp.dtype(cfg.param_dtype))
    positions = jnp.broadcast_to(jnp.arange(Ss)[None], (B, Ss))

    def body(x, lp):
        h = L.apply_norm(cfg.norm, lp["ln1"], x)
        x = x + A.attn_block(lp["attn"], h, positions, cfg.attn, causal=False)
        h = L.apply_norm(cfg.norm, lp["ln2"], x)
        x = x + L.mlp(lp["mlp"], h, activation=cfg.activation)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(
        body, x, values["enc_layers"],
        unroll=1 if cfg.scan_layers else cfg.enc_layers,
    )
    return L.apply_norm(cfg.norm, values["enc_norm"], x)


def decode_hidden(values, cfg, enc_out, tgt_tokens):
    """Decoder stack up to (but not including) the vocab projection."""
    return decode_train(values, cfg, enc_out, tgt_tokens, return_hidden=True)


def decode_train(values, cfg, enc_out, tgt_tokens, *, collect_cache=False,
                 return_hidden=False):
    B, St = tgt_tokens.shape
    Ss = enc_out.shape[1]
    x = L.embed_lookup(values["embed"], tgt_tokens)
    if cfg.embed_scale:
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype)
    positions = jnp.broadcast_to(jnp.arange(St)[None], (B, St))
    kv_positions = jnp.broadcast_to(jnp.arange(Ss)[None], (B, Ss))

    def body(x, lp):
        h = L.apply_norm(cfg.norm, lp["ln1"], x)
        q, k, v = A.qkv(lp["self_attn"], h, positions, cfg.attn)
        o = A.flash_attention(q, k, v, causal=True)
        x = x + jnp.einsum("bshk,hkd->bsd", o, lp["self_attn"]["wo"])
        h = L.apply_norm(cfg.norm, lp["ln_x"], x)
        x = x + A.cross_attn_block(lp["cross_attn"], h, positions, enc_out,
                                   kv_positions, cfg.attn)
        h = L.apply_norm(cfg.norm, lp["ln2"], x)
        x = x + L.mlp(lp["mlp"], h, activation=cfg.activation)
        if collect_cache:
            ck = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wk"])
            ck = L.rope(ck, kv_positions, theta=cfg.attn.rope_theta)
            cv = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wv"])
            return x, (k, v, ck, cv)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, caches = jax.lax.scan(
        body, x, values["dec_layers"],
        unroll=1 if cfg.scan_layers else cfg.num_layers,
    )
    x = L.apply_norm(cfg.norm, values["final_norm"], x)
    if return_hidden:
        return x
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, values["embed"]["tokens"])
    else:
        logits = x @ values["lm_head"]["w"]
    if collect_cache:
        return logits.astype(jnp.float32), caches
    return logits.astype(jnp.float32)


def encdec_loss(values, cfg, src_embeds, tgt_tokens, labels):
    from repro.models.transformer import chunked_xent

    enc_out = encode(values, cfg, src_embeds)
    x = decode_hidden(values, cfg, enc_out, tgt_tokens)
    loss = chunked_xent(values, cfg, x, labels)
    return loss, {"loss": loss}


def init_encdec_cache(cfg, batch, slots, src_len, dtype=jnp.bfloat16):
    Lc = cfg.num_layers
    kvs = (Lc, batch, slots, cfg.attn.num_kv_heads, cfg.attn.head_dim)
    xkv = (Lc, batch, src_len, cfg.attn.num_kv_heads, cfg.attn.head_dim)
    return {
        "pos": jnp.zeros((), jnp.int32),
        "k": jnp.zeros(kvs, dtype),
        "v": jnp.zeros(kvs, dtype),
        "xk": jnp.zeros(xkv, dtype),
        "xv": jnp.zeros(xkv, dtype),
    }


def encdec_decode_step(values, cfg, cache, tokens):
    """One decoder step against self-KV + precomputed cross-KV caches."""
    B = tokens.shape[0]
    pos = cache["pos"]
    x = L.embed_lookup(values["embed"], tokens)
    if cfg.embed_scale:
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype)
    slots = cache["k"].shape[2]
    write_at = jnp.minimum(pos, slots - 1)
    scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.attn.head_dim, jnp.float32))
    KV, G = cfg.attn.num_kv_heads, cfg.attn.num_heads // cfg.attn.num_kv_heads

    def body(x, xs):
        lp, ck, cv, xk, xv = xs
        h = L.apply_norm(cfg.norm, lp["ln1"], x)
        o, k1, v1 = A.decode_attn(lp["self_attn"], h, ck, cv, pos, cfg.attn)
        x = x + o
        # Cross-attention against the full precomputed encoder KV.
        h = L.apply_norm(cfg.norm, lp["ln_x"], x)
        q = jnp.einsum("bd,dhk->bhk", h, lp["cross_attn"]["wq"])
        q = L.rope(q[:, None], jnp.full((B, 1), pos), theta=cfg.attn.rope_theta)[:, 0]
        qg = q.reshape(B, KV, G, cfg.attn.head_dim)
        s = jnp.einsum("bkgd,bskd->bkgs", qg, xk,
                       preferred_element_type=jnp.float32) * scale
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgs,bskd->bkgd", w.astype(xv.dtype), xv)
        o = o.reshape(B, cfg.attn.num_heads, cfg.attn.head_dim)
        x = x + jnp.einsum("bhk,hkd->bd", o, lp["cross_attn"]["wo"])
        h = L.apply_norm(cfg.norm, lp["ln2"], x)
        x = x + L.mlp(lp["mlp"], h, activation=cfg.activation)
        ck = jax.lax.dynamic_update_index_in_dim(ck, k1.astype(ck.dtype), write_at, axis=1)
        cv = jax.lax.dynamic_update_index_in_dim(cv, v1.astype(cv.dtype), write_at, axis=1)
        return x, (ck, cv)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (values["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"])
    )
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = k_new, v_new
    new_cache["pos"] = pos + 1
    x = L.apply_norm(cfg.norm, values["final_norm"], x)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bd,vd->bv", x, values["embed"]["tokens"])
    else:
        logits = x @ values["lm_head"]["w"]
    return logits.astype(jnp.float32), new_cache
