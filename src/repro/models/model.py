"""Family dispatch facade: one API over decoder-only and encoder-decoder."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import encdec as ED
from repro.models import layers as L
from repro.models import transformer as T


def init_model(key, cfg):
    """Param tree (Param leaves with logical axes)."""
    if cfg.family == "encdec":
        return ED.init_encdec(key, cfg)
    return T.init_lm(key, cfg)


def split_params(params):
    """-> (values tree, logical-axes tree)."""
    return L.split(params)


def loss_fn(values, cfg, batch):
    """batch: dict with 'tokens'/'labels' (+ 'embeds' or 'src_embeds')."""
    if cfg.family == "encdec":
        return ED.encdec_loss(
            values, cfg, batch["src_embeds"], batch["tokens"], batch["labels"]
        )
    return T.lm_loss(
        values, cfg, batch["tokens"], batch["labels"], embeds=batch.get("embeds")
    )


def forward(values, cfg, batch):
    if cfg.family == "encdec":
        enc = ED.encode(values, cfg, batch["src_embeds"])
        return ED.decode_train(values, cfg, enc, batch["tokens"])
    logits, _ = T.forward_lm(
        values, cfg, batch["tokens"], embeds=batch.get("embeds")
    )
    return logits


def init_cache(cfg, batch_size, seq_len, dtype=jnp.bfloat16):
    if cfg.family == "encdec":
        return ED.init_encdec_cache(cfg, batch_size, seq_len, seq_len, dtype)
    spec = T.cache_spec(cfg, batch_size, seq_len)
    return T.init_cache(cfg, spec, dtype)


def decode_step(values, cfg, cache, tokens):
    if cfg.family == "encdec":
        return ED.encdec_decode_step(values, cfg, cache, tokens)
    return T.decode_step(values, cfg, cache, tokens)


def param_count(params):
    values, _ = L.split(params)
    return sum(int(v.size) for v in jax.tree.leaves(values))
