"""Mixture-of-Experts FFN: top-k routing, scatter/gather dispatch, EP-shardable.

Dispatch layout: every (token, choice) is assigned a slot in a capacity-
padded expert-input buffer of shape (E*C + 1, D) (the extra row absorbs
dropped tokens). Dispatch is a scatter-add, combine a gather — O(T k D)
bytes and *zero* extra FLOPs, unlike the dense GShard (T, E, C) one-hot
einsum whose dispatch FLOPs rival the expert GEMMs at 1M-token batches.
Expert weights carry the ``experts`` logical axis (EP over the TP axis when
divisible; expert_mlp sharding otherwise — see sharding/rules.py), and the
expert-input buffer is the EP all-to-all boundary on a real mesh.

Supports the arctic-480b wrinkle: a *dense residual* FFN in parallel with
the routed experts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


def moe_init(key, d_model, moe_cfg, d_ff_default, dtype):
    e = moe_cfg.num_experts
    d_ff = moe_cfg.expert_d_ff or d_ff_default
    kr, ki, kg, ko, kd = jax.random.split(key, 5)
    params = {
        "router": L.param(kr, (d_model, e), ("embed", "experts"), dtype=jnp.float32),
        "wi": L.param(ki, (e, d_model, d_ff), ("experts", "embed", "expert_mlp"), dtype=dtype),
        "wg": L.param(kg, (e, d_model, d_ff), ("experts", "embed", "expert_mlp"), dtype=dtype),
        "wo": L.param(ko, (e, d_ff, d_model), ("experts", "expert_mlp", "embed"), dtype=dtype),
    }
    if moe_cfg.dense_residual:
        params["dense"] = L.mlp_init(kd, d_model, d_ff_default, dtype)
    return params


def moe_block(p, x, moe_cfg, *, activation="swiglu"):
    """x: (B, S, D) -> (out (B, S, D), aux_losses dict)."""
    B, S, D = x.shape
    T = B * S
    e = moe_cfg.num_experts
    k = moe_cfg.top_k
    cap = max(int(moe_cfg.capacity_factor * T * k / e), 1)

    xt = x.reshape(T, D)
    logits = xt.astype(jnp.float32) @ p["router"]  # (T, E) fp32 routing
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Slot assignment: position within the chosen expert via masked cumsum.
    flat_e = expert_idx.reshape(T * k)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (T*k, E)
    pos = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot, axis=-1) - 1  # (T*k,)
    keep = pos < cap
    slot = jnp.where(keep, flat_e * cap + pos, e * cap)  # drop row at e*cap

    # Dispatch: scatter token copies into the expert-input buffer.
    tok = jnp.arange(T * k) // k
    xs = jnp.take(xt, tok, axis=0)  # (T*k, D)
    buf = jnp.zeros((e * cap + 1, D), x.dtype).at[slot].add(xs)
    xe = buf[: e * cap].reshape(e, cap, D)
    # NOTE (§Perf cell D): explicit EP pins on this buffer (experts->model,
    # capacity->data) were tried and REFUTED — the scatter/gather dispatch
    # reshards catastrophically against a row-sharded buffer (2.3x / 5.4x
    # collective regressions). The correct cluster-scale fix is a shard_map
    # all-to-all dispatch; left to XLA's propagation here.

    # Expert FFNs (the EP GEMMs).
    act = jax.nn.silu if activation in ("swiglu", "silu") else jax.nn.gelu
    h = act(jnp.einsum("ecd,edf->ecf", xe, p["wg"])) * jnp.einsum(
        "ecd,edf->ecf", xe, p["wi"]
    )
    ye = jnp.einsum("ecf,efd->ecd", h, p["wo"])  # (E, C, D)

    # Combine: gather back, weight by gates, sum the k choices.
    ye_flat = jnp.concatenate(
        [ye.reshape(e * cap, D), jnp.zeros((1, D), ye.dtype)], axis=0
    )
    y = jnp.take(ye_flat, slot, axis=0).astype(jnp.float32)
    y = y * gate_vals.reshape(T * k, 1)
    out = jnp.sum(y.reshape(T, k, D), axis=1).astype(x.dtype).reshape(B, S, D)

    if "dense" in p:
        out = out + L.mlp(p["dense"], x, activation=activation)

    # Aux losses: load-balance (Switch-style) + router z-loss.
    density = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32), axis=0)
    router_prob = jnp.mean(probs, axis=0)
    aux = {
        "load_balance": moe_cfg.aux_loss_coef * e * jnp.sum(density * router_prob),
        "router_z": moe_cfg.router_z_coef
        * jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1))),
    }
    return out, aux
