"""Attention: flash-style chunked softmax attention + decode cache paths.

Covers every assigned variant: MHA/GQA/MQA (grouped KV), sliding-window
(SWA), logit soft-capping (gemma2), local/global alternation (window passed
as a traced scalar so alternating layers share one scanned body), causal and
bidirectional (encoder / cross-attention) modes.

Training/prefill attention streams KV chunks with an online softmax (running
max / normaliser in fp32), so the (S x S) score matrix never materialises —
the memory behaviour FlashAttention gets on GPUs, expressed here at the XLA
level (a Pallas flash kernel is a recorded §Perf candidate, not required for
the dry-run roofline).

Decode attends one query against a cache; SWA uses a ring buffer of
``window`` slots so a 500k-token stream runs in O(window) memory (the KV
analogue of the paper's O(n) panel streaming).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L

NEG_INF = -1e30


def attention_init(key, attn_cfg, d_model, dtype):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": L.param(kq, (d_model, attn_cfg.num_heads, attn_cfg.head_dim),
                      ("embed", "heads", "head_dim"), dtype=dtype),
        "wk": L.param(kk, (d_model, attn_cfg.num_kv_heads, attn_cfg.head_dim),
                      ("embed", "kv_heads", "head_dim"), dtype=dtype),
        "wv": L.param(kv, (d_model, attn_cfg.num_kv_heads, attn_cfg.head_dim),
                      ("embed", "kv_heads", "head_dim"), dtype=dtype),
        "wo": L.param(ko, (attn_cfg.num_heads, attn_cfg.head_dim, d_model),
                      ("heads", "head_dim", "embed"), dtype=dtype),
    }


def qkv(p, x, positions, attn_cfg):
    """Project + RoPE. x: (B, S, D) -> q (B,S,H,Dh), k/v (B,S,KV,Dh)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = L.rope(q, positions, theta=attn_cfg.rope_theta)
    k = L.rope(k, positions, theta=attn_cfg.rope_theta)
    return q, k, v


def flash_attention(
    q,
    k,
    v,
    *,
    causal: bool,
    window=None,
    cap: Optional[float] = None,
    q_offset=0,
    kv_offset=0,
    q_chunk: int = 2048,
    kv_chunk: int = 1024,
):
    """Chunked online-softmax attention.

    q: (B, Sq, H, Dh); k, v: (B, Skv, KV, Dh) with H % KV == 0.
    ``window`` may be None, a python int, or a traced scalar (0/negative
    disables it) — the traced form is what lets gemma2's alternating
    local/global layers share one scanned layer body.
    Offsets give global positions (cross-chunk prefill, right-aligned decode).
    """
    B, Sq, H, Dh = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    if Sq % q_chunk or Skv % kv_chunk:
        raise ValueError(f"chunk sizes must divide: {Sq}%{q_chunk}, {Skv}%{kv_chunk}")
    nq, nk = Sq // q_chunk, Skv // kv_chunk
    scale = 1.0 / jnp.sqrt(jnp.asarray(Dh, jnp.float32))

    qg = q.reshape(B, nq, q_chunk, KV, G, Dh).transpose(1, 0, 3, 4, 2, 5)
    # qg: (nq, B, KV, G, Cq, Dh)
    kc = k.reshape(B, nk, kv_chunk, KV, Dh).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(B, nk, kv_chunk, KV, Dh).transpose(1, 0, 3, 2, 4)
    # kc, vc: (nk, B, KV, Ckv, Dh)

    if window is None:
        window_val = jnp.asarray(0, jnp.int32)
    else:
        window_val = jnp.asarray(window, jnp.int32)

    def q_block(qi, q_blk):
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_block(carry, xs):
            m, l, acc = carry
            ki, k_blk, v_blk = xs
            kv_pos = kv_offset + ki * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum(
                "bkgqd,bksd->bkgqs", q_blk, k_blk,
                preferred_element_type=jnp.float32,
            ) * scale
            s = L.softcap(s, cap)
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= kv_pos[None, :] <= q_pos[:, None]
            win_mask = kv_pos[None, :] > (q_pos[:, None] - window_val)
            mask &= jnp.where(window_val > 0, win_mask, True)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # Mask p explicitly: with a finite NEG_INF sentinel, a fully
            # masked block would otherwise produce exp(0) = 1 everywhere.
            p = jnp.where(mask[None, None, None], jnp.exp(s - m_new[..., None]), 0.0)
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum(
                "bkgqs,bksd->bkgqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32,
            )
            acc = acc * corr[..., None] + pv
            return (m_new, l, acc), None

        m0 = jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_chunk, Dh), jnp.float32)
        # Remat each KV block: the backward pass recomputes the block scores
        # instead of saving the (Cq x Ckv) probability tensors — the
        # FlashAttention memory behaviour, at one extra QK^T per block.
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_block), (m0, l0, a0), (jnp.arange(nk), kc, vc)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out  # (B, KV, G, Cq, Dh)

    outs = jax.lax.map(lambda xs: q_block(xs[0], xs[1]), (jnp.arange(nq), qg))
    # outs: (nq, B, KV, G, Cq, Dh) -> (B, Sq, H, Dh)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, KV * G, Dh)
    return out.astype(q.dtype)


def attn_block(p, x, positions, attn_cfg, *, causal=True, window=None):
    """Full attention sub-layer (projections + flash + output)."""
    q, k, v = qkv(p, x, positions, attn_cfg)
    o = flash_attention(
        q, k, v, causal=causal, window=window, cap=attn_cfg.softcap
    )
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def cross_attn_block(p, x, positions, kv_src, kv_positions, attn_cfg):
    """Cross-attention: queries from x, keys/values from kv_src (encoder)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q = L.rope(q, positions, theta=attn_cfg.rope_theta)
    k = jnp.einsum("bsd,dhk->bshk", kv_src, p["wk"])
    k = L.rope(k, kv_positions, theta=attn_cfg.rope_theta)
    v = jnp.einsum("bsd,dhk->bshk", kv_src, p["wv"])
    o = flash_attention(q, k, v, causal=False, cap=attn_cfg.softcap)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


# ---------------------------------------------------------------------------
# Decode (single new token against a cache).
# ---------------------------------------------------------------------------


def decode_attn(p, x1, cache_k, cache_v, pos, attn_cfg, *, window=None, ring=False):
    """One-token attention against a (ring or linear) cache.

    x1: (B, D) current token activations; cache_k/v: (B, S_slots, KV, Dh)
    (already rotated); pos: scalar current position. ``ring=True`` treats the
    cache as a ring buffer of S_slots recent positions (SWA, O(window)
    memory for 500k streams); ``window`` (python int or traced scalar; 0/neg
    disables) additionally masks a sliding window inside a *linear* cache —
    that is how gemma2's alternating local/global layers decode against one
    stacked cache. Returns (out, k_new, v_new) where k_new/v_new are this
    step's rotated K/V (B, KV, Dh) for the caller to insert (at slot
    ``pos % S_slots`` when ring, else ``pos``).
    """
    B, S_slots, KV, Dh = cache_k.shape
    H = attn_cfg.num_heads
    G = H // KV
    pos_arr = jnp.full((B, 1), pos)
    q = jnp.einsum("bd,dhk->bhk", x1, p["wq"])[:, None]  # (B, 1, H, Dh)
    q = L.rope(q, pos_arr, theta=attn_cfg.rope_theta)[:, 0]
    k1 = jnp.einsum("bd,dhk->bhk", x1, p["wk"])[:, None]
    k1 = L.rope(k1, pos_arr, theta=attn_cfg.rope_theta)[:, 0]
    v1 = jnp.einsum("bd,dhk->bhk", x1, p["wv"])

    slot = jnp.arange(S_slots)
    if ring:
        # Slot s holds absolute position pos - ((pos - s) % W); the caller
        # writes this step's K/V at slot pos % W after the call.
        slot_pos = pos - jnp.mod(pos - slot, S_slots)
        valid = (slot_pos >= 0) & (slot_pos != pos)
    else:
        valid = slot < pos
        if window is not None:
            window_val = jnp.asarray(window, jnp.int32)
            win_ok = slot > (pos - window_val)
            valid &= jnp.where(window_val > 0, win_ok, True)

    qg = q.reshape(B, KV, G, Dh)
    scale = 1.0 / jnp.sqrt(jnp.asarray(Dh, jnp.float32))
    s = jnp.einsum("bkgd,bskd->bkgs", qg, cache_k,
                   preferred_element_type=jnp.float32) * scale
    s_self = jnp.einsum("bkgd,bkd->bkg", qg, k1.reshape(B, KV, Dh),
                        preferred_element_type=jnp.float32)[..., None] * scale
    s = L.softcap(s, attn_cfg.softcap)
    s_self = L.softcap(s_self, attn_cfg.softcap)
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    s_all = jnp.concatenate([s, s_self], axis=-1)
    w = jax.nn.softmax(s_all.astype(jnp.float32), axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", w[..., :-1].astype(cache_v.dtype), cache_v,
                   preferred_element_type=jnp.float32)
    o = o + w[..., -1:].astype(jnp.float32) * v1.reshape(B, KV, 1, Dh).astype(jnp.float32)
    o = o.reshape(B, H, Dh).astype(x1.dtype)
    out = jnp.einsum("bhk,hkd->bd", o, p["wo"])
    return out, k1.reshape(B, KV, Dh), v1.reshape(B, KV, Dh)
