"""Process-local metrics registry: counters, gauges, histograms (DESIGN.md §13).

The source paper's performance story is *accounting* — launches,
bytes-per-update, dependency-chain stalls — yet until this module those
quantities lived in four unrelated module-global counters
(``launches_traced`` × 2, ``mutations_issued``/``traces_counted``,
``lowerings_traced``) plus ad-hoc ``perf_counter`` spans, and latency
percentiles existed only inside ``benchmarks/stream_bench.py``. This is
the single seam they all report through now:

* **Counter** — monotonically increasing event count (``inc``).
* **Gauge** — last-write-wins instantaneous value (``set``).
* **Histogram** — fixed log-spaced buckets (power-of-two edges, exactly
  representable, so golden tests can pin them): ``observe`` drops a value
  into its bucket, ``percentile`` reads p50/p99 back out. The serving
  stack computes its own latency percentiles instead of every benchmark
  recomputing them.

Series are keyed by ``(name, labels)`` — labels are the
backend/lowering/structure/dtype/sign axes the conformance tables slice
by. ``snapshot()`` returns a plain-dict view (JSON-ready; the benchmark
snapshot files embed it verbatim), ``export_jsonl`` appends one record
per call, and ``total(name)`` sums a metric across every label set —
which is exactly what the legacy counter shims return, so the shims are
equivalent to the registry *by construction*.

Thread-safety: one lock per registry guards both the series table and
every mutation — the background flush worker (DESIGN.md §11) increments
from its own thread while the producer reads snapshots. Mutations are a
dict lookup + integer add; contention at serving rates is negligible
next to a device dispatch.

Stdlib-only on purpose: every layer (core, kernels, stream, checkpoint,
benchmarks) imports this module, so it must not pull in jax — the
pure-JAX core's lazy-import policy (``repro.core.backends``) stays
intact.
"""
from __future__ import annotations

import bisect
import json
import threading
import time
from typing import Dict, Iterable, Optional, Tuple

#: Fixed log-spaced latency buckets, in SECONDS: power-of-two multiples of
#: 1 microsecond, 1us .. ~16.8s (25 edges + overflow). Power-of-two edges
#: are exactly representable in binary floating point, so the golden test
#: can pin them without tolerance gymnastics.
LATENCY_BUCKETS_S: Tuple[float, ...] = tuple(1e-6 * 2 ** i for i in range(25))

#: Width/occupancy buckets: powers of two 1 .. 4096 (the coalesce-width
#: and ladder-rung scales are both power-of-two ladders already).
WIDTH_BUCKETS: Tuple[float, ...] = tuple(float(2 ** i) for i in range(13))


def _label_key(labels: Dict[str, object]) -> str:
    """Canonical series key: ``name{a=1,b=x}`` with sorted label names."""
    if not labels:
        return ""
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return "{" + inner + "}"


class _Metric:
    """Shared plumbing: every metric belongs to one registry whose lock
    guards its mutations (see module docstring)."""

    def __init__(self, registry: "Registry", name: str,
                 labels: Dict[str, object]):
        self._lock = registry._lock
        self.name = name
        self.labels = dict(labels)


class Counter(_Metric):
    """Monotonic event counter."""

    def __init__(self, registry, name, labels):
        super().__init__(registry, name, labels)
        self._value = 0

    def inc(self, k: int = 1) -> None:
        with self._lock:
            self._value += k

    @property
    def value(self) -> int:
        with self._lock:
            return self._value


class Gauge(_Metric):
    """Last-write-wins instantaneous value (queue depth, occupancy)."""

    def __init__(self, registry, name, labels):
        super().__init__(registry, name, labels)
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def add(self, k: float = 1.0) -> None:
        with self._lock:
            self._value += k

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram(_Metric):
    """Fixed-bucket histogram. ``counts[i]`` holds observations with
    ``edges[i-1] < v <= edges[i]`` (``counts[0]``: ``v <= edges[0]``);
    the trailing slot is the overflow bucket, so ``len(counts) ==
    len(edges) + 1`` always."""

    def __init__(self, registry, name, labels,
                 edges: Tuple[float, ...] = LATENCY_BUCKETS_S):
        super().__init__(registry, name, labels)
        self.edges = tuple(float(e) for e in edges)
        self._counts = [0] * (len(self.edges) + 1)
        self._count = 0
        self._sum = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        idx = bisect.bisect_left(self.edges, v)
        with self._lock:
            self._counts[idx] += 1
            self._count += 1
            self._sum += v

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def percentile(self, q: float) -> float:
        """Bucket-resolution percentile (upper edge of the rank's bucket)."""
        with self._lock:
            return percentile_from(
                {"edges": self.edges, "counts": list(self._counts),
                 "count": self._count}, q)


def percentile_from(hist: Dict, q: float) -> float:
    """Percentile from a histogram *snapshot entry* (also works on the
    JSON-round-tripped dicts in ``BENCH_stream.json`` — the report
    renderer reads percentiles from recorded snapshots with this).

    Returns the upper edge of the bucket the rank falls in (overflow
    observations report the last edge — the histogram cannot resolve
    beyond its range); NaN on an empty histogram.
    """
    count = hist["count"]
    if count == 0:
        return float("nan")
    rank = max(1, int(round(q / 100.0 * count)))
    seen = 0
    edges, counts = hist["edges"], hist["counts"]
    for i, c in enumerate(counts):
        seen += c
        if seen >= rank:
            return float(edges[min(i, len(edges) - 1)])
    return float(edges[-1])


class Registry:
    """One process-local metrics registry (see module docstring)."""

    def __init__(self):
        self._lock = threading.RLock()
        self._series: Dict[Tuple[str, str], _Metric] = {}

    def _get(self, cls, name: str, labels: Dict[str, object], **kw):
        key = (name, _label_key(labels))
        with self._lock:
            m = self._series.get(key)
            if m is None:
                m = cls(self, name, labels, **kw)
                self._series[key] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r}{key[1]} already registered as "
                    f"{type(m).__name__}, requested {cls.__name__}")
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, *, buckets: Optional[Iterable[float]] =
                  None, **labels) -> Histogram:
        kw = {} if buckets is None else {"edges": tuple(buckets)}
        return self._get(Histogram, name, labels, **kw)

    def total(self, name: str) -> float:
        """Sum of a counter/gauge across every label set — the quantity the
        legacy counter shims (``mutations_issued`` et al.) return."""
        with self._lock:
            vals = [m._value for (n, _), m in self._series.items()
                    if n == name and not isinstance(m, Histogram)]
        return sum(vals)

    def value(self, name: str, **labels) -> float:
        """One series' current value (0 when the series does not exist yet
        — reading a metric must never create it)."""
        key = (name, _label_key(labels))
        with self._lock:
            m = self._series.get(key)
            return 0 if m is None else m._value

    def snapshot(self) -> Dict:
        """Plain-dict view of every series, keyed ``name{labels}``:

        ``{"counters": {...}, "gauges": {...}, "histograms": {...}}``

        Histogram entries carry count/sum/edges/counts so percentiles are
        recomputable from the snapshot alone (``percentile_from``) — the
        benchmark trajectory files embed these verbatim.
        """
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        with self._lock:
            for (name, lk), m in sorted(self._series.items()):
                key = name + lk
                if isinstance(m, Counter):
                    out["counters"][key] = m._value
                elif isinstance(m, Gauge):
                    out["gauges"][key] = m._value
                else:
                    out["histograms"][key] = {
                        "count": m._count,
                        "sum": m._sum,
                        "edges": list(m.edges),
                        "counts": list(m._counts),
                    }
        return out

    def export_jsonl(self, path) -> None:
        """Append one timestamped snapshot record (JSONL, same append-only
        convention as the benchmark trajectory files)."""
        rec = {"ts": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
               **self.snapshot()}
        with open(path, "a") as fh:
            fh.write(json.dumps(rec) + "\n")

    def reset(self) -> None:
        """Drop every series (tests only — the legacy shims are cumulative
        within a process, like the module globals they replaced)."""
        with self._lock:
            self._series.clear()


def diff_snapshots(before: Dict, after: Dict) -> Dict:
    """``after - before`` per series: counters/gauges subtract, histogram
    counts/sum subtract bucket-wise (edges must match). Series absent from
    ``before`` pass through — this is how a benchmark isolates one drive's
    metrics without resetting the process-cumulative registry."""
    out = {"counters": {}, "gauges": {}, "histograms": {}}
    for kind in ("counters", "gauges"):
        for key, v in after.get(kind, {}).items():
            out[kind][key] = v - before.get(kind, {}).get(key, 0)
    for key, h in after.get("histograms", {}).items():
        h0 = before.get("histograms", {}).get(key)
        if h0 is None:
            out["histograms"][key] = h
            continue
        if list(h0["edges"]) != list(h["edges"]):
            raise ValueError(f"histogram {key!r} edges changed between "
                             "snapshots — cannot diff")
        out["histograms"][key] = {
            "count": h["count"] - h0["count"],
            "sum": h["sum"] - h0["sum"],
            "edges": list(h["edges"]),
            "counts": [a - b for a, b in zip(h["counts"], h0["counts"])],
        }
    return out


#: The default registry every instrumented layer reports to. Tests build
#: private ``Registry()`` instances; production code uses these
#: module-level conveniences.
REGISTRY = Registry()


def counter(name: str, **labels) -> Counter:
    return REGISTRY.counter(name, **labels)


def gauge(name: str, **labels) -> Gauge:
    return REGISTRY.gauge(name, **labels)


def histogram(name: str, *, buckets: Optional[Iterable[float]] = None,
              **labels) -> Histogram:
    return REGISTRY.histogram(name, buckets=buckets, **labels)


def total(name: str) -> float:
    return REGISTRY.total(name)


def value(name: str, **labels) -> float:
    return REGISTRY.value(name, **labels)


def snapshot() -> Dict:
    return REGISTRY.snapshot()


def export_jsonl(path) -> None:
    REGISTRY.export_jsonl(path)
