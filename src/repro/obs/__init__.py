"""``repro.obs`` — the measurement layer of the stack (DESIGN.md §13).

One process-local **metrics registry** (counters / gauges / histograms
with fixed log-spaced buckets, labeled by backend/lowering/structure/
dtype/sign) plus **span tracing** with a Chrome ``trace_event`` exporter.
Every layer reports through it:

* ``repro.core.backends.dispatch`` — resolve decisions, launch counts and
  bytes-per-update by backend/lowering/structure;
* ``repro.core.CholFactor`` — update/downdate/guard traffic;
* ``repro.stream`` — per-flush latency histograms, coalesce widths, queue
  depth, admissions/evictions/promotions, ladder occupancy, step-cache
  tiers, retrace events, WAL bytes/records, checkpoint/restore spans,
  per-executable warmup compile times;
* the legacy counters (``launches_traced``, ``mutations_issued``,
  ``traces_counted``, ``lowerings_traced``) are thin shims over this
  registry — same numbers, one source of truth.

Environment toggles (read at process exit, exported atexit):
``REPRO_OBS_TRACE=path.json`` writes the Chrome trace;
``REPRO_OBS_METRICS=path.json`` writes the metrics snapshot.

Stdlib-only: safe to import from any layer, including the pure-JAX core.
"""
from __future__ import annotations

import atexit

from repro.obs.metrics import (
    LATENCY_BUCKETS_S,
    WIDTH_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    Registry,
    REGISTRY,
    counter,
    diff_snapshots,
    export_jsonl,
    gauge,
    histogram,
    percentile_from,
    snapshot,
    total,
    value,
)
from repro.obs.tracing import (
    METRICS_ENV,
    RECORDER,
    TRACE_ENV,
    SpanEvent,
    SpanRecorder,
    chrome_trace,
    export_chrome_trace,
    instant,
    span,
    traced,
    _export_at_exit,
)

atexit.register(_export_at_exit)


def summary_line() -> str:
    """One-line serving-metrics summary (the ``--stats`` exit line of the
    examples): the quantities the paper says matter, read back from the
    registry instead of recomputed by every consumer."""
    from repro.obs import metrics

    flush = None
    snap = metrics.snapshot()
    # Merge every flush-latency series (one per reason label) for the
    # headline percentiles.
    merged = None
    for key, h in snap["histograms"].items():
        if key.startswith("repro.stream.flush_seconds"):
            if merged is None:
                merged = {"count": 0, "sum": 0.0, "edges": h["edges"],
                          "counts": [0] * len(h["counts"])}
            merged["count"] += h["count"]
            merged["sum"] += h["sum"]
            merged["counts"] = [a + b for a, b in
                                zip(merged["counts"], h["counts"])]
    if merged and merged["count"]:
        p50 = metrics.percentile_from(merged, 50) * 1e6
        p99 = metrics.percentile_from(merged, 99) * 1e6
        flush = f"flushes={merged['count']} p50<={p50:.0f}us p99<={p99:.0f}us"
    bits = [
        f"mutations={int(total('repro.stream.mutations'))}",
        flush or "flushes=0",
        f"retraces={int(total('repro.stream.retraces'))}",
        f"admissions={int(total('repro.stream.admissions'))}",
        f"evictions={int(total('repro.stream.evictions'))}",
        f"wal_bytes={int(total('repro.stream.wal_bytes'))}",
        f"occupancy={value('repro.stream.ladder_occupancy'):.2f}",
        f"spans={len(RECORDER)}",
    ]
    return "obs: " + " ".join(bits)
