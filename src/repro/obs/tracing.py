"""Span tracing + Chrome ``trace_event`` export (DESIGN.md §13).

``span()`` brackets a region of the serving stack (a flush, a background
drain, a checkpoint, a warmup compile) and records one structured event —
name, start, duration, labels, thread — into a bounded ring buffer.
``chrome_trace()`` renders the buffer in the Chrome ``trace_event`` JSON
format (the Perfetto/chrome://tracing interchange schema), so a
``StreamService`` run can literally be *opened in a trace viewer*: flush
spans on the producer thread, drain spans on the flush worker's thread,
checkpoint/restore spans wherever they ran — the dependency-chain-stall
story the paper tells, as a timeline.

Recording is always on (a deque append + two ``perf_counter`` calls per
span — noise next to a device dispatch) and bounded (ring buffer, oldest
events drop first), so tracing never needs an enable flag on the hot
path. Export is explicit (``export_chrome_trace``) or environment-driven:
``REPRO_OBS_TRACE=path.json`` writes the trace at process exit (and
``REPRO_OBS_METRICS=path.json`` the metrics snapshot) — the toggle
``scripts/bench.sh`` and the CI tracing step use.

Every exported event carries the full key set ``name/ph/ts/dur/pid/tid``
(instant events included, with ``dur=0``) — ``tests/test_obs.py`` pins
the schema. Timestamps are microseconds from the recorder's epoch, the
unit the trace_event format specifies.

Stdlib-only, same as ``repro.obs.metrics`` and for the same reason.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import functools
import json
import os
import threading
import time
from typing import Dict, List, Optional

TRACE_ENV = "REPRO_OBS_TRACE"
METRICS_ENV = "REPRO_OBS_METRICS"

#: Default ring capacity: enough for ~100k spans (a long serving session)
#: while bounding memory to a few tens of MB worst-case.
DEFAULT_CAPACITY = 131072


@dataclasses.dataclass
class SpanEvent:
    """One recorded span (durations and timestamps in MICROSECONDS —
    the trace_event unit — relative to the recorder's epoch)."""

    name: str
    ts: float
    dur: float
    tid: int
    labels: Dict[str, object] = dataclasses.field(default_factory=dict)
    phase: str = "X"  # 'X' complete span | 'i' instant


class SpanRecorder:
    """Bounded thread-safe ring buffer of ``SpanEvent``s."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        self._lock = threading.Lock()
        self._ring: "collections.deque[SpanEvent]" = collections.deque(
            maxlen=capacity)
        self._epoch = time.perf_counter()

    def now_us(self) -> float:
        return (time.perf_counter() - self._epoch) * 1e6

    def record(self, event: SpanEvent) -> None:
        with self._lock:
            self._ring.append(event)

    def events(self) -> List[SpanEvent]:
        with self._lock:
            return list(self._ring)

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)


#: The default recorder every instrumented layer records into.
RECORDER = SpanRecorder()


@contextlib.contextmanager
def span(name: str, *, recorder: Optional[SpanRecorder] = None, **labels):
    """Record one complete ('X') span around the block. Yields the event
    (its ``labels`` dict is live — a block can attach results, e.g. the
    flush attaches its width/mutation counts before the span closes).

    ``recorder is None`` — not truthiness — selects the default: an EMPTY
    recorder is falsy (``__len__``), and must still receive its spans."""
    rec = RECORDER if recorder is None else recorder
    ev = SpanEvent(name=name, ts=rec.now_us(), dur=0.0,
                   tid=threading.get_ident(), labels=labels)
    try:
        yield ev
    finally:
        ev.dur = rec.now_us() - ev.ts
        rec.record(ev)


def instant(name: str, *, recorder: Optional[SpanRecorder] = None,
            **labels) -> None:
    """Record a zero-duration instant event (e.g. a retrace marker)."""
    rec = RECORDER if recorder is None else recorder
    rec.record(SpanEvent(name=name, ts=rec.now_us(), dur=0.0,
                         tid=threading.get_ident(), labels=labels,
                         phase="i"))


def traced(name: Optional[str] = None, **labels):
    """Decorator form of ``span`` — the function body becomes one span
    named after the function (or ``name=``)."""

    def deco(fn):
        span_name = name or f"{fn.__module__}.{fn.__qualname__}"

        @functools.wraps(fn)
        def wrapper(*args, **kw):
            with span(span_name, **labels):
                return fn(*args, **kw)

        return wrapper

    return deco


def chrome_trace(events: Optional[List[SpanEvent]] = None) -> Dict:
    """The Chrome ``trace_event`` JSON object for ``events`` (default: the
    default recorder's ring). Every event carries name/ph/ts/dur/pid/tid;
    labels ride in ``args``; instant events add the thread scope marker
    the viewer expects."""
    pid = os.getpid()
    out = []
    for ev in (RECORDER.events() if events is None else events):
        rec = {
            "name": ev.name,
            "ph": ev.phase,
            "ts": ev.ts,
            "dur": ev.dur,
            "pid": pid,
            "tid": ev.tid,
            "args": {k: _jsonable(v) for k, v in ev.labels.items()},
        }
        if ev.phase == "i":
            rec["s"] = "t"
        out.append(rec)
    return {"traceEvents": out,
            "displayTimeUnit": "ms",
            "otherData": {"producer": "repro.obs"}}


def _jsonable(v):
    return v if isinstance(v, (str, int, float, bool, type(None))) else str(v)


def export_chrome_trace(path, events: Optional[List[SpanEvent]] = None
                        ) -> None:
    """Write the trace to ``path`` (open it in chrome://tracing or
    ui.perfetto.dev)."""
    with open(path, "w") as fh:
        json.dump(chrome_trace(events), fh)


def _export_at_exit() -> None:
    """The ``REPRO_OBS_TRACE``/``REPRO_OBS_METRICS`` exit hook (registered
    by ``repro.obs`` at import; env read at EXIT so a toggle set after
    import still works). Failures are swallowed — observability export
    must never turn a clean exit into a crash."""
    trace_path = os.environ.get(TRACE_ENV)
    if trace_path:
        try:
            export_chrome_trace(trace_path)
        except OSError:
            pass
    metrics_path = os.environ.get(METRICS_ENV)
    if metrics_path:
        try:
            from repro.obs import metrics

            with open(metrics_path, "w") as fh:
                json.dump(metrics.snapshot(), fh)
        except OSError:
            pass
