"""Minimal functional optimizer substrate (no optax in the container).

An ``Optimizer`` is an (init, update) pair over parameter pytrees:

    state   = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params  = apply_updates(params, updates)

Updates are *deltas* (already scaled by the learning rate, sign included).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., Any]  # (grads, state, params) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p + u.astype(p.dtype)) if u is not None else p,
        params,
        updates,
        is_leaf=lambda x: x is None,
    )


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def cast_tree(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), tree)
