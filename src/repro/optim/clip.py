"""Gradient clipping / finiteness guards."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.base import global_norm


def clip_by_global_norm(grads, max_norm: float):
    """Returns (clipped_grads, pre_clip_norm)."""
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def all_finite(tree):
    leaves = [jnp.all(jnp.isfinite(x)) for x in jax.tree.leaves(tree)]
    return jnp.all(jnp.stack(leaves))
