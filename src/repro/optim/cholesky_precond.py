"""CholeskyPrecond: the paper's rank-k up/down-date as a training-time feature.

A sketched Online-Newton-Step (ONS) optimizer in the Shampoo/Sketchy family.
For every 2-D parameter ``W (m, n)`` it preconditions the gradient over the
*smaller* side with the maintained statistics

    A = eps*I + sum_s beta^(t-s) V_s V_s^T,     V_s = G_s Omega / sqrt(k)

where ``V_s`` is a rank-k JL sketch of step s's gradient. The key point is
that ``A``'s upper-Cholesky factor is **never re-factorised**:

* per step, the factor absorbs the new sketch with the paper's **rank-k
  update** — O(k d^2) instead of the O(d^3) refactorization;
* exponential decay ``beta`` is exact factor scaling (``C <- sqrt(beta) C``);
* an optional exact sliding window (``window > 0``) **downdates** the factor
  by the expiring (decay-scaled) sketch — an operation that only the
  up/down-dating formulation supports without refactorization, i.e. the
  paper's downdate path running in production every step.

The preconditioned direction ``A^{-1} G`` (or ``G A^{-1}``) comes from two
triangular solves against the maintained factor and is *grafted* onto Adam's
per-parameter step norm (standard Shampoo practice), so step sizes track a
well-tuned Adam while directions come from the second-order statistics.

Dimensions larger than ``block_size`` are blocked Shampoo-style: independent
diagonal blocks stacked in one (n_blocks, b, b) array — vmapped cholupdates,
and a natural sharding axis for TP/EP. Non-2D params take the Adam path.

The statistics are maintained as a batched ``repro.core.factor.CholFactor``
living directly in the optimizer state: decay is ``.scale``, the sketch
absorb is ``.update``, the window eviction is ``.downdate``, and the
preconditioned direction is ``.solve`` — every mutation flows through the
backend registry (``update_method='auto'`` resolves to the fused
single-launch kernel on TPU, the oracle/GEMM drivers elsewhere), so training
exercises exactly the engine serving uses.
"""
from __future__ import annotations

from typing import Callable, Union

import jax
import jax.numpy as jnp

from repro.core.factor import CholFactor
from repro.optim.adamw import _lr_at
from repro.optim.base import Optimizer


def _precond_side(p_shape, max_precond_dim, rank, block_size):
    """Which side to precondition: the smaller one; None if ineligible."""
    if len(p_shape) != 2:
        return None
    m, n = p_shape
    d = min(m, n)
    if d < 2 * rank or d > max_precond_dim:
        return None
    b = min(block_size, d)
    if d % b:
        return None
    return "left" if m <= n else "right"


def cholesky_precond(
    lr: Union[float, Callable] = 1e-3,
    *,
    rank: int = 16,
    block_size: int = 1024,
    beta: float = 0.999,
    window: int = 0,
    eps: float = 1e-2,
    b1: float = 0.9,
    b2: float = 0.95,
    adam_eps: float = 1e-8,
    weight_decay: float = 0.0,
    max_precond_dim: int = 16384,
    update_method: str = "auto",
    seed: int = 0,
) -> Optimizer:
    """See module docstring. ``window > 0`` enables exact sliding-window stats
    (paper downdates every step); it composes with ``beta`` by downdating the
    expiring sketch scaled by ``beta**(window/2)``."""

    def init(params):
        def per_param(p):
            side = _precond_side(p.shape, max_precond_dim, rank, block_size)
            if side is None:
                return None
            d = min(p.shape)
            b = min(block_size, d)
            nb = d // b
            # The maintained statistics ARE a CholFactor: a batched factor
            # of eps*I per diagonal block, every mutation routed through the
            # backend registry (fused kernel on TPU, oracle/GEMM elsewhere).
            c0 = CholFactor.identity(
                b, scale=eps, batch=nb, backend=update_method,
                panel=min(256, b),
            )
            state = {"c": c0}
            if window > 0:
                state["ring"] = jnp.zeros((window, d, rank), jnp.float32)
            return state

        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "factors": jax.tree.map(per_param, params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)

        def upd(path_idx, g, m, v, p, fac):
            g32 = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * g32
            v_new = b2 * v + (1 - b2) * jnp.square(g32)
            adam_dir = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + adam_eps)
            side = _precond_side(g32.shape, max_precond_dim, rank, block_size)
            if fac is None or side is None:
                delta = -lr_t * (adam_dir + weight_decay * p.astype(jnp.float32))
                return delta, m_new, v_new, fac

            gmat = g32 if side == "left" else g32.T  # (d, other)
            d, other = gmat.shape
            b = min(block_size, d)

            om = jax.random.normal(
                jax.random.fold_in(key, path_idx), (other, rank), jnp.float32
            ) / jnp.sqrt(jnp.asarray(rank, jnp.float32))
            sketch = gmat @ om  # (d, k)

            # Exponential decay is exact factor scaling; the new sketch is a
            # rank-k update; the expiring sketch a rank-k downdate — all on
            # the ONE maintained CholFactor, never refactorizing.
            c = fac["c"].scale(jnp.sqrt(jnp.asarray(beta, jnp.float32)))
            vb = sketch.reshape(d // b, b, rank)
            c = c.update(vb)
            fac_new = dict(fac)
            if window > 0:
                slot = (step - 1) % window
                old = jax.lax.dynamic_index_in_dim(
                    fac["ring"], slot, axis=0, keepdims=False
                )
                scale = jnp.asarray(beta, jnp.float32) ** (window / 2.0)
                ob = (old * scale).reshape(d // b, b, rank)
                c = c.downdate(ob)
                fac_new["ring"] = jax.lax.dynamic_update_index_in_dim(
                    fac["ring"], sketch, slot, axis=0
                )
            fac_new["c"] = c

            # direction = A^{-1} gmat: two triangular solves per block
            # against the maintained factor.
            gb = gmat.reshape(d // b, b, other)
            pdir = c.solve(gb).reshape(d, other)
            if side == "right":
                pdir = pdir.T
            # Grafting: second-order direction, Adam step norm.
            p_norm = jnp.linalg.norm(pdir) + 1e-16
            a_norm = jnp.linalg.norm(adam_dir)
            direction = pdir * (a_norm / p_norm)
            delta = -lr_t * (direction + weight_decay * p.astype(jnp.float32))
            return delta, m_new, v_new, fac_new

        g_flat, treedef = jax.tree.flatten(grads)
        m_flat = treedef.flatten_up_to(state["m"])
        v_flat = treedef.flatten_up_to(state["v"])
        p_flat = treedef.flatten_up_to(params)
        f_flat = treedef.flatten_up_to(state["factors"])
        out = [
            upd(i, g, m, v, p, f)
            for i, (g, m, v, p, f) in enumerate(
                zip(g_flat, m_flat, v_flat, p_flat, f_flat)
            )
        ]
        deltas = treedef.unflatten([o[0] for o in out])
        new_state = {
            "step": step,
            "m": treedef.unflatten([o[1] for o in out]),
            "v": treedef.unflatten([o[2] for o in out]),
            "factors": treedef.unflatten([o[3] for o in out]),
        }
        return deltas, new_state

    return Optimizer(init=init, update=update)
