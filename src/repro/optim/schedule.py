"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    return lambda step: jnp.asarray(value, jnp.float32)


def warmup_cosine(peak: float, *, warmup_steps: int, total_steps: int, floor: float = 0.0):
    """Linear warmup to ``peak`` then cosine decay to ``floor``."""

    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup_steps, 1)
        prog = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup_steps, warm, cos)

    return sched


def inverse_sqrt(peak: float, *, warmup_steps: int):
    def sched(step):
        step = jnp.maximum(step.astype(jnp.float32), 1.0)
        warm = peak * step / max(warmup_steps, 1)
        decay = peak * jnp.sqrt(warmup_steps / step)
        return jnp.where(step < warmup_steps, warm, decay)

    return sched
