"""SGD with momentum (reference/baseline optimizer)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.adamw import _lr_at
from repro.optim.base import Optimizer


def sgd(lr=1e-2, *, momentum: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }

    def update(grads, state, params):
        del params
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)

        def upd(g, mu):
            g32 = g.astype(jnp.float32)
            mu_new = momentum * mu + g32
            d = g32 + momentum * mu_new if nesterov else mu_new
            return -lr_t * d, mu_new

        g_flat, treedef = jax.tree.flatten(grads)
        mu_flat = treedef.flatten_up_to(state["mu"])
        out = [upd(g, mu) for g, mu in zip(g_flat, mu_flat)]
        deltas = treedef.unflatten([o[0] for o in out])
        mu_new = treedef.unflatten([o[1] for o in out])
        return deltas, {"step": step, "mu": mu_new}

    return Optimizer(init=init, update=update)
