from repro.optim.adamw import adamw
from repro.optim.base import Optimizer, apply_updates, cast_tree, global_norm
from repro.optim.cholesky_precond import cholesky_precond
from repro.optim.clip import all_finite, clip_by_global_norm
from repro.optim.schedule import constant, inverse_sqrt, warmup_cosine
from repro.optim.sgd import sgd

__all__ = [
    "Optimizer",
    "apply_updates",
    "global_norm",
    "cast_tree",
    "adamw",
    "sgd",
    "cholesky_precond",
    "clip_by_global_norm",
    "all_finite",
    "constant",
    "inverse_sqrt",
    "warmup_cosine",
]


def get_optimizer(name: str, lr, **kw) -> Optimizer:
    """Config-driven optimizer factory."""
    if name == "adamw":
        return adamw(lr, **kw)
    if name == "sgd":
        return sgd(lr, **kw)
    if name == "cholesky_precond":
        return cholesky_precond(lr, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
