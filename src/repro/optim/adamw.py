"""AdamW with fp32 state over possibly-lower-precision params (baseline optimizer)."""
from __future__ import annotations

from typing import Callable, Union

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer

ScheduleOrFloat = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


def _lr_at(lr: ScheduleOrFloat, step):
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


def adamw(
    lr: ScheduleOrFloat = 1e-3,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    state_dtype=jnp.float32,
) -> Optimizer:
    """AdamW. ``state_dtype`` may be bf16 for memory-squeezed mega models."""

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            mhat = m_new / bc1
            vhat = v_new / bc2
            delta = -lr_t * (
                mhat / (jnp.sqrt(vhat) + eps)
                + weight_decay * p.astype(jnp.float32)
            )
            return delta, m_new.astype(state_dtype), v_new.astype(state_dtype)

        g_flat, treedef = jax.tree.flatten(grads)
        m_flat = treedef.flatten_up_to(state["m"])
        v_flat = treedef.flatten_up_to(state["v"])
        p_flat = treedef.flatten_up_to(params)
        out = [upd(g, m, v, p) for g, m, v, p in zip(g_flat, m_flat, v_flat, p_flat)]
        deltas = treedef.unflatten([o[0] for o in out])
        m_new = treedef.unflatten([o[1] for o in out])
        v_new = treedef.unflatten([o[2] for o in out])
        return deltas, {"step": step, "m": m_new, "v": v_new}

    return Optimizer(init=init, update=update)
