"""Cholesky-factor utilities: solves, logdet, factor construction.

Everything operates on the *upper* factor convention of the paper
(``A = L^T L``). These are the operations the maintained factor exists to
serve (the optimizer's preconditioned step, posterior solves, etc.).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def chol_factor(A):
    """Upper factor L with A = L^T L (wraps lax cholesky, lower -> upper)."""
    return jnp.linalg.cholesky(A).T


def solve_triangular(L, b, *, trans: bool):
    """Solve ``L^T x = b`` (trans=True) or ``L x = b`` (trans=False)."""
    return jax.scipy.linalg.solve_triangular(L, b, trans=1 if trans else 0, lower=False)


def chol_solve(L, b):
    """Solve ``A x = b`` given the upper factor (two triangular solves)."""
    y = solve_triangular(L, b, trans=True)   # L^T y = b
    return solve_triangular(L, y, trans=False)  # L x = y


def chol_logdet(L):
    """log det A = 2 * sum(log diag L)."""
    return 2.0 * jnp.sum(jnp.log(jnp.diagonal(L)))


def chol_inverse_multiply(L, X):
    """Compute A^{-1} X for a matrix right-hand side."""
    return chol_solve(L, X)


def is_positive_factor(L, *, tol: float = 0.0):
    """True iff the factor has a strictly positive diagonal (valid factor)."""
    return jnp.all(jnp.diagonal(L) > tol)


def downdate_feasible(L, V):
    """Check that ``A - V V^T`` stays PD: ||L^{-T} v||^2 < 1 per deflated col.

    Exact criterion for rank 1; for rank k we apply the standard sequential
    sufficiency check on the triangular solve of the whole block — conservative
    and cheap (k triangular solves). Used by callers (e.g. the optimizer's
    windowed statistics) to guard downdates.
    """
    if V.ndim == 1:
        V = V[:, None]
    # Solve L^T P = V; downdating succeeds iff I - P^T P is PD.
    Pm = solve_triangular(L, V, trans=True)
    G = jnp.eye(V.shape[1], dtype=L.dtype) - Pm.T @ Pm
    # PD check via eigenvalues of the small k x k Gram complement.
    return jnp.all(jnp.linalg.eigvalsh(G) > 0)
