"""Storage-structure layer: the factor's layout as a first-class object
(DESIGN.md §12).

The source paper's headline scaling claim is O(n) GPU memory for the
modification path, yet a dense ``(n, n)`` factor caps everything at O(n²)
bytes before a single update runs. This module splits *what the factor is*
(an upper Cholesky factor of an SPD matrix) from *how it is laid out*:

* ``DenseStorage``        — the ``(n, n)`` / ``(B, n, n)`` array layout every
  existing backend consumes; behaviour-identical to the pre-refactor
  ``CholFactor`` code paths (same ops, same vmap structure, bit-for-bit).
* ``BlockTriDiagStorage`` — the factor of a block-tridiagonal SPD matrix
  (Kalman smoothing / MPC normal equations, Schwan et al. in PAPERS.md):
  an upper block-BIdiagonal factor stored as ``(nb, b, b)`` diagonal blocks
  plus ``(nb-1, b, b)`` coupling blocks — O(n·b) memory for n = nb·b.

``FactorStorage`` is the protocol ``CholFactor`` delegates every
layout-specific operation to: diagonal extraction, triangular solves,
``logdet``, validity, densification, scaling, dtype casts, and (via the
pytree registration) the checkpoint leaf layout. The factor object itself
is polymorphic over structure; its public API does not change.

Math convention (same as the rest of the repo): upper factor, ``A = U^T U``.
For a block-tridiagonal ``A`` with diagonal blocks ``Ad[j]`` and
super-diagonal blocks ``Ao[j] = A[j·b:(j+1)·b, (j+1)·b:(j+2)·b]`` (the
sub-diagonal blocks are their transposes), the factor is block bidiagonal:

    U[j, j]   = diag[j]   (upper triangular, positive diagonal)
    U[j, j+1] = off[j]    (dense b×b)

with the chain recurrence (Schwan et al., transposed to the upper
convention)::

    S_0     = Ad[0]
    diag[j] = chol_upper(S_j)
    off[j]  = diag[j]^{-T} Ao[j]
    S_{j+1} = Ad[j+1] - off[j]^T off[j]

Rank-k modification support: a modification ``A ± V V^T`` stays
block-tridiagonal — and the factor stays block-bidiagonal, i.e.
representable in this storage — iff every COLUMN of ``V`` is supported
inside one adjacent block-row pair ``{j, j+1}``. That is exactly the
update traffic of the structured workloads (a Kalman measurement touches
one state block; a dynamics term touches one adjacent pair). See
``assert_blocklocal`` for the host-side validator and
``repro.kernels.blocktridiag`` for the dependency argument.
"""
from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core import solve as _solve
from repro.core.precision import Precision


def _mT(x):
    """Matrix transpose over the trailing two axes (batched-safe)."""
    return jnp.swapaxes(x, -1, -2)


@runtime_checkable
class FactorStorage(Protocol):
    """What ``CholFactor`` requires of a storage layout.

    Implementations are frozen dataclasses registered as pytrees (their
    leaves ARE the checkpoint leaf layout) and carry:

    * ``structure`` — the registry key backends declare support for
      (``'dense'``, ``'blocktridiag'``, ...);
    * ``n`` / ``batched`` / ``dtype`` — metadata views;
    * ``diagonal / solve / solve_triangular / logdet / is_valid /
      downdate_feasible / matrix / to_dense / astype`` — the
      layout-specific operations;
    * ``raw`` — the value ``CholFactor.data`` holds (the bare array for
      dense — keeping the dense pytree/checkpoint layout bit-identical to
      the pre-refactor factor — and the storage object itself otherwise).
    """

    structure: str

    @property
    def n(self) -> int: ...

    @property
    def batched(self) -> bool: ...

    @property
    def dtype(self): ...

    @property
    def raw(self): ...

    def diagonal(self): ...

    def solve(self, b): ...

    def solve_triangular(self, b, *, trans: bool): ...

    def logdet(self): ...

    def is_valid(self, *, tol: float = 0.0): ...

    def downdate_feasible(self, V): ...

    def matrix(self): ...

    def to_dense(self): ...

    def astype(self, dtype): ...


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DenseStorage:
    """The ``(n, n)`` / ``(B, n, n)`` array layout (the pre-refactor one).

    Every method is the literal operation ``CholFactor`` used to inline —
    same solve calls, same vmap-over-leading-axis batching — so dense
    behaviour through the delegation is bit-identical.
    """

    data: jax.Array

    structure = "dense"

    def tree_flatten(self):
        return (self.data,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    # -- metadata views -----------------------------------------------------
    @property
    def n(self) -> int:
        return self.data.shape[-1]

    @property
    def batched(self) -> bool:
        return self.data.ndim == 3

    @property
    def batch(self):
        """Fleet size when batched, else None."""
        return self.data.shape[0] if self.data.ndim == 3 else None

    @property
    def dtype(self):
        return self.data.dtype

    @property
    def raw(self):
        # CholFactor.data stays the bare array: the dense pytree leaf /
        # checkpoint layout predates the storage layer and must not change.
        return self.data

    # -- layout-specific operations -----------------------------------------
    def _percore(self, fn, *args):
        if self.batched:
            return jax.vmap(fn)(self.data, *args)
        return fn(self.data, *args)

    def diagonal(self):
        return jnp.diagonal(self.data, axis1=-2, axis2=-1)

    def solve(self, b):
        return self._percore(_solve.chol_solve, b)

    def solve_triangular(self, b, *, trans: bool):
        if self.batched:
            return jax.vmap(
                lambda L, rhs: _solve.solve_triangular(L, rhs, trans=trans)
            )(self.data, b)
        return _solve.solve_triangular(self.data, b, trans=trans)

    def logdet(self):
        return self._percore(_solve.chol_logdet)

    def is_valid(self, *, tol: float = 0.0):
        return self._percore(lambda L: _solve.is_positive_factor(L, tol=tol))

    def downdate_feasible(self, V):
        return self._percore(_solve.downdate_feasible, V)

    def matrix(self):
        return _mT(self.data) @ self.data

    def to_dense(self):
        return self.data

    def blocks_like(self, dense):
        # Tangent re-entry (autodiff.diffable_update_structured): dense is
        # already this storage's layout.
        return DenseStorage(dense.astype(self.dtype))

    def astype(self, dtype):
        return DenseStorage(self.data.astype(dtype))

    def describe(self) -> str:
        return "x".join(str(s) for s in self.data.shape)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class BlockTriDiagStorage:
    """Upper block-bidiagonal factor of a block-tridiagonal SPD matrix.

    Attributes:
      diag: ``(nb, b, b)`` upper-triangular diagonal blocks ``U[j, j]``,
        or ``(B, nb, b, b)`` for a fleet of B factors.
      off:  ``(nb-1, b, b)`` coupling blocks ``U[j, j+1]`` (the transposes
        of the lower factor's sub-diagonal blocks), or ``(B, nb-1, b, b)``.

    O(n·b) memory for ``n = nb·b`` — the layout for factors whose dense
    ``(n, n)`` form would not fit. Batched (4-D leaves) storage is a fleet
    of factors over one shared chain layout: every per-factor operation
    vmaps over the leading axis, mirroring ``DenseStorage``'s ``(B, n, n)``
    convention so ``FactorStore`` can hold structured members.
    """

    diag: jax.Array
    off: jax.Array

    structure = "blocktridiag"

    def __post_init__(self):
        d, o = jnp.shape(self.diag), jnp.shape(self.off)
        if len(d) not in (3, 4) or d[-1] != d[-2]:
            raise ValueError(f"diag must be (nb, b, b) or (B, nb, b, b), "
                             f"got {d}")
        if (len(o) != len(d) or o[-2:] != d[-2:] or o[-3] != d[-3] - 1
                or o[:-3] != d[:-3]):
            raise ValueError(
                f"off must be (..., nb-1, b, b) matching diag {d}, got {o}")

    def tree_flatten(self):
        return (self.diag, self.off), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        diag, off = children
        # Bypass validation: transient pytree states (tracers in vjp/scan
        # internals, restore placeholders) may carry object() leaves.
        obj = object.__new__(cls)
        object.__setattr__(obj, "diag", diag)
        object.__setattr__(obj, "off", off)
        return obj

    # -- metadata views -----------------------------------------------------
    @property
    def nblocks(self) -> int:
        return self.diag.shape[-3]

    @property
    def block(self) -> int:
        return self.diag.shape[-1]

    @property
    def n(self) -> int:
        return self.nblocks * self.block

    @property
    def batched(self) -> bool:
        return self.diag.ndim == 4

    @property
    def batch(self):
        """Fleet size when batched, else None."""
        return self.diag.shape[0] if self.batched else None

    @property
    def dtype(self):
        return self.diag.dtype

    @property
    def raw(self):
        return self

    def _per(self, fn, *args):
        """vmap ``fn(unbatched_storage, *args)`` over the fleet axis."""
        if self.batched:
            return jax.vmap(
                lambda d, o, *a: fn(BlockTriDiagStorage(d, o), *a)
            )(self.diag, self.off, *args)
        return fn(self, *args)

    # -- constructors -------------------------------------------------------
    @classmethod
    def from_matrix_blocks(cls, Ad, Ao) -> "BlockTriDiagStorage":
        """Factor block-tridiagonal SPD blocks (Schwan et al. chain).

        ``Ad``: (nb, b, b) diagonal blocks; ``Ao``: (nb-1, b, b)
        super-diagonal blocks ``A[j, j+1]``. O(nb·b³) work, O(n·b) memory —
        the structured analogue of ``CholFactor.from_matrix``.
        """
        Ad, Ao = jnp.asarray(Ad), jnp.asarray(Ao)

        def step(S, x):
            ao, ad_next = x
            U = _mT(jnp.linalg.cholesky(S))
            off = jax.scipy.linalg.solve_triangular(U, ao, trans=1,
                                                    lower=False)
            return ad_next - _mT(off) @ off, (U, off)

        S_last, (diag_head, off) = jax.lax.scan(step, Ad[0], (Ao, Ad[1:]))
        U_last = _mT(jnp.linalg.cholesky(S_last))
        return cls(jnp.concatenate([diag_head, U_last[None]], axis=0), off)

    @classmethod
    def from_dense(cls, L, block: int) -> "BlockTriDiagStorage":
        """Slice an (n, n) upper block-bidiagonal factor into blocks.

        Entries outside the two block diagonals are DROPPED (callers assert
        they are zero where that matters — see the conformance tests).
        """
        n = L.shape[-1]
        if n % block:
            raise ValueError(f"block {block} does not divide n={n}")
        nb = n // block
        diag = jnp.stack([L[j * block:(j + 1) * block,
                            j * block:(j + 1) * block] for j in range(nb)])
        if nb > 1:
            off = jnp.stack([L[j * block:(j + 1) * block,
                               (j + 1) * block:(j + 2) * block]
                             for j in range(nb - 1)])
        else:
            off = jnp.zeros((0, block, block), L.dtype)
        return cls(diag, off)

    @classmethod
    def identity(cls, nb: int, block: int, *, scale: float = 1.0,
                 dtype=jnp.float32, batch=None) -> "BlockTriDiagStorage":
        """Factor of ``scale * I`` in block form (the warm start). With
        ``batch=B`` the fleet variant: B identical members, 4-D leaves."""
        eye = jnp.sqrt(jnp.asarray(scale, dtype)) * jnp.eye(block, dtype=dtype)
        dshape = (nb, block, block)
        oshape = (max(nb - 1, 0), block, block)
        if batch is not None:
            dshape, oshape = (batch,) + dshape, (batch,) + oshape
        return cls(jnp.broadcast_to(eye, dshape), jnp.zeros(oshape, dtype))

    def blocks_like(self, dense) -> "BlockTriDiagStorage":
        """Extract this storage's block pattern from a dense (n, n) matrix,
        cast to this storage's leaf dtypes (the autodiff tangent re-entry
        point — see ``repro.core.autodiff.diffable_update_structured``)."""
        out = BlockTriDiagStorage.from_dense(dense, self.block)
        return BlockTriDiagStorage(out.diag.astype(self.diag.dtype),
                                   out.off.astype(self.off.dtype))

    # -- densification (diagnostics / tests / tangent lift only) ------------
    def to_dense(self):
        """The (n, n) / (B, n, n) upper factor — O(n²) memory, diagnostics
        only; the modification path never calls this (asserted via jaxpr
        inspection in tests/test_structure.py)."""
        if self.batched:
            return self._per(lambda s: s.to_dense())
        b, nb = self.block, self.nblocks
        out = jnp.zeros((self.n, self.n), self.dtype)
        for j in range(nb):
            out = jax.lax.dynamic_update_slice(out, self.diag[j],
                                               (j * b, j * b))
        for j in range(nb - 1):
            out = jax.lax.dynamic_update_slice(out, self.off[j],
                                               (j * b, (j + 1) * b))
        return out

    def matrix(self):
        """Materialise ``A = U^T U`` (O(n²) — diagnostics only)."""
        L = self.to_dense()
        return _mT(L) @ L

    def matrix_blocks(self):
        """``(Ad, Ao)`` of ``A = U^T U`` in block form — O(n·b), the
        structured counterpart of ``matrix()``."""
        ad = _mT(self.diag) @ self.diag
        if self.nblocks > 1:
            ad = ad.at[..., 1:, :, :].add(_mT(self.off) @ self.off)
        ao = _mT(self.diag[..., :-1, :, :]) @ self.off
        return ad, ao

    # -- layout-specific operations -----------------------------------------
    def diagonal(self):
        d = jnp.diagonal(self.diag, axis1=-2, axis2=-1)
        return d.reshape(d.shape[:-2] + (-1,))

    def _blocks_of(self, rhs):
        """(n, ...) -> (nb, b, ...) block view of a right-hand side."""
        if rhs.shape[0] != self.n:
            raise ValueError(
                f"rhs leading dim {rhs.shape[0]} != n={self.n}")
        return rhs.reshape((self.nblocks, self.block) + rhs.shape[1:])

    def solve_triangular(self, b, *, trans: bool):
        """``U^T x = b`` (trans) or ``U x = b`` by block substitution.

        Forward (trans): ``y_j = U_jj^{-T} (b_j - off_{j-1}^T y_{j-1})``.
        Backward:        ``x_j = U_jj^{-1} (y_j - off_j x_{j+1})``.
        One lax.scan over the block chain either way — O(nb·b²·m) work,
        never a dense (n, n) operand.
        """
        if self.batched:
            return self._per(
                lambda s, rhs: s.solve_triangular(rhs, trans=trans), b)
        b = jnp.asarray(b)
        bb = self._blocks_of(b)
        st = jax.scipy.linalg.solve_triangular
        if trans:
            y0 = st(self.diag[0], bb[0], trans=1, lower=False)

            def fwd(y_prev, x):
                U, R, rhs = x
                y = st(U, rhs - _mT(R) @ y_prev, trans=1, lower=False)
                return y, y

            _, tail = jax.lax.scan(fwd, y0, (self.diag[1:], self.off, bb[1:]))
            out = jnp.concatenate([y0[None], tail], axis=0)
        else:
            xl = st(self.diag[-1], bb[-1], trans=0, lower=False)

            def bwd(x_next, x):
                U, R, rhs = x
                xj = st(U, rhs - R @ x_next, trans=0, lower=False)
                return xj, xj

            _, head = jax.lax.scan(bwd, xl,
                                   (self.diag[:-1], self.off, bb[:-1]),
                                   reverse=True)
            out = jnp.concatenate([head, xl[None]], axis=0)
        return out.reshape(b.shape)

    def solve(self, b):
        y = self.solve_triangular(b, trans=True)
        return self.solve_triangular(y, trans=False)

    def logdet(self):
        return 2.0 * jnp.sum(jnp.log(self.diagonal()), axis=-1)

    def is_valid(self, *, tol: float = 0.0):
        return jnp.all(self.diagonal() > tol, axis=-1)

    def downdate_feasible(self, V):
        """Same criterion as the dense path (``I - P^T P`` PD for
        ``U^T P = V``) — the forward substitution keeps it O(n·b·k).
        Batched storage takes (B, n, k) V and returns (B,) verdicts."""
        if self.batched:
            return self._per(lambda s, v: s.downdate_feasible(v), V)
        if V.ndim == 1:
            V = V[:, None]
        P = self.solve_triangular(V, trans=True)
        G = jnp.eye(V.shape[1], dtype=self.dtype) - P.T @ P
        return jnp.all(jnp.linalg.eigvalsh(G) > 0)

    def astype(self, dtype):
        return BlockTriDiagStorage(self.diag.astype(dtype),
                                   self.off.astype(dtype))

    def describe(self) -> str:
        if self.batched:
            return f"blocktridiag[{self.batch}x{self.nblocks}x{self.block}]"
        return f"blocktridiag[{self.nblocks}x{self.block}]"


#: Storage classes the layer knows about; ``as_storage`` wraps raw arrays
#: in DenseStorage and passes these through.
STORAGE_CLASSES = (DenseStorage, BlockTriDiagStorage)


def is_factor_storage(x) -> bool:
    """True for structured storage objects (raw arrays are dense data)."""
    return isinstance(x, STORAGE_CLASSES)


def as_storage(data) -> FactorStorage:
    """The delegation view of a ``CholFactor.data`` value."""
    if is_factor_storage(data):
        return data
    return DenseStorage(data)


def assert_blocklocal(V, block: int):
    """Host-side validator of the structured modification contract.

    Each column of ``V`` must be supported inside one adjacent block-row
    pair ``{j, j+1}`` for ``A ± V V^T`` to stay block-tridiagonal (anything
    wider generates fill-in the storage cannot represent). Traced values
    cannot be checked — call this from eager/test/ingest code, not inside
    jit.
    """
    import numpy as np

    V = np.asarray(V)
    if V.ndim == 1:
        V = V[:, None]
    for m in range(V.shape[1]):
        nz = np.nonzero(V[:, m])[0]
        if nz.size == 0:
            continue
        first, last = int(nz[0]) // block, int(nz[-1]) // block
        if last - first > 1:
            raise ValueError(
                f"column {m} of V spans block rows {first}..{last}; the "
                "block-tridiagonal modification contract allows one "
                "adjacent pair (A ± v v^T would leave the storage class)")


def anchor_block(v, block: int):
    """Anchor block-row of a block-local rank-1 row: the FIRST block row
    its support touches (a row supported on pair {j, j+1} anchors at j).

    Validates the block-local contract on the way (raises the same
    ``ValueError`` as ``assert_blocklocal``); returns ``None`` for an
    all-zero row, which is block-local trivially and anchors nowhere.
    Host-side only — the coalescer keys structured rows by this value at
    ``push()`` time so a contract violation fails at ingest, not inside
    the kernel.
    """
    import numpy as np

    v = np.asarray(v).reshape(-1)
    nz = np.nonzero(v)[0]
    if nz.size == 0:
        return None
    first, last = int(nz[0]) // block, int(nz[-1]) // block
    if last - first > 1:
        raise ValueError(
            f"column 0 of V spans block rows {first}..{last}; the "
            "block-tridiagonal modification contract allows one "
            "adjacent pair (A ± v v^T would leave the storage class)")
    return first


def chol_update_blocktridiag_ref(S, V, *, sigma: int = 1, precision=None,
                                 **_ignored):
    """Pure-jnp block-chain rank-k up/down-date — the lax.scan twin of the
    Pallas kernel (``repro.kernels.blocktridiag``), and the fast CPU path.

    Walks the block chain exactly like the dense blocked driver walks
    panels: the diagonal recurrence on block j annihilates the ``V^T`` slab
    of block j and emits the panel transform ``T``; the apply transforms
    the single trailing tile the structure has — ``off[j]`` — together with
    the next ``V^T`` slab, which carries the cascade to block j+1. All
    other trailing tiles are zero and all other slabs belong to columns
    whose rotations at this block are identities (the block-local support
    contract), so skipping them is exact, not approximate.

    O(k·b²·nb) work, O(n·(b+k)) memory; never materialises (n, n).
    """
    from repro.core import blocked

    if sigma not in (1, -1):
        raise ValueError(f"sigma must be +1 or -1, got {sigma}")
    precision = Precision.parse(precision)
    if precision is not None:
        S = precision.cast_storage(S)
        V = precision.cast_storage(V)
    up = (lambda x: x) if precision is None else precision.up
    if V.ndim == 1:
        V = V[:, None]
    nb, b = S.nblocks, S.block
    k = V.shape[1]
    store = S.dtype
    # (nb, k, b) V^T slabs + a zero tail slab / zero tail off-block so the
    # last chain step is a regular (zero-GEMM) apply.
    slabs = jnp.swapaxes(V.T.reshape(k, nb, b), 0, 1)
    slabs_next = jnp.concatenate(
        [slabs[1:], jnp.zeros((1, k, b), slabs.dtype)], axis=0)
    offp = jnp.concatenate(
        [S.off, jnp.zeros((1, b, b), S.off.dtype)], axis=0)

    def step(slab, xs):
        D, R, nxt = xs
        D_new, _c, _s, T = blocked.panel_diag(up(D), up(slab), sigma,
                                              with_transform=True)
        R_new, nxt_new = blocked.panel_apply_gemm(up(R), up(nxt), T)
        return nxt_new.astype(store), (D_new.astype(store),
                                       R_new.astype(store))

    _, (diag_new, off_new) = jax.lax.scan(step, slabs[0],
                                          (S.diag, offp, slabs_next))
    return BlockTriDiagStorage(diag_new, off_new[:nb - 1])
