"""``CholFactor``: the maintained Cholesky factor as a stateful pytree.

The paper's whole point is that a factor absorbs rank-k modifications
without refactorization — i.e. it is a *long-lived production object*, not
the return value of a one-shot routine. This module gives that object a
type: the upper factor plus its execution metadata (panel size, backend
name, dtype policy, interpret flag), with methods for every operation the
factor exists to serve::

    f = CholFactor.from_matrix(A, backend="auto")
    f = f.update(V)                  # A + V V^T, no refactorization
    f = f.downdate(V)                # A - V V^T, ditto
    x = f.solve(b)                   # two triangular solves
    ld = f.logdet()                  # 2 sum log diag
    ok = f.downdate_feasible(V)      # PD guard before a risky downdate

``CholFactor`` is a registered pytree: it jits, vmaps, scans, and lives
inside optimizer state (``repro.optim.cholesky_precond`` maintains one per
parameter). The array leaf is ``data``; everything else is static aux, so a
factor with a different backend is a different jaxpr — exactly the caching
behaviour you want.

Batching: ``data`` may be ``(B, n, n)`` — a fleet of per-user factors. All
methods vmap over the leading axis automatically, and updates still cost
one device launch on the fused backend (vmap folds B into the kernel grid).
Batching composes with sharding (DESIGN.md §10): a batched factor bound to
a mesh (``backend='sharded'``, ``mesh=``, ``axis=``) holds a fleet whose
members are EACH column-sharded ``P(None, None, axis)`` — factors too big
for one device — and mutations still cost ONE kernel launch per shard for
the whole fleet (the batch folds into the per-shard grid).

Every mutation dispatches through the backend registry
(``repro.core.backends``) wrapped in the Murray derivative rules
(``repro.core.autodiff``), so ``jax.grad`` through ``update``/``downdate``
works on every backend, including the Pallas kernels.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core import api, backends
from repro.obs import metrics as obs_metrics
from repro.core import structure as _structure
from repro.core.precision import Precision

Axis = Union[str, tuple]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CholFactor:
    """Upper Cholesky factor (``A = L^T L``) + execution metadata.

    Attributes:
      data: (n, n) — or (B, n, n) batched — upper-triangular factor(s), OR
        a structured ``FactorStorage`` (e.g. ``BlockTriDiagStorage`` —
        ``CholFactor.from_blocktridiag``). Layout-specific operations are
        delegated to the storage layer (``repro.core.structure``,
        DESIGN.md §12); for dense data the delegate inlines the exact code
        this class used to carry, so dense behaviour is bit-identical and
        the pytree leaf stays the bare array.
      panel: row-panel size for the blocked/kernel backends.
      backend: registry name or 'auto' (resolved per call by heuristics).
      interpret: force Pallas interpret mode (None = auto-detect).
      precision: storage/accum dtype policy (``Precision``, a preset string
        like 'bf16', or None = compute and store in the factor's own dtype).
        Replaces the old scalar ``compute_dtype`` hook: 'bf16' stores L-tiles
        and the running V^T in bfloat16 while the diagonal recurrence,
        rotation state and GEMM accumulation stay fp32 (DESIGN.md §8).
      mesh, axis: mesh binding for the 'sharded' backend (None otherwise).
        Valid for both single ``(n, n)`` and batched ``(B, n, n)`` data —
        the batched-sharded composition routes through the fleet-native
        distributed driver.
      lowering: fused-kernel lowering for the 'fused'/'sharded' backends —
        'mosaic', 'portable', or None/'auto' (resolve per device kind,
        DESIGN.md §5). Ignored by the jnp backends.
    """

    data: jax.Array
    panel: int = 256
    backend: str = "auto"
    interpret: Optional[bool] = None
    precision: Optional[Precision] = None
    mesh: Optional[object] = None
    axis: Axis = "model"
    lowering: Optional[str] = None

    def __post_init__(self):
        # Canonicalise string/dtype specs once, so the static aux is a
        # hashable Precision (or None) and equal policies compare equal.
        object.__setattr__(self, "precision", Precision.parse(self.precision))

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        aux = (self.panel, self.backend, self.interpret, self.precision,
               self.mesh, self.axis, self.lowering)
        return (self.data,), aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        (data,) = children
        return cls(data, *aux)

    # -- constructors -------------------------------------------------------
    @classmethod
    def from_matrix(cls, A, **meta) -> "CholFactor":
        """Factor an SPD matrix (O(n^3), once) into a maintained factor."""
        L = jnp.linalg.cholesky(A)
        return cls(jnp.swapaxes(L, -1, -2), **meta)

    @classmethod
    def from_factor(cls, L, **meta) -> "CholFactor":
        """Wrap an existing upper factor (no validation, no copy)."""
        if _structure.is_factor_storage(L):
            return cls(L, **meta)
        return cls(jnp.asarray(L), **meta)

    @classmethod
    def from_storage(cls, storage, **meta) -> "CholFactor":
        """Wrap a ``FactorStorage`` (dense storage unwraps to the array)."""
        return cls(storage.raw, **meta)

    @classmethod
    def from_blocktridiag(cls, Ad, Ao, **meta) -> "CholFactor":
        """Factor a block-tridiagonal SPD matrix given as blocks.

        ``Ad``: (nb, b, b) diagonal blocks; ``Ao``: (nb-1, b, b)
        super-diagonal blocks ``A[j, j+1]``. O(nb·b³) work, O(n·b) memory —
        the dense ``(n, n)`` matrix is never formed.
        """
        return cls(_structure.BlockTriDiagStorage.from_matrix_blocks(Ad, Ao),
                   **meta)

    @classmethod
    def identity(cls, n: int, *, scale: float = 1.0, batch: Optional[int] = None,
                 dtype=jnp.float32, **meta) -> "CholFactor":
        """Factor of ``scale * I`` — the canonical warm-start (eps*I stats)."""
        eye = jnp.sqrt(jnp.asarray(scale, dtype)) * jnp.eye(n, dtype=dtype)
        if batch is not None:
            eye = jnp.broadcast_to(eye, (batch, n, n))
        return cls(eye, **meta)

    # -- metadata views -----------------------------------------------------
    @property
    def storage(self) -> "_structure.FactorStorage":
        """The layout delegate (a zero-copy view; dense data gets wrapped)."""
        return _structure.as_storage(self.data)

    @property
    def structure(self) -> str:
        """'dense' or a structured layout name ('blocktridiag', ...)."""
        return getattr(self.data, "structure", "dense")

    @property
    def n(self) -> int:
        return self.storage.n

    @property
    def batched(self) -> bool:
        return self.storage.batched

    @property
    def dtype(self):
        return self.data.dtype

    def with_backend(self, backend: str, **meta) -> "CholFactor":
        """Same factor, different execution metadata (data is shared)."""
        return dataclasses.replace(self, backend=backend, **meta)

    def replace(self, **changes) -> "CholFactor":
        return dataclasses.replace(self, **changes)

    # -- the paper's operations --------------------------------------------
    def _mutate(self, V, sigma: int) -> "CholFactor":
        # Trace-time count, same convention as the kernel launch counters:
        # one per traced modification (cached re-executions are free).
        obs_metrics.counter(
            "repro.core.mutations",
            op="update" if sigma > 0 else "downdate",
            structure=self.structure, backend=self.backend).inc()
        opts = {}
        if self.backend == "sharded":
            if self.mesh is None:
                raise ValueError("sharded backend requires a mesh binding "
                                 "(CholFactor(..., mesh=, axis=))")
            opts = {"mesh": self.mesh, "axis": self.axis}
        if self.lowering is not None and self.backend in (
                "auto", "fused", "sharded"):
            # Only the fused-kernel family understands the opt; 'auto' may
            # resolve to a jnp backend, which ignores extra opts by design.
            opts["lowering"] = self.lowering
        if self.batched:
            new = api.chol_update_batched(
                self.data, V, sigma=sigma, method=self.backend,
                panel=self.panel, interpret=self.interpret,
                precision=self.precision, **opts)
        else:
            new = api.chol_update(
                self.data, V, sigma=sigma, method=self.backend,
                panel=self.panel, interpret=self.interpret,
                precision=self.precision, **opts)
        return dataclasses.replace(self, data=new)

    def update(self, V) -> "CholFactor":
        """Absorb ``+ V V^T`` (rank k) without refactorization."""
        return self._mutate(V, 1)

    def downdate(self, V) -> "CholFactor":
        """Remove ``- V V^T`` (rank k) without refactorization."""
        return self._mutate(V, -1)

    def downdate_guarded(self, V):
        """Feasibility-guarded downdate: ``(factor', ok)``.

        ``factor'`` is the downdated factor where ``A - V V^T`` stays PD and
        the *unchanged* factor where it does not (``ok`` reports which).
        Both branches are computed (jnp.where semantics) — this is the jit-
        and vmap-safe guard for serving-time downdates of untrusted data.

        On the sharded backend the verdict comes from the downdated
        factor's diagonal (already psum-gathered and replicated by the
        chain phase) instead of ``downdate_feasible``'s triangular-solve
        criterion: the solve reads full rows, which a column-sharded
        layout would have to all-gather per guard, and the old
        ``ok[..., None, None]`` masking silently assumed those full rows
        were local. The recurrence leaves a non-positive or non-finite
        diagonal exactly when ``A - V V^T`` exits the PD cone, so the
        diagonal IS the feasibility verdict — at zero extra collectives.
        """
        obs_metrics.counter("repro.core.guard_calls",
                            structure=self.structure,
                            backend=self.backend).inc()
        down = self.downdate(V)
        if self.structure != "dense":
            # Structured storage is a pytree of block arrays; the verdict
            # gates every leaf — scalar for one factor, (B,) broadcast over
            # each leaf's trailing block axes for a fleet.
            ok = self.downdate_feasible(V)

            def pick(d, o):
                mask = ok.reshape(ok.shape + (1,) * (d.ndim - ok.ndim))
                return jnp.where(mask, d, o)

            new = jax.tree.map(pick, down.data, self.data)
            return dataclasses.replace(self, data=new), ok
        if self.backend == "sharded":
            diag = jnp.diagonal(down.data, axis1=-2, axis2=-1)
            ok = jnp.all(jnp.isfinite(diag) & (diag > 0), axis=-1)
        else:
            ok = self.downdate_feasible(V)
        mask = ok[..., None, None] if self.batched else ok
        new = jnp.where(mask, down.data, self.data)
        return dataclasses.replace(self, data=new), ok

    def scale(self, alpha) -> "CholFactor":
        """Factor of ``alpha^2 * A``: exact exponential decay of statistics.

        Only ``|alpha|`` matters (the factor represents ``alpha^2 A``), so
        the magnitude is used: a raw negative multiplier would flip the
        diagonal sign and silently break the positive-diagonal invariant
        that ``is_valid``/``logdet``/``solve`` all rely on.
        """
        if self.structure != "dense":
            # Every block of the factor scales uniformly (U and its
            # coupling blocks alike), same as every dense entry.
            new = jax.tree.map(lambda x: x * jnp.abs(alpha), self.data)
            return dataclasses.replace(self, data=new)
        return dataclasses.replace(self, data=self.data * jnp.abs(alpha))

    # -- consumer operations (the reason the factor is maintained) ----------
    # All layout-specific: delegated to the storage (repro.core.structure).
    # Dense delegation inlines the literal old code paths (same solve calls,
    # same vmap batching) — bit-identical by construction.

    def solve(self, b):
        """Solve ``A x = b`` against the maintained factor."""
        return self.storage.solve(b)

    def solve_triangular(self, b, *, trans: bool):
        """One triangular solve: ``L^T x = b`` (trans) or ``L x = b``."""
        return self.storage.solve_triangular(b, trans=trans)

    def logdet(self):
        """``log det A`` from the maintained diagonal."""
        return self.storage.logdet()

    def downdate_feasible(self, V):
        """True where ``A - V V^T`` stays PD (per batch element)."""
        return self.storage.downdate_feasible(V)

    def is_valid(self, *, tol: float = 0.0):
        """Strictly positive diagonal — the factor invariant."""
        return self.storage.is_valid(tol=tol)

    def diagonal(self):
        """The factor's diagonal (sqrt of A's pivots), any layout."""
        return self.storage.diagonal()

    def matrix(self):
        """Materialise ``A = L^T L`` (O(n^3) — diagnostics only)."""
        return self.storage.matrix()

    def __repr__(self):  # keep aux readable in optimizer-state dumps
        return (f"CholFactor({self.storage.describe()} {self.dtype}, "
                f"panel={self.panel}, backend={self.backend!r})")


def resolve_backend_for(factor: CholFactor) -> str:
    """The concrete backend a factor's next mutation will run on."""
    return backends.resolve(factor.backend, n=factor.n, panel=factor.panel,
                            interpret=factor.interpret,
                            structure=factor.structure)
