"""Multi-device rank-k Cholesky modification (shard_map).

The paper streams O(n)-sized panels of ``L`` between host and GPU because the
factor does not fit device memory. At cluster scale the analogous regime is a
factor too large for one device, column-sharded over a mesh axis. The paper's
CPU/GPU split maps onto a device grid:

* the *diagonal phase* (serial, O(P^2 k)) is replicated on every device from a
  psum-gathered (P+k, P) stacked block — the analogue of the paper's
  host -> device upload of ``(c, s)`` (O(P k) there, O((P+k) P) here; one
  collective per panel);
* the *panel phase* is embarrassingly parallel over column shards, exactly as
  the paper's thread-per-column kernel: each device transforms the rows of its
  own columns.

Three strategies share that decomposition:

* ``fused`` (default) — the distributed fused composition (DESIGN.md §7):
  a jnp *chain phase* runs all diagonal recurrences and V^T evolution
  (one psum per panel, no kernels), then ONE Pallas launch per shard
  (``repro.kernels.sharded``) applies every off-diagonal tile. The key
  fact making the tiles independent: each row-panel of L is read in its
  original state (row-panels are written exactly once, by their own panel
  step), so all sequential coupling lives in the chain-phase outputs
  (``T^(p)``, ``D~^(p)``, and the running ``V^T`` snapshots).
* ``gemm`` / ``paper`` — the per-panel jnp drivers (transform GEMM or the
  paper's element-wise rotation chain) interleaved with the diagonal
  phase in one lax.scan, as in the original mapping (§4).

Finalized columns (global index < panel start) hold zeros in the active rows,
which every strategy maps to zeros, so devices do uniform-shape work with no
load imbalance; the triangular waste is accounted for in the §Perf analysis.

**Batched fleets (DESIGN.md §10).** ``L`` may be a stacked ``(B, n, n)``
fleet whose members are EACH column-sharded over the same mesh axis
(sharding spec ``P(None, None, axis)``): the serving-fleet composition for
per-user factors that outgrow one device. The chain phase vmaps over the
batch — which folds every per-panel psum-gather into ONE collective of a
``(B, P+k, P)`` stacked operand, not B collectives — and the fused panel
phase folds the batch into the grid of the SAME per-shard kernel, so a
whole fleet's rank-k update still costs exactly one Pallas launch per
shard: launches scale with shards (× sign blocks at the stream layer),
never with B.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import blocked
from repro.core.precision import Precision
from repro.runtime.compat import shard_map as _shard_map, shard_map_norep

AxisNames = Union[str, Sequence[str]]

STRATEGIES = ("fused", "gemm", "paper")


def axis_tuple(axis: AxisNames):
    """Canonical tuple form of a mesh-axis binding (str, tuple, or list).

    The one normalization every consumer shares — the sharded driver, the
    fleet placement and step-cache keys in ``repro.stream.store``."""
    return (axis,) if isinstance(axis, str) else tuple(axis)


_axis_tuple = axis_tuple  # internal alias (pre-existing call sites)


def _combined_axis_index(axes, mesh):
    """Linearised device index along possibly-multiple mesh axes."""
    idx = jnp.zeros((), jnp.int32)
    for ax in axes:
        idx = idx * mesh.shape[ax] + jax.lax.axis_index(ax)
    return idx


def chol_update_sharded(
    L,
    V,
    *,
    sigma: int = 1,
    mesh,
    axis: AxisNames = "model",
    panel: int = 256,
    strategy: str = "fused",
    lowering: str = "auto",
    interpret: Optional[bool] = None,
    precision: Optional[Precision] = None,
):
    """Rank-k up/down-date of a column-sharded factor (or stacked fleet).

    Args:
      L: (n, n) upper factor, sharded ``P(None, axis)`` (or reshardable to
        it) — or a stacked fleet ``(B, n, n)``, each member column-sharded
        ``P(None, None, axis)``.
      V: (n, k) modification, replicated — ``(B, n, k)`` for a fleet.
      sigma: +1 / -1.
      mesh: the jax Mesh holding ``axis``.
      axis: mesh axis name (or tuple of names) the columns are sharded over.
      panel: row-panel size; must divide the per-device column count.
      strategy: 'fused' (one Pallas launch per shard, default), 'gemm'
        (per-panel transform GEMM) or 'paper' (element-wise).
      lowering: per-shard kernel lowering for the fused strategy —
        'mosaic', 'portable', or 'auto' (resolve by device kind, see
        ``backends.resolve_lowering``). Ignored by the jnp strategies.
      interpret: Pallas interpret mode for the fused strategy (default:
        auto per the resolved lowering — the portable spec also compiles
        on GPU). An explicit value always wins. Ignored by the jnp
        strategies.
      precision: storage/accum policy (DESIGN.md §8). The shard tiles, the
        running V^T, and the per-panel psum-gathers move in the storage
        dtype (halving collective + HBM bytes under 'bf16'); the gathered
        diagonal blocks are cast to the accumulation dtype BEFORE the chain
        phase, so every replicated recurrence and transform stays fp32.

    Returns:
      The updated factor with the same sharding (storage dtype).
    """
    if sigma not in (1, -1):
        raise ValueError("sigma must be +1 or -1")
    if strategy not in STRATEGIES:
        raise ValueError(f"strategy must be one of {STRATEGIES}, got {strategy!r}")
    precision = Precision.parse(precision)
    if precision is not None:
        L = precision.cast_storage(L)
        V = precision.cast_storage(V)
    accum_dtype = None if precision is None else jnp.dtype(precision.accum)
    axes = _axis_tuple(axis)
    batched = L.ndim == 3
    n = L.shape[-1]
    if batched:
        if V.ndim == 2:
            V = V[:, :, None]
        if V.shape[:2] != (L.shape[0], n):
            raise ValueError(
                f"V must be (B, n, k) matching L {L.shape}, got {V.shape}")
        k = V.shape[-1]
    else:
        k = V.shape[1] if V.ndim == 2 else 1
    n_shards = 1
    for ax in axes:
        n_shards *= mesh.shape[ax]
    if n % n_shards:
        raise ValueError(f"n={n} must divide over {n_shards} column shards")
    w_loc = n // n_shards
    if panel > w_loc or w_loc % panel:
        raise ValueError(
            f"panel={panel} must divide the per-device column count {w_loc}"
        )
    if n % panel:
        raise ValueError(f"n={n} must be a multiple of panel={panel}")
    from repro.core.backends import default_interpret, resolve_lowering

    lowering = resolve_lowering(lowering)
    if interpret is None:
        # Lowering-aware auto-detect (like the fused single-device kernel):
        # the mosaic per-shard spec compiles on TPU only; the portable spec
        # also on GPU. An explicit interpret= argument always wins.
        interpret = default_interpret(lowering=lowering)
    if batched:
        vt = jnp.swapaxes(V, -1, -2)  # (B, k, n)
        col_spec = P(None, None, axes)
    else:
        vt = jnp.reshape(V, (n, k)).T
        col_spec = P(None, axes)
    if strategy == "fused":
        fn = functools.partial(
            _sharded_update_fused, sigma=sigma, axes=axes, mesh=mesh,
            panel=panel, w_loc=w_loc, interpret=bool(interpret),
            accum_dtype=accum_dtype, lowering=lowering,
        )
        wrap = shard_map_norep  # pallas_call has no replication rule
    else:
        fn = functools.partial(
            _sharded_update_perpanel, sigma=sigma, axes=axes, mesh=mesh,
            panel=panel, w_loc=w_loc, strategy=strategy,
            accum_dtype=accum_dtype,
        )
        wrap = _shard_map
    mapped = wrap(
        fn,
        mesh=mesh,
        in_specs=(col_spec, col_spec),
        out_specs=col_spec,
    )
    L = jax.device_put(L, NamedSharding(mesh, col_spec))
    vt = jax.lax.with_sharding_constraint(vt, NamedSharding(mesh, col_spec))
    return mapped(L, vt)


def _gather_diag(L_loc, vt, p, *, panel, w_loc, me, axes):
    """psum-gather the stacked [D_p; V^T_d] block from its owner device."""
    k = vt.shape[0]
    r0 = p * panel
    owner = r0 // w_loc
    loc_r0 = r0 % w_loc
    d_cols = jax.lax.dynamic_slice(L_loc, (r0, loc_r0), (panel, panel))
    vtd = jax.lax.dynamic_slice(vt, (0, loc_r0), (k, panel))
    stacked = jnp.concatenate([d_cols, vtd], axis=0)
    stacked = jnp.where(owner == me, stacked, jnp.zeros_like(stacked))
    stacked = jax.lax.psum(stacked, axes)
    return stacked[:panel], stacked[panel:]


# ---------------------------------------------------------------------------
# Fused composition: chain phase (jnp) + one panel-phase kernel per shard.
# ---------------------------------------------------------------------------


def _chain_phase(L_loc, vt_loc, *, sigma, axes, panel, w_loc, me, gcol,
                 accum_dtype=None):
    """The chain phase for ONE factor's local shard (jnp, no kernels).

    Row-panels of L are never written here, so every slice below reads
    ORIGINAL factor data; the only sequential state is vt. Under
    ``jax.vmap`` (the batched fleet path) the per-panel psum-gather
    becomes a single collective over the stacked ``(B, P+k, P)`` operand —
    one gather per panel for the whole fleet, independent of B.
    """
    n = L_loc.shape[0]
    n_panels = n // panel
    acc_t = accum_dtype or jnp.float32

    def chain_body(vt, p):
        r0 = p * panel
        d_blk, vtd_g = _gather_diag(L_loc, vt, p, panel=panel, w_loc=w_loc,
                                    me=me, axes=axes)
        if accum_dtype is not None:
            # The psum gather moved storage-dtype bytes; the replicated
            # recurrence must NOT run there — upcast before the chain.
            d_blk = d_blk.astype(accum_dtype)
            vtd_g = vtd_g.astype(accum_dtype)
        D_new, _, _, T = blocked.panel_diag(d_blk, vtd_g, sigma,
                                            with_transform=True)
        vt_in = vt  # snapshot entering panel p: the kernel's V^T operand
        R = jax.lax.dynamic_slice(L_loc, (r0, 0), (panel, w_loc))
        vt_new = (
            jnp.dot(T[panel:, :panel], R, preferred_element_type=acc_t)
            + jnp.dot(T[panel:, panel:], vt,
                      preferred_element_type=acc_t)
        ).astype(vt.dtype)
        in_block = (gcol >= r0) & (gcol < r0 + panel)
        vt_new = jnp.where(in_block[None, :], jnp.zeros_like(vt_new), vt_new)
        return vt_new, (T, D_new, vt_in)

    _, stacks = jax.lax.scan(chain_body, vt_loc, jnp.arange(n_panels))
    return stacks  # (T_stack, D_stack, vt_stack)


def _sharded_update_fused(L_loc, vt_loc, *, sigma, axes, mesh, panel, w_loc,
                          interpret, accum_dtype=None, lowering="mosaic"):
    from repro.kernels import sharded as sharded_k

    me = _combined_axis_index(axes, mesh)
    gcol = me * w_loc + jnp.arange(w_loc)
    chain = functools.partial(
        _chain_phase, sigma=sigma, axes=axes, panel=panel, w_loc=w_loc,
        me=me, gcol=gcol, accum_dtype=accum_dtype,
    )
    if L_loc.ndim == 3:
        # Stacked fleet shard: vmap the chain (one psum per panel for the
        # whole batch), then fold B into the grid of the SAME launch.
        T_stack, D_stack, vt_stack = jax.vmap(chain)(L_loc, vt_loc)
    else:
        T_stack, D_stack, vt_stack = chain(L_loc, vt_loc)

    # --- panel phase: the whole update in ONE launch on this shard --------
    return sharded_k.panel_apply_sharded(
        L_loc, T_stack, D_stack, vt_stack,
        tile_off=me * (w_loc // panel), panel=panel, interpret=interpret,
        accum_dtype=accum_dtype, lowering=lowering,
    )


# ---------------------------------------------------------------------------
# Per-panel jnp strategies (the original §4 mapping).
# ---------------------------------------------------------------------------


def _sharded_update_perpanel(L_loc, vt_loc, *, sigma, axes, mesh, panel,
                             w_loc, strategy, accum_dtype=None):
    if L_loc.ndim == 3:
        # Stacked fleet shard: vmap the whole per-panel driver. The psum
        # inside batches into one collective per panel (jnp only — no
        # kernels to fold).
        return jax.vmap(functools.partial(
            _sharded_update_perpanel, sigma=sigma, axes=axes, mesh=mesh,
            panel=panel, w_loc=w_loc, strategy=strategy,
            accum_dtype=accum_dtype))(L_loc, vt_loc)
    n = L_loc.shape[0]
    me = _combined_axis_index(axes, mesh)
    dev_off = me * w_loc
    gcol = dev_off + jnp.arange(w_loc)
    n_panels = n // panel
    store = L_loc.dtype
    up = (lambda x: x) if accum_dtype is None else (
        lambda x: x.astype(accum_dtype))

    def panel_body(carry, p):
        L_loc, vt_loc = carry
        r0 = p * panel
        loc_r0 = r0 % w_loc
        # --- gather the stacked diagonal block to all devices (one psum) ---
        d_blk, vtd_g = _gather_diag(L_loc, vt_loc, p, panel=panel,
                                    w_loc=w_loc, me=me, axes=axes)
        # --- replicated serial diagonal phase (paper CPU role) — the psum
        # moved storage bytes; the recurrence itself runs in accum dtype ---
        d_new, c, s, T = blocked.panel_diag(
            up(d_blk), up(vtd_g), sigma, with_transform=(strategy == "gemm")
        )
        # --- parallel panel phase on local columns (paper GPU role) ---
        R = jax.lax.dynamic_slice(L_loc, (r0, 0), (panel, w_loc))
        if strategy == "gemm":
            R_new, vt_new = blocked.panel_apply_gemm(up(R), up(vt_loc), T)
        else:
            R_new, vt_new = blocked.panel_apply_paper(up(R), up(vt_loc), c, s,
                                                      sigma)
        # --- stitch: inside-block columns take the serial result ---
        in_block = (gcol >= r0) & (gcol < r0 + panel)
        d_pad = jax.lax.dynamic_update_slice(
            jnp.zeros((panel, w_loc), d_new.dtype), d_new, (0, loc_r0)
        )
        R_final = jnp.where(in_block[None, :], d_pad, R_new).astype(store)
        vt_final = jnp.where(
            in_block[None, :], jnp.zeros_like(vt_new), vt_new
        ).astype(store)
        L_loc = jax.lax.dynamic_update_slice(L_loc, R_final, (r0, 0))
        return (L_loc, vt_final), None

    (L_loc, _), _ = jax.lax.scan(
        panel_body, (L_loc, vt_loc), jnp.arange(n_panels)
    )
    return L_loc
