"""Multi-device rank-k Cholesky modification (shard_map).

The paper streams O(n)-sized panels of ``L`` between host and GPU because the
factor does not fit device memory. At cluster scale the analogous regime is a
factor too large for one device, column-sharded over a mesh axis. The paper's
CPU/GPU split maps onto a device grid:

* the *diagonal phase* (serial, O(P^2 k)) is replicated on every device from a
  psum-gathered (P+k, P) stacked block — the analogue of the paper's
  host -> device upload of ``(c, s)`` (O(P k) there, O((P+k) P) here; one
  collective per panel);
* the *panel phase* is embarrassingly parallel over column shards, exactly as
  the paper's thread-per-column kernel: each device transforms the rows of its
  own columns, either element-wise (``strategy='paper'``) or with the
  transform GEMM (``strategy='gemm'``).

Finalized columns (global index < panel start) hold zeros in the active rows,
which both strategies map to zeros, so every device does uniform-shape work
each panel (a ``lax.scan``) with no load imbalance; the triangular waste is
accounted for in the §Perf analysis.
"""
from __future__ import annotations

import functools
from typing import Sequence, Union

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import blocked
from repro.runtime.compat import shard_map as _shard_map

AxisNames = Union[str, Sequence[str]]


def _axis_tuple(axis: AxisNames):
    return (axis,) if isinstance(axis, str) else tuple(axis)


def _combined_axis_index(axes, mesh):
    """Linearised device index along possibly-multiple mesh axes."""
    idx = jnp.zeros((), jnp.int32)
    for ax in axes:
        idx = idx * mesh.shape[ax] + jax.lax.axis_index(ax)
    return idx


def chol_update_sharded(
    L,
    V,
    *,
    sigma: int = 1,
    mesh,
    axis: AxisNames = "model",
    panel: int = 256,
    strategy: str = "gemm",
):
    """Rank-k up/down-date of a column-sharded factor.

    Args:
      L: (n, n) upper factor, sharded ``P(None, axis)`` (or reshardable to it).
      V: (n, k) modification, replicated.
      sigma: +1 / -1.
      mesh: the jax Mesh holding ``axis``.
      axis: mesh axis name (or tuple of names) the columns are sharded over.
      panel: row-panel size; must divide the per-device column count.
      strategy: 'gemm' (transform GEMM, default) or 'paper' (element-wise).

    Returns:
      The updated factor with the same sharding.
    """
    if sigma not in (1, -1):
        raise ValueError("sigma must be +1 or -1")
    axes = _axis_tuple(axis)
    n = L.shape[0]
    k = V.shape[1] if V.ndim == 2 else 1
    n_shards = 1
    for ax in axes:
        n_shards *= mesh.shape[ax]
    if n % n_shards:
        raise ValueError(f"n={n} must divide over {n_shards} column shards")
    w_loc = n // n_shards
    if panel > w_loc or w_loc % panel:
        raise ValueError(
            f"panel={panel} must divide the per-device column count {w_loc}"
        )
    if n % panel:
        raise ValueError(f"n={n} must be a multiple of panel={panel}")
    vt = jnp.reshape(V, (n, k)).T

    col_spec = P(None, axes)
    fn = functools.partial(
        _sharded_update, sigma=sigma, axes=axes, mesh=mesh, panel=panel,
        w_loc=w_loc, strategy=strategy,
    )
    mapped = _shard_map(
        fn,
        mesh=mesh,
        in_specs=(col_spec, col_spec),
        out_specs=col_spec,
    )
    L = jax.device_put(L, NamedSharding(mesh, col_spec))
    vt = jax.lax.with_sharding_constraint(vt, NamedSharding(mesh, col_spec))
    return mapped(L, vt)


def _sharded_update(L_loc, vt_loc, *, sigma, axes, mesh, panel, w_loc, strategy):
    n = L_loc.shape[0]
    k = vt_loc.shape[0]
    me = _combined_axis_index(axes, mesh)
    dev_off = me * w_loc
    gcol = dev_off + jnp.arange(w_loc)
    n_panels = n // panel

    def panel_body(carry, p):
        L_loc, vt_loc = carry
        r0 = p * panel
        owner = r0 // w_loc
        loc_r0 = r0 % w_loc
        # --- gather the stacked diagonal block to all devices (one psum) ---
        d_cols = jax.lax.dynamic_slice(L_loc, (r0, loc_r0), (panel, panel))
        vtd = jax.lax.dynamic_slice(vt_loc, (0, loc_r0), (k, panel))
        stacked = jnp.concatenate([d_cols, vtd], axis=0)
        stacked = jnp.where(owner == me, stacked, jnp.zeros_like(stacked))
        stacked = jax.lax.psum(stacked, axes)
        d_blk, vtd_g = stacked[:panel], stacked[panel:]
        # --- replicated serial diagonal phase (paper CPU role) ---
        d_new, c, s, T = blocked.panel_diag(
            d_blk, vtd_g, sigma, with_transform=(strategy == "gemm")
        )
        # --- parallel panel phase on local columns (paper GPU role) ---
        R = jax.lax.dynamic_slice(L_loc, (r0, 0), (panel, w_loc))
        if strategy == "gemm":
            R_new, vt_new = blocked.panel_apply_gemm(R, vt_loc, T)
        else:
            R_new, vt_new = blocked.panel_apply_paper(R, vt_loc, c, s, sigma)
        # --- stitch: inside-block columns take the serial result ---
        in_block = (gcol >= r0) & (gcol < r0 + panel)
        d_pad = jax.lax.dynamic_update_slice(
            jnp.zeros((panel, w_loc), L_loc.dtype), d_new, (0, loc_r0)
        )
        R_final = jnp.where(in_block[None, :], d_pad, R_new)
        vt_final = jnp.where(in_block[None, :], jnp.zeros_like(vt_new), vt_new)
        L_loc = jax.lax.dynamic_update_slice(L_loc, R_final, (r0, 0))
        return (L_loc, vt_final), None

    (L_loc, _), _ = jax.lax.scan(
        panel_body, (L_loc, vt_loc), jnp.arange(n_panels)
    )
    return L_loc
