"""Pure-jnp oracle for rank-k Cholesky up/down-dating (paper Algorithm 1).

Conventions follow the paper: ``L`` is the *upper* triangular Cholesky factor
with ``A = L.T @ L``; ``V`` has shape ``(n, k)``; ``sigma = +1`` performs an
update (``A + V V^T``), ``sigma = -1`` a downdate (``A - V V^T``).

This module is the trusted reference: it is a direct transcription of the
hyperbolic-rotation serial algorithm (paper ``CholeskyModifyB`` row ordering
with the rank-k inner ``Apply`` batching described in §4.4), with O(k n^2)
work. Every faster path in the repo (blocked, Pallas kernels, distributed)
is tested against it, and it itself is tested against full re-factorization.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _row_rotations(l_ii, v_i, sigma):
    """Paper ``Compute`` applied k times at one row (sequential in m).

    Returns the rotation coefficient vectors ``c, s`` of shape ``(k,)`` and the
    final diagonal element. ``c**2 = 1 + sigma * s**2`` holds per rotation.
    """

    def step(lii, vim):
        w = jnp.sqrt(lii * lii + sigma * vim * vim)
        c = w / lii
        s = vim / lii
        return w, (c, s)

    l_ii_new, (c, s) = jax.lax.scan(step, l_ii, v_i)
    return c, s, l_ii_new


def _apply_rotations_to_row(t, vt, c, s, sigma):
    """Paper ``Apply`` for all k rotations of one row, vectorised over columns.

    ``t``: the current row of L, shape (n,). ``vt``: V^T, shape (k, n).
    Sequential in m (the rotations of one row chain through the row), vector
    over the trailing columns.
    """

    def step(t_m, xs):
        v_m, c_m, s_m = xs
        t_m = (t_m + sigma * s_m * v_m) / c_m
        v_m = c_m * v_m - s_m * t_m
        return t_m, v_m

    t_new, vt_new = jax.lax.scan(step, t, (vt, c, s))
    return t_new, vt_new


@functools.partial(jax.jit, static_argnames=("sigma",))
def chol_update_ref(L, V, *, sigma: int = 1):
    """Rank-k up/down-date of the upper Cholesky factor, O(k n^2).

    Args:
      L: (n, n) upper-triangular with positive diagonal, ``A = L.T @ L``.
      V: (n, k) update matrix (or (n,) for rank 1).
      sigma: +1 update, -1 downdate.

    Returns:
      (n, n) upper-triangular factor of ``A + sigma * V @ V.T``.
    """
    if sigma not in (1, -1):
        raise ValueError(f"sigma must be +1 or -1, got {sigma}")
    squeeze = V.ndim == 1
    if squeeze:
        V = V[:, None]
    n = L.shape[0]
    vt0 = V.T  # (k, n)
    col = jnp.arange(n)

    def row_fn(carry, i):
        L, vt = carry
        l_row = L[i]
        c, s, l_ii = _row_rotations(l_row[i], vt[:, i], sigma)
        t_new, vt_new = _apply_rotations_to_row(l_row, vt, c, s, sigma)
        # Only trailing columns (j > i) are semantically updated; j <= i lanes
        # computed garbage above and are restored, then the diagonal is set to
        # its serially-computed value. v[:, i] is annihilated by construction.
        keep = col > i
        l_row = jnp.where(keep, t_new, l_row).at[i].set(l_ii)
        vt = jnp.where(keep[None, :], vt_new, vt).at[:, i].set(0.0)
        L = L.at[i].set(l_row)
        return (L, vt), None

    (L_new, _), _ = jax.lax.scan(row_fn, (L, vt0), jnp.arange(n))
    return L_new


def chol_update_dense(L, V, *, sigma: int = 1):
    """Ground truth by full re-factorization: chol(L^T L + sigma V V^T).

    O(n^3); used only in tests/benchmarks as the independent oracle the paper
    measures its errors against.
    """
    if V.ndim == 1:
        V = V[:, None]
    A = L.T @ L + sigma * (V @ V.T)
    return jnp.linalg.cholesky(A).T  # lower -> upper


def modify_error(L_new, L_old, V, *, sigma: int = 1):
    """The paper's error metric: ``max_ij |Atilde_ij - (Ltilde^T Ltilde)_ij|``."""
    if V.ndim == 1:
        V = V[:, None]
    A_tilde = L_old.T @ L_old + sigma * (V @ V.T)
    C = L_new.T @ L_new
    return jnp.max(jnp.abs(A_tilde - C))
