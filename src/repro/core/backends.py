"""Backend registry for rank-k Cholesky up/down-dating (DESIGN.md §7).

Every execution path of the modification — the serial oracle, the panelled
jnp drivers, the per-panel Pallas kernels, the single-launch fused kernel,
and the column-sharded multi-device driver — is a registered implementation
of ONE protocol::

    update(L, V, *, sigma, panel, interpret, **opts) -> L_new

``repro.core.api.chol_update`` dispatches through this table instead of an
if/elif ladder, and ``resolve`` replaces hard-coded method strings with a
heuristic over (device kind, problem size, interpret mode), so consumers ask
for *a* backend ("auto") rather than *the* backend.

Registration is eager (the names exist at import time) but the Pallas and
distributed modules are imported lazily inside each backend function, so the
pure-JAX core carries no kernel dependencies until a kernel path runs.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Dict, Optional, Tuple

import jax

from repro.core.precision import Precision
from repro.obs import metrics as obs_metrics

# Device kinds Pallas can lower kernels for: TPU (Mosaic) and GPU (Triton).
# The paper's target hardware is the GPU — 'auto' routing must not treat
# TPU as the only kernel-capable device. The fused kernel has TWO lowerings
# of one kernel body (DESIGN.md §5): the Mosaic spec (scalar-prefetch index
# table + pltpu.VMEM scratch) where it wins, and a portable spec (plain
# pl.GridSpec, chain-walk state in loop carries) that Triton can compile —
# so GPU kinds take the single-launch path too.
PALLAS_DEVICE_KINDS = ("tpu", "gpu", "cuda", "rocm")
MOSAIC_DEVICE_KINDS = ("tpu",)
PORTABLE_DEVICE_KINDS = ("gpu", "cuda", "rocm")

#: Valid ``lowering=`` values for the fused kernel family ('auto' and None
#: both mean "resolve by device kind").
LOWERINGS = ("auto", "mosaic", "portable")

# Environment overrides, used by the CI routing job (test-gpu-routing):
# REPRO_FAKE_DEVICE_KIND makes every routing heuristic see a chosen device
# kind without real hardware; REPRO_FORCE_INTERPRET=1 pins the interpret
# auto-detect to True so kernels selected for that fake kind still execute
# (in interpret mode) on the host actually running the suite. Explicit
# ``interpret=`` arguments are never touched by either.
FAKE_DEVICE_KIND_ENV = "REPRO_FAKE_DEVICE_KIND"
FORCE_INTERPRET_ENV = "REPRO_FORCE_INTERPRET"


def device_kind() -> str:
    """The device kind every routing heuristic keys on (lowercase).

    Reads ``REPRO_FAKE_DEVICE_KIND`` first so a whole test run can exercise
    the GPU routing path from a CPU host, then falls back to the real
    ``jax.default_backend()``.
    """
    fake = os.environ.get(FAKE_DEVICE_KIND_ENV)
    if fake:
        return fake.lower()
    return jax.default_backend().lower()


_current_device_kind = device_kind  # alias: params named device_kind shadow


def resolve_lowering(lowering: Optional[str] = None, *,
                     device_kind: Optional[str] = None) -> str:
    """Map a ``lowering`` request (possibly None/'auto') to a concrete one.

    'mosaic' keeps the PrefetchScalarGridSpec + pltpu.VMEM scratch spec —
    the tuned TPU path (and the interpret-mode default off-GPU). 'portable'
    is the plain-GridSpec spec whose chain-walk state lives in loop carries,
    which Triton can lower — the auto choice on gpu/cuda/rocm kinds.
    """
    if lowering in ("mosaic", "portable"):
        return lowering
    if lowering not in (None, "auto"):
        raise ValueError(
            f"lowering must be one of {LOWERINGS}, got {lowering!r}")
    kind = (device_kind or _current_device_kind()).lower()
    return "portable" if kind in PORTABLE_DEVICE_KINDS else "mosaic"


def default_interpret(*, mosaic_only: bool = False,
                      lowering: Optional[str] = None) -> bool:
    """Interpret-mode auto-detect, shared by every kernel entry point.

    Callers pass this ONLY when no explicit ``interpret=`` argument was
    given — an explicit argument (including ``False``) always wins over
    this heuristic (see tests/test_fused.py's regression).

    ``lowering`` selects the fused-kernel policy: the 'mosaic' lowering
    compiles on TPU only; the 'portable' lowering also compiles on GPU via
    Triton (so GPU kinds no longer hard-force interpret mode for the fused
    kernel). ``mosaic_only=True`` is the legacy spelling of
    ``lowering='mosaic'``. The default covers the per-panel kernels, which
    compile on both TPU and GPU.

    ``REPRO_FORCE_INTERPRET=1`` pins the result to True (the CI fake-GPU
    routing job: routing resolves for 'gpu', execution stays interpretable
    on the CPU host actually running it).
    """
    if os.environ.get(FORCE_INTERPRET_ENV, "") not in ("", "0"):
        return True
    kind = device_kind()
    if lowering is not None:
        mosaic_only = resolve_lowering(lowering, device_kind=kind) == "mosaic"
    kinds = MOSAIC_DEVICE_KINDS if mosaic_only else PALLAS_DEVICE_KINDS
    return kind not in kinds


@dataclasses.dataclass(frozen=True)
class Backend:
    """One registered implementation of the rank-k modification protocol."""

    name: str
    fn: Callable
    kind: str  # 'serial' | 'blocked' | 'pallas' | 'collective'
    description: str
    # Factor storage structures this backend can modify (DESIGN.md §12).
    # Dense backends index into (n, n) rows/panels — handing them a
    # BlockTriDiagStorage cannot work even by accident, so the funnel
    # rejects the pairing up front instead of letting shape errors escape
    # from deep inside a kernel trace.
    structures: Tuple[str, ...] = ("dense",)

    def __call__(self, L, V, *, sigma, panel, interpret, precision=None,
                 **opts):
        precision = Precision.parse(precision)
        if precision is not None:
            # Storage casts happen at the funnel: every backend sees inputs
            # already in the policy's storage dtype, and returns it.
            L = precision.cast_storage(L)
            V = precision.cast_storage(V)
        return self.fn(L, V, sigma=sigma, panel=panel, interpret=interpret,
                       precision=precision, **opts)


_REGISTRY: Dict[str, Backend] = {}


def register(name: str, *, kind: str, description: str,
             structures: Tuple[str, ...] = ("dense",)):
    """Decorator registering ``fn`` as backend ``name``."""

    def deco(fn: Callable) -> Callable:
        if name in _REGISTRY:
            raise ValueError(f"backend {name!r} already registered")
        _REGISTRY[name] = Backend(name, fn, kind, description, structures)
        return fn

    return deco


def get(name: str) -> Backend:
    """Look up a backend; raises ValueError naming the valid set."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"method must be one of {methods()}, got {name!r}"
        ) from None


def names(structure: Optional[str] = None) -> Tuple[str, ...]:
    """Registered backend names, registration order.

    With ``structure=`` given, only the backends valid for that factor
    storage structure ('dense', 'blocktridiag', ...). No argument keeps the
    historical meaning: every registered backend.
    """
    if structure is None:
        return tuple(_REGISTRY)
    return tuple(n for n, b in _REGISTRY.items() if structure in b.structures)


def methods(structure: Optional[str] = None) -> Tuple[str, ...]:
    """Valid ``method=`` strings: every backend plus the 'auto' heuristic.

    ``structure=`` narrows to the methods valid for one storage structure —
    'auto' is always valid (it resolves per structure).
    """
    return names(structure) + ("auto",)


def resolve(
    method: str,
    *,
    n: int,
    panel: int = 256,
    interpret: Optional[bool] = None,
    device_kind: Optional[str] = None,
    structure: str = "dense",
) -> str:
    """Map ``method`` (possibly 'auto') to a concrete backend name.

    An explicit ``method`` must support ``structure`` — a dense-only
    backend asked to modify structured storage raises immediately with the
    valid set for that structure (the error a user can act on, instead of a
    shape mismatch from inside a kernel trace).

    The dense 'auto' heuristic prefers the single-launch fused kernel on
    EVERY Pallas-capable device (or under explicitly requested interpret
    mode): the Mosaic lowering on TPU, the portable lowering on
    gpu/cuda/rocm — the paper's actual target hardware, which used to
    route to the O(n/panel)-launch per-panel GEMM cascade because the
    fused grid spec was Mosaic-only (see ``resolve_lowering``). Otherwise
    the pure-JAX paths: the serial oracle for problems under two panels
    (where panelling buys nothing) and the transform-GEMM driver beyond.

    The 'blocktridiag' structure has one kernel and one pure-jnp twin: the
    block-chain Pallas kernel wherever Pallas can lower it (or under
    interpret mode), the lax.scan reference elsewhere.
    """
    if method != "auto":
        backend = get(method)  # validate the name first
        if structure not in backend.structures:
            raise ValueError(
                f"method {method!r} supports structures "
                f"{backend.structures}, not {structure!r}; valid methods "
                f"for {structure!r}: {methods(structure)}")
        return method
    if device_kind is None:
        device_kind = _current_device_kind()
    device_kind = device_kind.lower()
    if structure == "blocktridiag":
        if device_kind in PALLAS_DEVICE_KINDS or interpret:
            return "blocktridiag"
        return "blocktridiag_ref"
    if device_kind in PALLAS_DEVICE_KINDS or interpret:
        return "fused"
    if n < 2 * panel:
        return "reference"
    return "gemm"


def modeled_bytes_per_update(*, structure: str, n: int, panel: int, k: int,
                             storage_dtype, nblocks: int = 0,
                             block: int = 0) -> int:
    """The paper's bandwidth model for ONE rank-k modification, by layout.

    Mirrors ``repro.kernels.fused.bytes_per_update`` (dense: every
    upper-triangular L-tile read+written once, V^T loaded once) and
    ``repro.kernels.blocktridiag.bytes_per_update`` (structured: diag +
    padded off block stacks read+written, V^T loaded once) WITHOUT
    importing the kernel modules — this funnel must stay free of Pallas
    dependencies on the pure-jnp paths (the module's lazy-import policy).
    The formulas are pinned against the kernel modules' own in
    ``tests/test_obs.py``, so they cannot drift apart silently.
    """
    isize = int(jax.numpy.dtype(storage_dtype).itemsize)
    if structure == "blocktridiag":
        tile_traffic = 2 * (nblocks + nblocks) * block * block * isize
        vt_traffic = k * (nblocks + 1) * block * isize
        return tile_traffic + vt_traffic
    n_tiles = -(-n // panel)
    tiles = n_tiles * (n_tiles + 1) // 2
    l_traffic = 2 * tiles * panel * panel * isize
    vt_traffic = k * (n_tiles * panel) * isize
    return l_traffic + vt_traffic


def dispatch(L, V, *, sigma, method, panel, interpret, precision=None,
             **opts):
    """Resolve + run: the single funnel every consumer's update flows through.

    ``L`` is either a dense (n, n) / (B, n, n) array or a ``FactorStorage``
    (anything carrying a ``structure`` attribute). The heuristic's n is the
    factor ORDER — ``L.shape[-1]`` for dense (``shape[0]`` would read the
    batch count off a (B, n, n) leaf reaching the funnel directly), the
    storage's own ``n`` otherwise.

    Observability (DESIGN.md §13): every dispatch records its resolve
    decision, sign, and the bandwidth model's bytes for the modification
    into ``repro.obs`` — labeled by backend/lowering/structure/dtype/sign,
    the axes the conformance tables slice by. Dispatch runs at TRACE time
    (the funnel sits inside the consumers' jits), so like the kernel
    launch counters these are trace-time counts: one per traced
    modification, not per cached re-execution.
    """
    structure = getattr(L, "structure", "dense")
    n = L.shape[-1] if structure == "dense" else L.n
    name = resolve(method, n=n, panel=panel, interpret=interpret,
                   structure=structure)

    policy = Precision.parse(precision)
    storage_dt = L.dtype if policy is None else policy.storage_for(L.dtype)
    lowering = (resolve_lowering(opts.get("lowering"))
                if name in ("fused", "sharded") else "none")
    try:  # sigma may be a tracer when a consumer jits over it
        sign = "up" if float(sigma) > 0 else "down"
    except Exception:
        sign = "traced"
    labels = dict(backend=name, structure=structure, lowering=lowering,
                  dtype=str(jax.numpy.dtype(storage_dt)), sign=sign)
    obs_metrics.counter("repro.backends.resolve", method=method,
                        **labels).inc()
    batch = L.shape[0] if structure == "dense" and L.ndim == 3 else 1
    obs_metrics.counter("repro.backends.bytes", **labels).inc(
        int(batch) * modeled_bytes_per_update(
            structure=structure, n=n, panel=panel, k=V.shape[-1],
            storage_dtype=storage_dt, nblocks=getattr(L, "nblocks", 0),
            block=getattr(L, "block", 0)))
    return get(name)(L, V, sigma=sigma, panel=panel, interpret=interpret,
                     precision=precision, **opts)


# ---------------------------------------------------------------------------
# Registered implementations. Lazy imports keep the pure-JAX core free of
# kernel/distributed dependencies until those paths actually run.
# ---------------------------------------------------------------------------


@register("reference", kind="serial",
          description="serial hyperbolic sweeps, O(k n^2) (paper Alg. 1)")
def _reference(L, V, *, sigma, panel, interpret, precision=None, **opts):
    del panel, interpret, opts
    from repro.core import ref

    if precision is None:
        return ref.chol_update_ref(L, V, sigma=sigma)
    # The serial oracle has no tile structure: the whole sweep runs in the
    # accumulation dtype, and only the returned factor is storage-typed.
    out = ref.chol_update_ref(precision.up(L), precision.up(V), sigma=sigma)
    return precision.down(out, like=L)


@register("paper", kind="blocked",
          description="panelled, element-wise panel apply (paper §4)")
def _paper(L, V, *, sigma, panel, interpret, precision=None, **opts):
    del interpret, opts
    from repro.core import blocked

    return blocked.chol_update_blocked(L, V, sigma=sigma, panel=panel,
                                       strategy="paper", precision=precision)


@register("gemm", kind="blocked",
          description="panelled, transform-GEMM panel apply (TPU-native)")
def _gemm(L, V, *, sigma, panel, interpret, precision=None, **opts):
    del interpret, opts
    from repro.core import blocked

    return blocked.chol_update_blocked(L, V, sigma=sigma, panel=panel,
                                       strategy="gemm", precision=precision)


@register("pallas", kind="pallas",
          description="per-panel Pallas kernels, element-wise panel apply")
def _pallas(L, V, *, sigma, panel, interpret, precision=None, **opts):
    from repro.kernels import ops as kernel_ops

    return kernel_ops.chol_update_pallas(L, V, sigma=sigma, panel=panel,
                                         strategy="paper",
                                         interpret=interpret,
                                         precision=precision, **opts)


@register("pallas_gemm", kind="pallas",
          description="per-panel Pallas kernels, MXU GEMM panel apply")
def _pallas_gemm(L, V, *, sigma, panel, interpret, precision=None, **opts):
    from repro.kernels import ops as kernel_ops

    return kernel_ops.chol_update_pallas(L, V, sigma=sigma, panel=panel,
                                         strategy="gemm",
                                         interpret=interpret,
                                         precision=precision, **opts)


@register("fused", kind="pallas",
          description="single-launch pipelined Pallas kernel, one body with "
                      "two lowerings: lowering='auto'|'mosaic'|'portable' "
                      "(DESIGN.md §5)")
def _fused(L, V, *, sigma, panel, interpret, precision=None, **opts):
    from repro.kernels import fused as kernel_fused

    return kernel_fused.chol_update_fused(L, V, sigma=sigma, panel=panel,
                                          interpret=interpret,
                                          precision=precision, **opts)


@register("blocktridiag", kind="pallas", structures=("blocktridiag",),
          description="block-chain Pallas kernel for block-bidiagonal "
                      "factors: ONE launch per sign block, O(n*b) bytes "
                      "(DESIGN.md §12)")
def _blocktridiag(L, V, *, sigma, panel, interpret, precision=None, **opts):
    del panel  # the chain's tile size is the storage's block size
    opts.pop("lowering", None)  # single portable lowering; accepted + ignored
    from repro.kernels import blocktridiag as kernel_btd

    return kernel_btd.chol_update_blocktridiag(L, V, sigma=sigma,
                                               interpret=interpret,
                                               precision=precision, **opts)


@register("blocktridiag_ref", kind="blocked", structures=("blocktridiag",),
          description="pure-jnp lax.scan twin of the block-chain kernel "
                      "(panel_diag + transform-GEMM apply per block)")
def _blocktridiag_ref(L, V, *, sigma, panel, interpret, precision=None,
                      **opts):
    del panel, interpret
    opts.pop("lowering", None)
    from repro.core import structure

    return structure.chol_update_blocktridiag_ref(L, V, sigma=sigma,
                                                  precision=precision, **opts)


@register("sharded", kind="collective",
          description="column-sharded multi-device driver composing the "
                      "fused kernel (DESIGN.md §4+§7); requires mesh=")
def _sharded(L, V, *, sigma, panel, interpret, precision=None, mesh=None,
             axis="model", **opts):
    if mesh is None:
        raise ValueError("method='sharded' requires a mesh= argument")
    from repro.core import distributed

    return distributed.chol_update_sharded(L, V, sigma=sigma, mesh=mesh,
                                           axis=axis, panel=panel,
                                           interpret=interpret,
                                           precision=precision, **opts)
