"""Backend registry for rank-k Cholesky up/down-dating (DESIGN.md §7).

Every execution path of the modification — the serial oracle, the panelled
jnp drivers, the per-panel Pallas kernels, the single-launch fused kernel,
and the column-sharded multi-device driver — is a registered implementation
of ONE protocol::

    update(L, V, *, sigma, panel, interpret, **opts) -> L_new

``repro.core.api.chol_update`` dispatches through this table instead of an
if/elif ladder, and ``resolve`` replaces hard-coded method strings with a
heuristic over (device kind, problem size, interpret mode), so consumers ask
for *a* backend ("auto") rather than *the* backend.

Registration is eager (the names exist at import time) but the Pallas and
distributed modules are imported lazily inside each backend function, so the
pure-JAX core carries no kernel dependencies until a kernel path runs.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax

from repro.core.precision import Precision

# Device kinds Pallas can lower kernels for: TPU (Mosaic) and GPU (Triton).
# The paper's target hardware is the GPU — 'auto' routing must not treat
# TPU as the only kernel-capable device. The fused kernel is the exception:
# its PrefetchScalarGridSpec + pltpu.VMEM scratch are Mosaic-only, so on
# GPU the Pallas path is the per-panel GEMM kernel (plain pallas_call +
# BlockSpecs, Triton-lowerable).
PALLAS_DEVICE_KINDS = ("tpu", "gpu", "cuda", "rocm")
MOSAIC_DEVICE_KINDS = ("tpu",)


def default_interpret(*, mosaic_only: bool = False) -> bool:
    """Interpret-mode auto-detect, shared by every kernel entry point.

    ``mosaic_only=True`` is for kernels using TPU-specific Pallas features
    (the fused kernel): compile on TPU, interpret elsewhere. The default
    covers the per-panel kernels, which also compile on GPU via Triton.
    """
    kinds = MOSAIC_DEVICE_KINDS if mosaic_only else PALLAS_DEVICE_KINDS
    return jax.default_backend().lower() not in kinds


@dataclasses.dataclass(frozen=True)
class Backend:
    """One registered implementation of the rank-k modification protocol."""

    name: str
    fn: Callable
    kind: str  # 'serial' | 'blocked' | 'pallas' | 'collective'
    description: str

    def __call__(self, L, V, *, sigma, panel, interpret, precision=None,
                 **opts):
        precision = Precision.parse(precision)
        if precision is not None:
            # Storage casts happen at the funnel: every backend sees inputs
            # already in the policy's storage dtype, and returns it.
            L = precision.cast_storage(L)
            V = precision.cast_storage(V)
        return self.fn(L, V, sigma=sigma, panel=panel, interpret=interpret,
                       precision=precision, **opts)


_REGISTRY: Dict[str, Backend] = {}


def register(name: str, *, kind: str, description: str):
    """Decorator registering ``fn`` as backend ``name``."""

    def deco(fn: Callable) -> Callable:
        if name in _REGISTRY:
            raise ValueError(f"backend {name!r} already registered")
        _REGISTRY[name] = Backend(name, fn, kind, description)
        return fn

    return deco


def get(name: str) -> Backend:
    """Look up a backend; raises ValueError naming the valid set."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"method must be one of {methods()}, got {name!r}"
        ) from None


def names() -> Tuple[str, ...]:
    """Registered backend names, registration order."""
    return tuple(_REGISTRY)


def methods() -> Tuple[str, ...]:
    """Valid ``method=`` strings: every backend plus the 'auto' heuristic."""
    return names() + ("auto",)


def resolve(
    method: str,
    *,
    n: int,
    panel: int = 256,
    interpret: Optional[bool] = None,
    device_kind: Optional[str] = None,
) -> str:
    """Map ``method`` (possibly 'auto') to a concrete backend name.

    The 'auto' heuristic prefers a Pallas kernel whenever a Pallas-capable
    device is present or interpret mode was explicitly requested: the
    single-launch fused kernel on TPU (and under interpret — its
    PrefetchScalarGridSpec/pltpu scratch are Mosaic-only), the per-panel
    GEMM kernel on GPU (Triton lowering; the paper's actual target
    hardware, which previously fell all the way back to the jnp gemm path
    and never launched a kernel). Otherwise the pure-JAX paths: the serial
    oracle for problems under two panels (where panelling buys nothing)
    and the transform-GEMM driver beyond.
    """
    if method != "auto":
        get(method)  # validate
        return method
    if device_kind is None:
        device_kind = jax.default_backend()
    device_kind = device_kind.lower()
    if device_kind in MOSAIC_DEVICE_KINDS or interpret:
        return "fused"
    if device_kind in PALLAS_DEVICE_KINDS:
        return "pallas_gemm"
    if n < 2 * panel:
        return "reference"
    return "gemm"


def dispatch(L, V, *, sigma, method, panel, interpret, precision=None,
             **opts):
    """Resolve + run: the single funnel every consumer's update flows through."""
    name = resolve(method, n=L.shape[0], panel=panel, interpret=interpret)
    return get(name)(L, V, sigma=sigma, panel=panel, interpret=interpret,
                     precision=precision, **opts)


# ---------------------------------------------------------------------------
# Registered implementations. Lazy imports keep the pure-JAX core free of
# kernel/distributed dependencies until those paths actually run.
# ---------------------------------------------------------------------------


@register("reference", kind="serial",
          description="serial hyperbolic sweeps, O(k n^2) (paper Alg. 1)")
def _reference(L, V, *, sigma, panel, interpret, precision=None, **opts):
    del panel, interpret, opts
    from repro.core import ref

    if precision is None:
        return ref.chol_update_ref(L, V, sigma=sigma)
    # The serial oracle has no tile structure: the whole sweep runs in the
    # accumulation dtype, and only the returned factor is storage-typed.
    out = ref.chol_update_ref(precision.up(L), precision.up(V), sigma=sigma)
    return precision.down(out, like=L)


@register("paper", kind="blocked",
          description="panelled, element-wise panel apply (paper §4)")
def _paper(L, V, *, sigma, panel, interpret, precision=None, **opts):
    del interpret, opts
    from repro.core import blocked

    return blocked.chol_update_blocked(L, V, sigma=sigma, panel=panel,
                                       strategy="paper", precision=precision)


@register("gemm", kind="blocked",
          description="panelled, transform-GEMM panel apply (TPU-native)")
def _gemm(L, V, *, sigma, panel, interpret, precision=None, **opts):
    del interpret, opts
    from repro.core import blocked

    return blocked.chol_update_blocked(L, V, sigma=sigma, panel=panel,
                                       strategy="gemm", precision=precision)


@register("pallas", kind="pallas",
          description="per-panel Pallas kernels, element-wise panel apply")
def _pallas(L, V, *, sigma, panel, interpret, precision=None, **opts):
    from repro.kernels import ops as kernel_ops

    return kernel_ops.chol_update_pallas(L, V, sigma=sigma, panel=panel,
                                         strategy="paper",
                                         interpret=interpret,
                                         precision=precision, **opts)


@register("pallas_gemm", kind="pallas",
          description="per-panel Pallas kernels, MXU GEMM panel apply")
def _pallas_gemm(L, V, *, sigma, panel, interpret, precision=None, **opts):
    from repro.kernels import ops as kernel_ops

    return kernel_ops.chol_update_pallas(L, V, sigma=sigma, panel=panel,
                                         strategy="gemm",
                                         interpret=interpret,
                                         precision=precision, **opts)


@register("fused", kind="pallas",
          description="single-launch pipelined Pallas kernel (DESIGN.md §5)")
def _fused(L, V, *, sigma, panel, interpret, precision=None, **opts):
    from repro.kernels import fused as kernel_fused

    return kernel_fused.chol_update_fused(L, V, sigma=sigma, panel=panel,
                                          interpret=interpret,
                                          precision=precision, **opts)


@register("sharded", kind="collective",
          description="column-sharded multi-device driver composing the "
                      "fused kernel (DESIGN.md §4+§7); requires mesh=")
def _sharded(L, V, *, sigma, panel, interpret, precision=None, mesh=None,
             axis="model", **opts):
    if mesh is None:
        raise ValueError("method='sharded' requires a mesh= argument")
    from repro.core import distributed

    return distributed.chol_update_sharded(L, V, sigma=sigma, mesh=mesh,
                                           axis=axis, panel=panel,
                                           interpret=interpret,
                                           precision=precision, **opts)
