"""The paper's primary contribution: rank-k Cholesky up/down-dating.

``ref`` is the trusted serial oracle (paper Algorithm 1), ``blocked`` the
panelled TPU-shaped implementation (paper §4 plus the GEMM adaptation),
``distributed`` the shard_map multi-device version, ``solve`` the consumer
utilities. ``backends`` is the registry every execution path is registered
in; ``api.chol_update`` is the functional entry point and
``factor.CholFactor`` the stateful engine object consumers maintain.
"""
from repro.core import backends
from repro.core.api import (
    chol_downdate,
    chol_downdate_batched,
    chol_update,
    chol_update_batched,
)
from repro.core.blocked import chol_update_blocked
from repro.core.factor import CholFactor, resolve_backend_for
from repro.core.precision import Precision
from repro.core.ref import chol_update_dense, chol_update_ref, modify_error
from repro.core.solve import (
    chol_factor,
    chol_logdet,
    chol_solve,
    downdate_feasible,
    is_positive_factor,
    solve_triangular,
)

__all__ = [
    "backends",
    "CholFactor",
    "Precision",
    "resolve_backend_for",
    "chol_update",
    "chol_update_batched",
    "chol_downdate",
    "chol_downdate_batched",
    "chol_update_blocked",
    "chol_update_ref",
    "chol_update_dense",
    "modify_error",
    "chol_factor",
    "chol_solve",
    "chol_logdet",
    "solve_triangular",
    "downdate_feasible",
    "is_positive_factor",
]
