"""Public API for rank-k Cholesky up/down-dating.

``chol_update`` is the single entry point the rest of the framework uses; the
``method`` argument selects the execution path:

* ``reference``   — serial oracle (O(k n^2), paper Algorithm 1).
* ``paper``       — panelled, faithful element-wise panel apply (paper §4).
* ``gemm``        — panelled, transform-matrix GEMM panel apply (TPU-native).
* ``pallas``      — Pallas kernel, paper-style element-wise panel kernel,
                    one launch per panel (the paper's dispatch pattern).
* ``pallas_gemm`` — Pallas kernel, MXU GEMM panel kernel, one launch/panel.
* ``fused``       — single-launch pipelined Pallas kernel: the whole panel
                    dependency chain in ONE ``pallas_call``, rotation state
                    parked in VMEM scratch (DESIGN.md §5).
* ``auto``        — heuristic: reference for tiny n, gemm otherwise.

``chol_update_batched`` vmaps any of these over stacked ``(B, n, n)``
factors — the serving workload of many concurrent per-user updates.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import blocked, ref

_METHODS = ("reference", "paper", "gemm", "pallas", "pallas_gemm", "fused", "auto")


def chol_update(
    L,
    V,
    *,
    sigma: int = 1,
    method: str = "auto",
    panel: int = 256,
    interpret: Optional[bool] = None,
):
    """Rank-k up/down-date of the upper Cholesky factor L (A = L^T L).

    Args:
      L: (n, n) upper-triangular factor with positive diagonal.
      V: (n, k) or (n,) modification matrix.
      sigma: +1 for update (A + V V^T), -1 for downdate (A - V V^T).
      method: execution path, see module docstring.
      panel: row-panel size for the blocked paths.
      interpret: force Pallas interpret mode (defaults to auto-detect: True on
        CPU, False on TPU).

    Returns:
      The modified upper-triangular factor.
    """
    if method not in _METHODS:
        raise ValueError(f"method must be one of {_METHODS}, got {method!r}")
    n = L.shape[0]
    if method == "auto":
        method = "reference" if n < 2 * panel else "gemm"
    if method == "reference":
        return ref.chol_update_ref(L, V, sigma=sigma)
    if method in ("paper", "gemm"):
        return blocked.chol_update_blocked(
            L, V, sigma=sigma, panel=panel, strategy=method
        )
    # Pallas paths imported lazily so the pure-JAX core has no kernel deps.
    if method == "fused":
        from repro.kernels import fused as kernel_fused

        return kernel_fused.chol_update_fused(
            L, V, sigma=sigma, panel=panel, interpret=interpret
        )
    from repro.kernels import ops as kernel_ops

    return kernel_ops.chol_update_pallas(
        L,
        V,
        sigma=sigma,
        panel=panel,
        strategy="gemm" if method == "pallas_gemm" else "paper",
        interpret=interpret,
    )


def chol_update_batched(
    L,
    V,
    *,
    sigma: int = 1,
    method: str = "fused",
    panel: int = 256,
    interpret: Optional[bool] = None,
):
    """Batched rank-k up/down-date over stacked factors (one vmapped launch).

    The serving workload: many concurrent per-user factors receive their own
    modification in one dispatch (e.g. a fleet of online-ridge windows, one
    per user). For the ``fused`` method vmap folds the batch into the kernel
    grid, so B updates still cost a single device launch.

    Args:
      L: (B, n, n) stacked upper-triangular factors.
      V: (B, n, k) — or (B, n), broadcast to rank 1 — stacked modifications.
      sigma, method, panel, interpret: as in ``chol_update`` (shared across
        the batch; per-element sigma would break the single-kernel grid).

    Returns:
      (B, n, n) stacked updated factors.
    """
    if L.ndim != 3:
        raise ValueError(f"L must be (B, n, n), got shape {L.shape}")
    if V.ndim == 2:
        V = V[:, :, None]
    if V.ndim != 3 or V.shape[0] != L.shape[0] or V.shape[1] != L.shape[1]:
        raise ValueError(
            f"V must be (B, n, k) matching L {L.shape}, got {V.shape}"
        )

    def one(l, v):
        return chol_update(
            l, v, sigma=sigma, method=method, panel=panel, interpret=interpret
        )

    return jax.vmap(one)(L, V)


def chol_downdate(L, V, **kw):
    """Convenience wrapper for ``chol_update(..., sigma=-1)``."""
    return chol_update(L, V, sigma=-1, **kw)
