"""Public API for rank-k Cholesky up/down-dating.

``chol_update`` is the single entry point the rest of the framework uses; the
``method`` argument selects the execution path:

* ``reference``   — serial oracle (O(k n^2), paper Algorithm 1).
* ``paper``       — panelled, faithful element-wise panel apply (paper §4).
* ``gemm``        — panelled, transform-matrix GEMM panel apply (TPU-native).
* ``pallas``      — Pallas kernel, paper-style element-wise panel kernel.
* ``pallas_gemm`` — Pallas kernel, MXU GEMM panel kernel.
* ``auto``        — heuristic: reference for tiny n, gemm otherwise.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core import blocked, ref

_METHODS = ("reference", "paper", "gemm", "pallas", "pallas_gemm", "auto")


def chol_update(
    L,
    V,
    *,
    sigma: int = 1,
    method: str = "auto",
    panel: int = 256,
    interpret: Optional[bool] = None,
):
    """Rank-k up/down-date of the upper Cholesky factor L (A = L^T L).

    Args:
      L: (n, n) upper-triangular factor with positive diagonal.
      V: (n, k) or (n,) modification matrix.
      sigma: +1 for update (A + V V^T), -1 for downdate (A - V V^T).
      method: execution path, see module docstring.
      panel: row-panel size for the blocked paths.
      interpret: force Pallas interpret mode (defaults to auto-detect: True on
        CPU, False on TPU).

    Returns:
      The modified upper-triangular factor.
    """
    if method not in _METHODS:
        raise ValueError(f"method must be one of {_METHODS}, got {method!r}")
    n = L.shape[0]
    if method == "auto":
        method = "reference" if n < 2 * panel else "gemm"
    if method == "reference":
        return ref.chol_update_ref(L, V, sigma=sigma)
    if method in ("paper", "gemm"):
        return blocked.chol_update_blocked(
            L, V, sigma=sigma, panel=panel, strategy=method
        )
    # Pallas paths imported lazily so the pure-JAX core has no kernel deps.
    from repro.kernels import ops as kernel_ops

    return kernel_ops.chol_update_pallas(
        L,
        V,
        sigma=sigma,
        panel=panel,
        strategy="gemm" if method == "pallas_gemm" else "paper",
        interpret=interpret,
    )


def chol_downdate(L, V, **kw):
    """Convenience wrapper for ``chol_update(..., sigma=-1)``."""
    return chol_update(L, V, sigma=-1, **kw)
