"""Public API for rank-k Cholesky up/down-dating.

``chol_update`` is the single entry point the rest of the framework uses; the
``method`` argument names a backend from the registry
(``repro.core.backends``):

* ``reference``   — serial oracle (O(k n^2), paper Algorithm 1).
* ``paper``       — panelled, faithful element-wise panel apply (paper §4).
* ``gemm``        — panelled, transform-matrix GEMM panel apply (TPU-native).
* ``pallas``      — Pallas kernel, paper-style element-wise panel kernel,
                    one launch per panel (the paper's dispatch pattern).
* ``pallas_gemm`` — Pallas kernel, MXU GEMM panel kernel, one launch/panel.
* ``fused``       — single-launch pipelined Pallas kernel: the whole panel
                    dependency chain in ONE ``pallas_call``, rotation state
                    parked in VMEM scratch (DESIGN.md §5).
* ``sharded``     — column-sharded multi-device driver composing the fused
                    kernel, one launch per shard (DESIGN.md §7); pass
                    ``mesh=`` (and optionally ``axis=``).
* ``auto``        — heuristic (``backends.resolve``): fused on a
                    Pallas-capable device or under explicit interpret mode,
                    reference for tiny n, gemm otherwise.

Every path is differentiable: dispatch runs through the Murray (2016)
derivative rules in ``repro.core.autodiff``, so ``jax.grad``/``jax.jvp`` of
a maintained factor never trace the underlying recurrence or kernel.

``chol_update_batched`` / ``chol_downdate_batched`` vmap any single-device
backend over stacked ``(B, n, n)`` factors — the serving workload of many
concurrent per-user updates.

The stateful-factor object API (update/downdate/solve/logdet on one carried
value) lives in ``repro.core.factor.CholFactor``; these functions remain as
the thin functional face over the same registry.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.core import autodiff, backends


@functools.lru_cache(maxsize=None)
def _cached_impl(method: str, panel: int, interpret: Optional[bool],
                 opts_items: tuple):
    """One impl closure per (method, panel, interpret, opts) so the
    custom_jvp wrapper sees a stable hashable callable (warm jit caches)."""
    opts = dict(opts_items)

    def impl(L, V, sigma):
        return backends.dispatch(L, V, sigma=sigma, method=method,
                                 panel=panel, interpret=interpret, **opts)

    return impl


def chol_update(
    L,
    V,
    *,
    sigma: int = 1,
    method: str = "auto",
    panel: int = 256,
    interpret: Optional[bool] = None,
    **opts,
):
    """Rank-k up/down-date of the upper Cholesky factor L (A = L^T L).

    Args:
      L: (n, n) upper-triangular factor with positive diagonal.
      V: (n, k) or (n,) modification matrix.
      sigma: +1 for update (A + V V^T), -1 for downdate (A - V V^T).
      method: backend name or 'auto', see module docstring.
      panel: row-panel size for the blocked paths.
      interpret: force Pallas interpret mode (defaults to auto-detect: True on
        CPU, False on TPU).
      **opts: backend-specific options (e.g. ``mesh=``/``axis=`` for
        'sharded', ``panel_apply=`` for 'fused').

    Returns:
      The modified upper-triangular factor.
    """
    if method not in backends.methods():
        raise ValueError(
            f"method must be one of {backends.methods()}, got {method!r}"
        )
    if sigma not in (1, -1):
        raise ValueError(f"sigma must be +1 or -1, got {sigma}")
    if V.ndim == 1:
        V = V[:, None]
    impl = _cached_impl(method, panel, interpret, tuple(sorted(opts.items())))
    return autodiff.diffable_update(impl, sigma, L, V)


def chol_update_batched(
    L,
    V,
    *,
    sigma: int = 1,
    method: str = "fused",
    panel: int = 256,
    interpret: Optional[bool] = None,
    **opts,
):
    """Batched rank-k up/down-date over stacked factors (one vmapped launch).

    The serving workload: many concurrent per-user factors receive their own
    modification in one dispatch (e.g. a fleet of online-ridge windows, one
    per user). For the ``fused`` method vmap folds the batch into the kernel
    grid, so B updates still cost a single device launch.

    Args:
      L: (B, n, n) stacked upper-triangular factors.
      V: (B, n, k) — or (B, n), broadcast to rank 1 — stacked modifications.
      sigma, method, panel, interpret, **opts: as in ``chol_update`` (shared
        across the batch; per-element sigma would break the single-kernel
        grid).

    Returns:
      (B, n, n) stacked updated factors.
    """
    if L.ndim != 3:
        raise ValueError(f"L must be (B, n, n), got shape {L.shape}")
    if V.ndim == 2:
        V = V[:, :, None]
    if V.ndim != 3 or V.shape[0] != L.shape[0] or V.shape[1] != L.shape[1]:
        raise ValueError(
            f"V must be (B, n, k) matching L {L.shape}, got {V.shape}"
        )
    if method == "sharded":
        raise ValueError("method='sharded' does not support the batched API")

    def one(l, v):
        return chol_update(
            l, v, sigma=sigma, method=method, panel=panel, interpret=interpret,
            **opts,
        )

    return jax.vmap(one)(L, V)


def chol_downdate(L, V, **kw):
    """Convenience wrapper for ``chol_update(..., sigma=-1)``."""
    return chol_update(L, V, sigma=-1, **kw)


def chol_downdate_batched(L, V, **kw):
    """Convenience wrapper for ``chol_update_batched(..., sigma=-1)``."""
    return chol_update_batched(L, V, sigma=-1, **kw)
