"""Public API for rank-k Cholesky up/down-dating.

``chol_update`` is the single entry point the rest of the framework uses; the
``method`` argument names a backend from the registry
(``repro.core.backends``):

* ``reference``   — serial oracle (O(k n^2), paper Algorithm 1).
* ``paper``       — panelled, faithful element-wise panel apply (paper §4).
* ``gemm``        — panelled, transform-matrix GEMM panel apply (TPU-native).
* ``pallas``      — Pallas kernel, paper-style element-wise panel kernel,
                    one launch per panel (the paper's dispatch pattern).
* ``pallas_gemm`` — Pallas kernel, MXU GEMM panel kernel, one launch/panel.
* ``fused``       — single-launch pipelined Pallas kernel: the whole panel
                    dependency chain in ONE ``pallas_call``, rotation state
                    parked in VMEM scratch (DESIGN.md §5).
* ``sharded``     — column-sharded multi-device driver composing the fused
                    kernel, one launch per shard (DESIGN.md §7); pass
                    ``mesh=`` (and optionally ``axis=``).
* ``blocktridiag``/``blocktridiag_ref`` — the structured pair (DESIGN.md
                    §12): valid only for ``BlockTriDiagStorage`` factors,
                    as dense-only backends are valid only for arrays —
                    ``backends.methods(structure=...)`` reports the split.
* ``auto``        — heuristic (``backends.resolve``): fused on TPU or under
                    explicit interpret mode, pallas_gemm on GPU (Triton —
                    the fused kernel's grid spec is Mosaic-only), reference
                    for tiny n, gemm otherwise.

``precision`` is the storage/accum dtype policy (DESIGN.md §8): a
``repro.core.precision.Precision``, a preset string ('bf16', 'f32', ...),
or None (legacy: compute and store in the input dtype). Under 'bf16' the
L-tiles and the running ``V^T`` are stored in bfloat16 — halving the HBM
bytes of this bandwidth-bound problem — while the diagonal recurrence, the
rotation state ``(c, s)``/``T`` and all GEMM accumulation stay fp32. The
returned factor has the policy's storage dtype. Mixed-dtype inputs are
pinned: ``V`` is always cast to ``L``'s dtype before dispatch, on every
backend (no silent promotion of the factor).

Every path is differentiable: dispatch runs through the Murray (2016)
derivative rules in ``repro.core.autodiff`` (tangents/cotangents computed
in fp32 regardless of storage dtype), so ``jax.grad``/``jax.jvp`` of a
maintained factor never trace the underlying recurrence or kernel.

``chol_update_batched`` / ``chol_downdate_batched`` vmap any single-device
backend over stacked ``(B, n, n)`` factors — the serving workload of many
concurrent per-user updates. Both default to ``method='auto'`` and resolve
the heuristic ONCE per batch (same funnel as the single-factor path).
``method='sharded'`` is the exception: the distributed driver consumes the
stacked fleet natively (DESIGN.md §10) — each member column-sharded over
the mesh axis, the batch folded into the one-per-shard kernel launch — so
the batched wrapper routes it through without vmapping.

The stateful-factor object API (update/downdate/solve/logdet on one carried
value) lives in ``repro.core.factor.CholFactor``; these functions remain as
the thin functional face over the same registry.
"""
from __future__ import annotations

import collections
import threading
from typing import Optional

import jax

from repro.core import autodiff, backends
from repro.core import structure as _structure
from repro.core.precision import Precision

# ---------------------------------------------------------------------------
# Impl cache. One impl closure per (method, panel, interpret, precision,
# opts) so the custom_jvp wrapper sees a stable hashable callable (warm jit
# caches). Two leak hazards are handled here:
#
# * the cache is BOUNDED (LRU): a long-lived serving process that cycles
#   through many configurations must not retain every closure forever;
# * mesh-valued opts are keyed by identity-safe METADATA (axis names, shape,
#   device ids) rather than the Mesh object itself, so two equal meshes
#   built at different times share one entry instead of each pinning a
#   distinct closure (and its jit cache) — the old unbounded lru_cache
#   keyed on the raw object retained every mesh ever passed.
# ---------------------------------------------------------------------------

_IMPL_CACHE_MAX = 64
_impl_cache: "collections.OrderedDict" = collections.OrderedDict()
_impl_lock = threading.Lock()


def _opt_key(value):
    """A hashable, identity-safe cache key for one backend option value."""
    if hasattr(value, "axis_names") and hasattr(value, "devices"):
        # Mesh-like: key by what determines the computation, not object id.
        devs = tuple(id(d) for d in value.devices.flat)
        return ("mesh", tuple(value.axis_names),
                tuple(value.shape[a] for a in value.axis_names), devs)
    return value


def _cached_impl(method: str, panel: int, interpret: Optional[bool],
                 precision: Optional[Precision], opts: dict):
    key = (method, panel, interpret, precision,
           tuple((k, _opt_key(v)) for k, v in sorted(opts.items())))
    # Get-or-create under ONE lock hold: two threads racing the same first
    # call must receive the SAME closure (a per-thread duplicate would
    # defeat the stable-callable contract and double-trace under jit).
    with _impl_lock:
        impl = _impl_cache.get(key)
        if impl is not None:
            _impl_cache.move_to_end(key)
            return impl

        def impl(L, V, sigma):
            return backends.dispatch(L, V, sigma=sigma, method=method,
                                     panel=panel, interpret=interpret,
                                     precision=precision, **opts)

        _impl_cache[key] = impl
        while len(_impl_cache) > _IMPL_CACHE_MAX:
            _impl_cache.popitem(last=False)
        return impl


def impl_cache_len() -> int:
    """Current impl-cache size (bounded by ``_IMPL_CACHE_MAX``); for tests."""
    return len(_impl_cache)


def chol_update(
    L,
    V,
    *,
    sigma: int = 1,
    method: str = "auto",
    panel: int = 256,
    interpret: Optional[bool] = None,
    precision=None,
    **opts,
):
    """Rank-k up/down-date of the upper Cholesky factor L (A = L^T L).

    Args:
      L: (n, n) upper-triangular factor with positive diagonal.
      V: (n, k) or (n,) modification matrix; cast to ``L.dtype`` if it
        differs (the factor's dtype is never silently promoted).
      sigma: +1 for update (A + V V^T), -1 for downdate (A - V V^T).
      method: backend name or 'auto', see module docstring.
      panel: row-panel size for the blocked paths.
      interpret: force Pallas interpret mode (defaults to auto-detect per
        kernel and lowering: the per-panel kernels compile on TPU and GPU,
        the fused kernel's mosaic lowering on TPU only and its portable
        lowering on both — see ``backends.default_interpret``). An explicit
        value, including ``False``, always wins over the auto-detect.
      precision: storage/accum dtype policy ('bf16', a ``Precision``, or
        None = legacy single-dtype behaviour). The result carries the
        storage dtype.
      **opts: backend-specific options (e.g. ``mesh=``/``axis=`` for
        'sharded', ``panel_apply=``/``lowering=`` for 'fused').

    Returns:
      The modified upper-triangular factor.
    """
    if method not in backends.methods():
        raise ValueError(
            f"method must be one of {backends.methods()}, got {method!r}"
        )
    if sigma not in (1, -1):
        raise ValueError(f"sigma must be +1 or -1, got {sigma}")
    structured = _structure.is_factor_storage(L)
    if structured and L.batched:
        raise ValueError(
            "batched structured storage goes through chol_update_batched "
            f"(got {L.describe()})"
        )
    if not structured and L.ndim == 3 and method != "sharded":
        # Only the sharded driver consumes a stacked fleet natively (it
        # folds the batch into its per-shard launch); every other backend
        # batches through the vmapping wrapper.
        raise ValueError(
            "stacked (B, n, n) factors go through chol_update_batched "
            f"(method={method!r})"
        )
    if V.ndim == 1:
        V = V[:, None]
    if V.dtype != L.dtype:
        # Pinned mixed-dtype behaviour (tests/test_factor.py): the factor's
        # dtype wins on every backend; no implicit jnp promotion of L.
        V = V.astype(L.dtype)
    precision = Precision.parse(precision)
    impl = _cached_impl(method, panel, interpret, precision, opts)
    if structured:
        # Structured storage carries its own Murray rule (the tangent is
        # re-extracted into the storage's block layout).
        return autodiff.diffable_update_structured(impl, sigma, L, V)
    return autodiff.diffable_update(impl, sigma, L, V)


def chol_update_batched(
    L,
    V,
    *,
    sigma: int = 1,
    method: str = "auto",
    panel: int = 256,
    interpret: Optional[bool] = None,
    precision=None,
    **opts,
):
    """Batched rank-k up/down-date over stacked factors (one vmapped launch).

    The serving workload: many concurrent per-user factors receive their own
    modification in one dispatch (e.g. a fleet of online-ridge windows, one
    per user). For the ``fused`` method vmap folds the batch into the kernel
    grid, so B updates still cost a single device launch.

    ``method`` defaults to ``'auto'`` — the SAME heuristic as the
    single-factor path — and is resolved once here for the whole batch, so
    the batched serving path can no longer silently bypass the device-kind
    routing (the old hard default of 'fused' did).

    Args:
      L: (B, n, n) stacked upper-triangular factors.
      V: (B, n, k) — or (B, n), broadcast to rank 1 — stacked modifications.
      sigma, method, panel, interpret, precision, **opts: as in
        ``chol_update`` (shared across the batch; per-element sigma would
        break the single-kernel grid).

    Returns:
      (B, n, n) stacked updated factors.
    """
    if _structure.is_factor_storage(L):
        # A structured FLEET: batched storage leaves, (B, n, k) rows. The
        # method resolves once against the storage's structure (same funnel
        # as the dense batch), then vmap maps the member rule over the
        # storage pytree — for the Pallas block-chain kernel the batch
        # folds into the grid, so B updates still construct ONE
        # pallas_call per sign block.
        if not L.batched:
            raise ValueError(
                f"structured fleet must be batched storage, got "
                f"{L.describe()}"
            )
        import jax.numpy as jnp

        V = jnp.asarray(V)
        if V.ndim == 2:
            V = V[:, :, None]
        if V.ndim != 3 or V.shape[0] != L.batch or V.shape[1] != L.n:
            raise ValueError(
                f"V must be (B, n, k) matching fleet {L.describe()}, got "
                f"{V.shape}"
            )
        method = backends.resolve(method, n=L.n, panel=panel,
                                  interpret=interpret, structure=L.structure)

        def one_s(l, v):
            return chol_update(
                l, v, sigma=sigma, method=method, panel=panel,
                interpret=interpret, precision=precision, **opts,
            )

        return jax.vmap(one_s)(L, V)
    if L.ndim != 3:
        raise ValueError(f"L must be (B, n, n), got shape {L.shape}")
    if V.ndim == 2:
        V = V[:, :, None]
    if V.ndim != 3 or V.shape[0] != L.shape[0] or V.shape[1] != L.shape[1]:
        raise ValueError(
            f"V must be (B, n, k) matching L {L.shape}, got {V.shape}"
        )
    if method == "sharded":
        # The sharded driver consumes the stacked fleet natively (chain
        # phase vmapped — one psum-gather per panel for the whole batch —
        # and B folded into the per-shard kernel grid), so it must NOT be
        # vmapped here: launches scale with shards, never with B.
        return chol_update(
            L, V, sigma=sigma, method="sharded", panel=panel,
            interpret=interpret, precision=precision, **opts,
        )
    # Resolve the heuristic ONCE for the batch (not per vmapped element).
    method = backends.resolve(method, n=L.shape[-1], panel=panel,
                              interpret=interpret)

    def one(l, v):
        return chol_update(
            l, v, sigma=sigma, method=method, panel=panel, interpret=interpret,
            precision=precision, **opts,
        )

    return jax.vmap(one)(L, V)


def chol_downdate(L, V, **kw):
    """Convenience wrapper for ``chol_update(..., sigma=-1)``."""
    return chol_update(L, V, sigma=-1, **kw)


def chol_downdate_batched(L, V, **kw):
    """Convenience wrapper for ``chol_update_batched(..., sigma=-1)``."""
    return chol_update_batched(L, V, sigma=-1, **kw)
