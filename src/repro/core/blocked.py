"""Panelled rank-k Cholesky modification (paper §4), TPU-shaped, pure JAX.

The paper splits ``L`` into row-panels: square on-diagonal blocks are
processed *serially* (on the CPU in the paper), and the off-diagonal panel to
the right of each diagonal block is processed *in parallel* (the GPU kernel),
using the rotation coefficients ``(c, s)`` produced by the diagonal pass.

Two panel-apply strategies are provided:

* ``paper`` — faithful to the paper: stream the rows of the off-diagonal
  panel, applying the k rotations element-wise per row (the paper's ``Apply``
  with ``ElementsPerThread`` batching). Bandwidth-bound, arithmetic intensity
  ~k FLOP/element, exactly like the CUDA kernel.

* ``gemm`` — the TPU-native adaptation (beyond-paper): the P·k rotations of a
  panel form a single linear map ``T ∈ R^{(P+k)x(P+k)}`` acting on the stacked
  rows ``[R; V^T]``. The whole panel update is then one dense matmul
  ``T @ [R; V^T]`` — MXU work with arithmetic intensity ~(P+k)/2 instead of k,
  converting the paper's bandwidth-bound kernel into a compute-dense GEMM.
  ``T`` is built during the (serial) diagonal pass by augmenting the stacked
  diagonal block with an identity, so the dependency structure (diagonal block
  p -> panel p -> diagonal block p+1) is unchanged.

Both agree with ``repro.core.ref`` to roundoff and are tested as such.
"""
from __future__ import annotations

import functools
from typing import Literal, Optional

import jax
import jax.numpy as jnp

from repro.core import ref as _ref
from repro.core.precision import Precision

Strategy = Literal["paper", "gemm"]


def _pad_to_panels(L, V, panel):
    """Pad L to a multiple of ``panel`` with an identity block, V with zeros.

    Padded rows produce identity rotations (v_i = 0 -> c = 1, s = 0), so the
    result on the original block is unchanged.
    """
    n = L.shape[0]
    n_pad = (-n) % panel
    if n_pad == 0:
        return L, V, n
    L = jnp.pad(L, ((0, n_pad), (0, n_pad)))
    L = L.at[jnp.arange(n, n + n_pad), jnp.arange(n, n + n_pad)].set(1.0)
    V = jnp.pad(V, ((0, n_pad), (0, 0)))
    return L, V, n


def panel_diag(D, vtd, sigma, *, with_transform: bool):
    """Serial pass over one diagonal block (the paper's CPU phase).

    Args:
      D:   (P, P) upper-triangular diagonal block of L.
      vtd: (k, P) the rows of V^T belonging to this panel.
      sigma: +1 / -1.
      with_transform: also accumulate the composite (P+k, P+k) transform ``T``
        by augmenting the block with an identity: the same row sweep applied
        to ``[D | I]`` emits T's top rows, to ``[vt | I]`` its bottom rows.

    Returns:
      (D_new, c, s, T) — ``c, s`` have shape (P, k); ``T`` is None unless
      requested. ``T`` satisfies ``[R_new; vt_new] = T @ [R; vt]`` for any
      trailing columns.
    """
    P = D.shape[0]
    k = vtd.shape[0]
    dtype = D.dtype
    vt = vtd.astype(dtype)
    W = D
    if with_transform:
        W = jnp.concatenate(
            [D, jnp.eye(P, dtype=dtype), jnp.zeros((P, k), dtype)], axis=1
        )  # (P, 2P+k)
        vt = jnp.concatenate(
            [vt, jnp.zeros((k, P), dtype), jnp.eye(k, dtype=dtype)], axis=1
        )  # (k, 2P+k)
    width = W.shape[1]
    col = jnp.arange(width)

    def row_fn(carry, i):
        W, vt = carry
        lrow = W[i]
        c_i, s_i, lii = _ref._row_rotations(lrow[i], vt[:, i], sigma)
        t_new, vt_new = _ref._apply_rotations_to_row(lrow, vt, c_i, s_i, sigma)
        keep = (col > i) | (col >= P)  # augmented columns always update
        lrow = jnp.where(keep, t_new, lrow).at[i].set(lii)
        vt = jnp.where(keep[None, :], vt_new, vt).at[:, i].set(0.0)
        W = W.at[i].set(lrow)
        return (W, vt), (c_i, s_i)

    (W, vt), (c, s) = jax.lax.scan(row_fn, (W, vt), jnp.arange(P))
    D_new = jnp.triu(W[:, :P])
    T = jnp.concatenate([W[:, P:], vt[:, P:]], axis=0) if with_transform else None
    return D_new, c, s, T


def panel_apply_paper(R, vt, c, s, sigma):
    """Faithful off-diagonal panel apply (the paper's GPU kernel, in jnp).

    Streams the P rows in order; per row the k rotations chain element-wise
    over the panel columns. ``R``: (P, w); ``vt``: (k, w); ``c, s``: (P, k).
    """

    def row_fn(vt, xs):
        r_row, c_i, s_i = xs

        def m_fn(t, ys):
            v_m, c_m, s_m = ys
            t = (t + sigma * s_m * v_m) / c_m
            v_m = c_m * v_m - s_m * t
            return t, v_m

        t, vt = jax.lax.scan(m_fn, r_row, (vt, c_i, s_i))
        return vt, t

    vt_new, R_new = jax.lax.scan(row_fn, vt, (R, c, s))
    return R_new, vt_new


def panel_apply_gemm(R, vt, T):
    """GEMM panel apply: one (P+k, P+k) @ (P+k, w) matmul on the MXU.

    Accumulates in at least fp32; wider operands (an f64 accum policy, or
    legacy f64 inputs) keep their own width — promote, never truncate.
    """
    acc_t = jnp.promote_types(jnp.result_type(R.dtype, T.dtype), jnp.float32)
    S = jnp.concatenate([R, vt], axis=0)
    S = jnp.dot(T, S, preferred_element_type=acc_t).astype(R.dtype)
    P = R.shape[0]
    return S[:P], S[P:]


@functools.partial(
    jax.jit,
    static_argnames=("sigma", "panel", "strategy", "apply_fn", "precision"),
)
def chol_update_blocked(
    L,
    V,
    *,
    sigma: int = 1,
    panel: int = 256,
    strategy: Strategy = "gemm",
    apply_fn=None,
    precision: Optional[Precision] = None,
):
    """Panelled rank-k up/down-date. See module docstring.

    ``apply_fn`` optionally overrides the off-diagonal panel apply with a
    custom implementation of signature ``(R, vt, c, s, T, sigma) -> (R, vt)``
    — this is the hook the Pallas kernels plug into.

    ``precision`` (DESIGN.md §8) mirrors the fused kernel's storage/accum
    split so reference comparisons are apples-to-apples: ``L`` and the
    running ``V^T`` are STORED in the storage dtype between panel steps
    (each downcast loses exactly the bits the kernel's HBM tiles lose),
    while the diagonal recurrence and the panel applies COMPUTE in the
    accumulation dtype — the rotation state ``(c, s)`` and the transform
    ``T`` never leave it.
    """
    if sigma not in (1, -1):
        raise ValueError(f"sigma must be +1 or -1, got {sigma}")
    squeeze = V.ndim == 1
    if squeeze:
        V = V[:, None]
    if precision is not None:
        L = precision.cast_storage(L)
        V = precision.cast_storage(V)
    up = (lambda x: x) if precision is None else precision.up
    store = L.dtype
    L, V, n = _pad_to_panels(L, V, panel)
    np_ = L.shape[0]
    k = V.shape[1]
    vt = V.T
    n_panels = np_ // panel
    with_T = strategy == "gemm" or apply_fn is not None

    # Per-panel trailing widths are static, so a python loop gives each panel
    # an exact-shape computation (no masking waste), all fused under one jit.
    for p in range(n_panels):
        r0 = p * panel
        D = jax.lax.dynamic_slice(L, (r0, r0), (panel, panel))
        vtd = jax.lax.dynamic_slice(vt, (0, r0), (k, panel))
        D_new, c, s, T = panel_diag(up(D), up(vtd), sigma,
                                    with_transform=with_T)
        L = jax.lax.dynamic_update_slice(L, D_new.astype(store), (r0, r0))
        vt = jax.lax.dynamic_update_slice(vt, jnp.zeros_like(vtd), (0, r0))
        w = np_ - r0 - panel
        if w == 0:
            continue
        R = jax.lax.dynamic_slice(L, (r0, r0 + panel), (panel, w))
        vtr = jax.lax.dynamic_slice(vt, (0, r0 + panel), (k, w))
        if apply_fn is not None:
            R_new, vtr_new = apply_fn(R, vtr, c, s, T, sigma)
        elif strategy == "gemm":
            R_new, vtr_new = panel_apply_gemm(up(R), up(vtr), T)
        else:
            R_new, vtr_new = panel_apply_paper(up(R), up(vtr), c, s, sigma)
        L = jax.lax.dynamic_update_slice(
            L, R_new.astype(store), (r0, r0 + panel))
        vt = jax.lax.dynamic_update_slice(
            vt, vtr_new.astype(store), (0, r0 + panel))

    return L[:n, :n]
