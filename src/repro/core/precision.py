"""Precision policy: storage dtype vs accumulation dtype (DESIGN.md §8).

The paper is explicit that rank-k up/down-dating is *bandwidth-bound*
("limited speed ups are possible due to the bandwidth bound nature of the
problem"), so the bytes each off-diagonal L-tile occupies in HBM are the
dominant cost of an update. Halving them — bf16 tiles — is the single
biggest paper-aligned lever left, *provided* the numerically sensitive part
stays in fp32: the serial diagonal recurrence divides by the running
diagonal (``c = w / l_ii``) and chains k hyperbolic rotations per row, so
its rounding errors propagate into every trailing panel.

``Precision`` makes that split a first-class, validated policy:

* ``storage`` — the dtype L-tiles and the running ``V^T`` panels live in
  (in HBM between grid steps, and in the whole-launch VMEM scratch of the
  fused kernel). ``None`` means "whatever dtype the inputs already have" —
  the legacy behaviour, bit-for-bit.
* ``accum``   — the dtype every *computation* runs in: the diagonal
  recurrence, the rotation coefficients ``(c, s)``, the transform ``T``,
  and GEMM accumulation (``preferred_element_type``). Always at least
  fp32; tangents/cotangents of the Murray derivative rules use it too.

This mirrors how the tall-skinny QR literature (Thies & Röhrig-Zöllner)
and Murray (2016) keep reductions/derivatives in higher precision than
storage. The policy is a frozen, hashable dataclass so it rides as static
aux on ``CholFactor`` and as a jit static argument through the registry.

The module is dependency-light on purpose (jax.numpy only): the blocked
drivers, all three kernel families, and the distributed driver import it
without touching the factor/api layer. ``repro.core.factor`` re-exports
``Precision`` as the user-facing home the rest of the docs point at.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Union

import jax.numpy as jnp
import numpy as np

PrecisionLike = Union[None, str, "Precision", Any]

# Named presets: the policies benchmarks/tests/CLIs spell by string.
_PRESETS = {
    "float32": ("float32", "float32"),
    "f32": ("float32", "float32"),
    "fp32": ("float32", "float32"),
    "bfloat16": ("bfloat16", "float32"),
    "bf16": ("bfloat16", "float32"),
    "float64": ("float64", "float64"),
    "f64": ("float64", "float64"),
    "highest": (None, "float64"),
}


def _as_dtype(spec) -> np.dtype:
    try:
        dt = np.dtype(jnp.dtype(spec))
    except TypeError as e:
        raise ValueError(f"not a dtype: {spec!r}") from e
    # jnp.issubdtype, not np.issubdtype: ml_dtypes (bfloat16, fp8) register
    # with JAX's extended lattice but are not numpy-floating subtypes.
    if not jnp.issubdtype(dt, jnp.floating):
        raise ValueError(f"precision dtypes must be floating, got {dt}")
    return dt


@dataclasses.dataclass(frozen=True)
class Precision:
    """Storage/accumulation dtype split for the rank-k modification.

    Attributes:
      storage: dtype the factor tiles and ``V^T`` panels are *stored* in
        between chain steps (None = keep the input dtype untouched).
      accum: dtype the recurrence/rotations/GEMMs *compute* in; must be at
        least as wide as ``storage`` and at least fp32.
    """

    storage: Optional[np.dtype] = None
    accum: np.dtype = np.dtype(np.float32)

    def __post_init__(self):
        storage = None if self.storage is None else _as_dtype(self.storage)
        accum = _as_dtype(self.accum)
        if accum.itemsize < np.dtype(np.float32).itemsize:
            raise ValueError(
                f"accum dtype must be at least float32, got {accum} — the "
                "diagonal recurrence divides by the running diagonal and is "
                "not stable in 16-bit arithmetic")
        if storage is not None and storage.itemsize > accum.itemsize:
            raise ValueError(
                f"storage dtype {storage} is wider than accum dtype {accum}; "
                "the policy is storage <= accum")
        object.__setattr__(self, "storage", storage)
        object.__setattr__(self, "accum", accum)

    # -- construction -------------------------------------------------------
    @classmethod
    def parse(cls, spec: PrecisionLike) -> Optional["Precision"]:
        """Canonicalise a user spec: None | preset str | dtype | Precision.

        ``None`` stays None (legacy behaviour: no casts anywhere). A bare
        dtype means "store in this dtype, accumulate in fp32-or-wider".
        """
        if spec is None or isinstance(spec, cls):
            return spec
        if isinstance(spec, str):
            key = spec.lower()
            if key in _PRESETS:
                storage, accum = _PRESETS[key]
                return cls(storage=storage, accum=accum)
            # fall through: maybe a dtype string like 'float16'
        storage = _as_dtype(spec)
        accum = np.promote_types(storage, np.float32)
        return cls(storage=storage, accum=accum)

    # -- application --------------------------------------------------------
    def storage_for(self, dtype) -> np.dtype:
        """The dtype an input of ``dtype`` is stored as under this policy."""
        return np.dtype(jnp.dtype(dtype)) if self.storage is None else self.storage

    def cast_storage(self, x):
        """Cast an array to the policy's storage dtype (no-op if None)."""
        return x if self.storage is None else x.astype(self.storage)

    def up(self, x):
        """Upcast into the accumulation dtype (compute happens here)."""
        return x.astype(self.accum)

    def down(self, x, like=None):
        """Downcast a computed value back to storage (or ``like``'s dtype)."""
        target = like.dtype if like is not None else self.storage
        return x if target is None else x.astype(target)

    def bytes_per_element(self, input_dtype) -> int:
        """Stored bytes per L element — the bandwidth-bound quantity."""
        return int(self.storage_for(input_dtype).itemsize)

    def __repr__(self):
        st = "input" if self.storage is None else str(self.storage)
        return f"Precision(storage={st}, accum={self.accum})"


# The legacy policy: no casts, compute wherever the inputs already are.
DEFAULT = None
