"""Differentiation rules for the rank-k Cholesky modification.

``chol_update`` computes ``L~ = chol(L^T L + sigma V V^T)`` by a long chain
of hyperbolic rotations (or a Pallas kernel, which JAX cannot differentiate
at all). Differentiating that chain op-by-op is both wasteful and fragile;
Murray (2016, "Differentiation of the Cholesky decomposition") gives the
blocked/level-3 derivative rules that let us differentiate *the function*
instead of *the algorithm*:

Forward (JVP).  With the upper convention ``A~ = L~^T L~``, the Cholesky
differential is

    dL~ = Psi(L~^{-T} dA~ L~^{-1}) L~,
    Psi(M) = triu(M) - (1/2) diag(M),        [Murray eq. 5, transposed]

and the modification contributes ``dA~ = dL^T L + L^T dL
+ sigma (dV V^T + V dV^T)``. The tangent map costs two triangular solves
and two GEMMs — O(n^3/3) less than re-running the recurrence, and valid
for every backend including the fused Pallas kernel.

Reverse (VJP).  The tangent map above is linear in ``(dL, dV)`` with
coefficients depending only on primal values, so JAX obtains the adjoint by
transposing it (jax.linearize + transpose); this reproduces Murray's
level-3 reverse rule ``A bar = (1/2) L~^{-1} (Phi + Phi^T) L~^{-T}`` with
``Phi = Phi(L~ bar L~^T)`` without a second hand-written formula, and is
what ``jax.grad`` exercises (gradcheck in tests/test_factor.py).

The wrapper also *insulates* the primal from AD: the Pallas kernels and the
lax.scan recurrences are never traced for derivatives, so the optimizer's
preconditioner update stays inside one traced graph on any backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _mT(x):
    """Matrix transpose over the last two axes (batched-safe ``.T``)."""
    return jnp.swapaxes(x, -1, -2)


def _psi(M):
    """Upper-triangular half-diagonal projector: triu(M) - diag(M)/2.

    Operates on the trailing two axes, so stacked ``(B, n, n)`` operands
    (the sharded-batched fleet path) go through the same rule.
    """
    d = jnp.diagonal(M, axis1=-2, axis2=-1)
    eye = jnp.eye(M.shape[-1], dtype=M.dtype)
    return jnp.triu(M) - 0.5 * eye * d[..., None, :]


@functools.partial(jax.custom_jvp, nondiff_argnums=(0, 1))
def diffable_update(impl, sigma, L, V):
    """``impl(L, V, sigma) -> L_new`` wrapped with Murray's derivative rules.

    ``impl`` must be a hashable callable (use a cached functools.partial so
    jit caches stay warm); ``sigma`` is static. ``V`` must already be
    ``(n, k)`` — normalise vectors before calling. Stacked ``(B, n, n)`` /
    ``(B, n, k)`` operands are supported (every step of the tangent map
    below acts on the trailing two axes), which is what lets the batched
    sharded driver keep ``jax.grad`` without a per-element vmap.
    """
    return impl(L, V, sigma)


@diffable_update.defjvp
def _diffable_update_jvp(impl, sigma, primals, tangents):
    L, V = primals
    dL, dV = tangents
    L_new = diffable_update(impl, sigma, L, V)
    # Tangent/cotangent discipline under low-precision storage (DESIGN.md
    # §8): the Murray rule runs two triangular solves against the output
    # factor — solves amplify rounding, so the whole tangent map computes in
    # at least fp32 even when the primal factor is stored bf16. fp64
    # primals keep fp64 (promote, never truncate). Only the returned
    # tangent is downcast, because custom_jvp requires tangent aval ==
    # primal-out aval. The VJP is the transpose of this (linear) map, so
    # cotangents inherit the same fp32 arithmetic.
    acc = jnp.promote_types(L_new.dtype, jnp.float32)
    Lh, Vh = L.astype(acc), V.astype(acc)
    dLh, dVh = dL.astype(acc), dV.astype(acc)
    Lnh = L_new.astype(acc)
    # dA~ = d(L^T L) + sigma d(V V^T), symmetric by construction.
    dA = (_mT(dLh) @ Lh + _mT(Lh) @ dLh
          + sigma * (dVh @ _mT(Vh) + Vh @ _mT(dVh)))
    # M = L~^{-T} dA~ L~^{-1} via two triangular solves against the output
    # factor (both linear in the tangent, hence transposable for the VJP).
    X = jax.scipy.linalg.solve_triangular(Lnh, dA, trans=1, lower=False)
    M = _mT(jax.scipy.linalg.solve_triangular(Lnh, _mT(X), trans=1,
                                              lower=False))
    dL_new = _psi(M) @ Lnh
    return L_new, dL_new.astype(L_new.dtype)


@functools.partial(jax.custom_jvp, nondiff_argnums=(0, 1))
def diffable_update_structured(impl, sigma, S, V):
    """The structured-storage twin of ``diffable_update``.

    ``S`` is a ``FactorStorage`` pytree (e.g. ``BlockTriDiagStorage``), so
    the primal/tangent pair flows through custom_jvp as a pytree of block
    arrays. The tangent map is the SAME Murray rule — Cholesky
    differentiation knows nothing about storage layout — lifted to dense,
    then re-extracted into the storage's block layout via ``blocks_like``.

    The extraction is EXACT, not a projection: for every direction in the
    block-tridiagonal perturbation family, ``dA~`` is block-tridiagonal,
    and the Cholesky differential of a block-bidiagonal factor under such
    perturbations stays block-bidiagonal (same dependency argument as the
    kernel — entries outside the band have zero derivative). The lift costs
    O(n^2) tangent memory, which only the DERIVATIVE path pays; the primal
    modification stays O(n·b) (pinned by the jaxpr test). A band-respecting
    O(n·b^2) tangent map via the structured triangular solve is the noted
    follow-up.
    """
    return impl(S, V, sigma)


@diffable_update_structured.defjvp
def _diffable_update_structured_jvp(impl, sigma, primals, tangents):
    S, V = primals
    dS, dV = tangents
    S_new = diffable_update_structured(impl, sigma, S, V)
    acc = jnp.promote_types(S_new.dtype, jnp.float32)
    Lh = S.to_dense().astype(acc)
    dLh = dS.to_dense().astype(acc)
    Vh, dVh = V.astype(acc), dV.astype(acc)
    Lnh = S_new.to_dense().astype(acc)
    dA = (_mT(dLh) @ Lh + _mT(Lh) @ dLh
          + sigma * (dVh @ _mT(Vh) + Vh @ _mT(dVh)))
    X = jax.scipy.linalg.solve_triangular(Lnh, dA, trans=1, lower=False)
    M = _mT(jax.scipy.linalg.solve_triangular(Lnh, _mT(X), trans=1,
                                              lower=False))
    dL_new = _psi(M) @ Lnh
    return S_new, S_new.blocks_like(dL_new)
