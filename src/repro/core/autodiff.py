"""Differentiation rules for the rank-k Cholesky modification.

``chol_update`` computes ``L~ = chol(L^T L + sigma V V^T)`` by a long chain
of hyperbolic rotations (or a Pallas kernel, which JAX cannot differentiate
at all). Differentiating that chain op-by-op is both wasteful and fragile;
Murray (2016, "Differentiation of the Cholesky decomposition") gives the
blocked/level-3 derivative rules that let us differentiate *the function*
instead of *the algorithm*:

Forward (JVP).  With the upper convention ``A~ = L~^T L~``, the Cholesky
differential is

    dL~ = Psi(L~^{-T} dA~ L~^{-1}) L~,
    Psi(M) = triu(M) - (1/2) diag(M),        [Murray eq. 5, transposed]

and the modification contributes ``dA~ = dL^T L + L^T dL
+ sigma (dV V^T + V dV^T)``. The tangent map costs two triangular solves
and two GEMMs — O(n^3/3) less than re-running the recurrence, and valid
for every backend including the fused Pallas kernel.

Reverse (VJP).  The tangent map above is linear in ``(dL, dV)`` with
coefficients depending only on primal values, so JAX obtains the adjoint by
transposing it (jax.linearize + transpose); this reproduces Murray's
level-3 reverse rule ``A bar = (1/2) L~^{-1} (Phi + Phi^T) L~^{-T}`` with
``Phi = Phi(L~ bar L~^T)`` without a second hand-written formula, and is
what ``jax.grad`` exercises (gradcheck in tests/test_factor.py).

The wrapper also *insulates* the primal from AD: the Pallas kernels and the
lax.scan recurrences are never traced for derivatives, so the optimizer's
preconditioner update stays inside one traced graph on any backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _mT(x):
    """Matrix transpose over the last two axes (batched-safe ``.T``)."""
    return jnp.swapaxes(x, -1, -2)


def _psi(M):
    """Upper-triangular half-diagonal projector: triu(M) - diag(M)/2.

    Operates on the trailing two axes, so stacked ``(B, n, n)`` operands
    (the sharded-batched fleet path) go through the same rule.
    """
    d = jnp.diagonal(M, axis1=-2, axis2=-1)
    eye = jnp.eye(M.shape[-1], dtype=M.dtype)
    return jnp.triu(M) - 0.5 * eye * d[..., None, :]


@functools.partial(jax.custom_jvp, nondiff_argnums=(0, 1))
def diffable_update(impl, sigma, L, V):
    """``impl(L, V, sigma) -> L_new`` wrapped with Murray's derivative rules.

    ``impl`` must be a hashable callable (use a cached functools.partial so
    jit caches stay warm); ``sigma`` is static. ``V`` must already be
    ``(n, k)`` — normalise vectors before calling. Stacked ``(B, n, n)`` /
    ``(B, n, k)`` operands are supported (every step of the tangent map
    below acts on the trailing two axes), which is what lets the batched
    sharded driver keep ``jax.grad`` without a per-element vmap.
    """
    return impl(L, V, sigma)


@diffable_update.defjvp
def _diffable_update_jvp(impl, sigma, primals, tangents):
    L, V = primals
    dL, dV = tangents
    L_new = diffable_update(impl, sigma, L, V)
    # Tangent/cotangent discipline under low-precision storage (DESIGN.md
    # §8): the Murray rule runs two triangular solves against the output
    # factor — solves amplify rounding, so the whole tangent map computes in
    # at least fp32 even when the primal factor is stored bf16. fp64
    # primals keep fp64 (promote, never truncate). Only the returned
    # tangent is downcast, because custom_jvp requires tangent aval ==
    # primal-out aval. The VJP is the transpose of this (linear) map, so
    # cotangents inherit the same fp32 arithmetic.
    acc = jnp.promote_types(L_new.dtype, jnp.float32)
    Lh, Vh = L.astype(acc), V.astype(acc)
    dLh, dVh = dL.astype(acc), dV.astype(acc)
    Lnh = L_new.astype(acc)
    # dA~ = d(L^T L) + sigma d(V V^T), symmetric by construction.
    dA = (_mT(dLh) @ Lh + _mT(Lh) @ dLh
          + sigma * (dVh @ _mT(Vh) + Vh @ _mT(dVh)))
    # M = L~^{-T} dA~ L~^{-1} via two triangular solves against the output
    # factor (both linear in the tangent, hence transposable for the VJP).
    X = jax.scipy.linalg.solve_triangular(Lnh, dA, trans=1, lower=False)
    M = _mT(jax.scipy.linalg.solve_triangular(Lnh, _mT(X), trans=1,
                                              lower=False))
    dL_new = _psi(M) @ Lnh
    return L_new, dL_new.astype(L_new.dtype)


@functools.partial(jax.custom_jvp, nondiff_argnums=(0, 1))
def diffable_update_structured(impl, sigma, S, V):
    """The structured-storage twin of ``diffable_update``.

    ``S`` is a ``FactorStorage`` pytree (e.g. ``BlockTriDiagStorage``), so
    the primal/tangent pair flows through custom_jvp as a pytree of block
    arrays. The tangent map is the SAME Murray rule — Cholesky
    differentiation knows nothing about storage layout — applied BLOCKWISE
    along the chain: one b×b Cholesky differential plus one coupling solve
    per block row, carried by a single lax.scan (O(nb·b³) work, O(n·b)
    memory — matching the primal's complexity class; neither side ever
    materialises an (n, n) array, pinned by
    ``tests/test_structure.py::test_structured_grad_does_not_densify``).

    The blockwise rule is EXACT for the block-tridiagonal perturbation
    family — tangent directions ``(d diag, d off)`` plus block-local ``dV``
    columns (support inside one adjacent block-row pair, the same contract
    the primal enforces). For such directions ``dA~`` is block-tridiagonal
    and the Cholesky differential of the block-bidiagonal factor stays
    block-bidiagonal, so restricting the Murray solve to the band drops
    only exact zeros. Out-of-family ``dV`` directions (columns spanning
    non-adjacent blocks) leave the storage class in the PRIMAL too — the
    rule, like the kernel, is defined on the contract's directions.
    """
    return impl(S, V, sigma)


def _chain_factor(Ad, Ao):
    """Block-chain Cholesky: (Ad, Ao) blocks of a block-tridiagonal SPD
    matrix -> (diag, off) blocks of its upper block-bidiagonal factor —
    the Schwan et al. recurrence as one lax.scan (O(nb·b³), never (n, n)).

    The tangent re-entry point for ``diffable_update_structured``: the rule
    below differentiates THIS map with ``jax.jvp``, so the scan is
    linearised by JAX's own scan-JVP machinery (which marks the tangent
    inputs linear — a scan traced directly inside a custom_jvp rule is not
    transposable, so ``jax.grad`` would fail on a hand-rolled tangent
    recurrence).
    """
    def step(Ssum, x):
        ao, ad_next = x
        U = _mT(jnp.linalg.cholesky(Ssum))
        off = jax.scipy.linalg.solve_triangular(U, ao, trans=1, lower=False)
        return ad_next - _mT(off) @ off, (U, off)

    S_last, (diag_head, off) = jax.lax.scan(step, Ad[0], (Ao, Ad[1:]))
    U_last = _mT(jnp.linalg.cholesky(S_last))
    return jnp.concatenate([diag_head, U_last[None]], axis=0), off


@diffable_update_structured.defjvp
def _diffable_update_structured_jvp(impl, sigma, primals, tangents):
    S, V = primals
    dS, dV = tangents
    S_new = diffable_update_structured(impl, sigma, S, V)
    from repro.core.structure import BlockTriDiagStorage

    # Same precision discipline as the dense rule: solves amplify rounding,
    # so the tangent map computes in at least fp32; only the returned
    # tangent is downcast to the primal-out leaf dtypes.
    acc = jnp.promote_types(S_new.dtype, jnp.float32)
    nb, b = S.nblocks, S.block
    k = V.shape[-1]
    D, O = S.diag.astype(acc), S.off.astype(acc)
    dD, dO = dS.diag.astype(acc), dS.off.astype(acc)
    # (nb, b, k) slabs of V: row block j of every column.
    Vb = V.astype(acc).reshape(nb, b, k)
    dVb = dV.astype(acc).reshape(nb, b, k)
    Un, On = S_new.diag.astype(acc), S_new.off.astype(acc)

    # Input-side tangent of A~ = U^T U + sigma V V^T in block form:
    #   dAd_j = d(diag_j^T diag_j) + d(off_{j-1}^T off_{j-1})
    #           + sigma d(V V^T)_{jj}
    #   dAo_j = d(diag_j^T off_j) + sigma d(V V^T)_{j,j+1}
    dAd = (_mT(dD) @ D + _mT(D) @ dD
           + sigma * (dVb @ _mT(Vb) + Vb @ _mT(dVb)))
    if nb > 1:
        dAd = dAd.at[1:].add(_mT(dO) @ O + _mT(O) @ dO)
        dAo = (_mT(dD[:-1]) @ O + _mT(D[:-1]) @ dO
               + sigma * (dVb[:-1] @ _mT(Vb[1:]) + Vb[:-1] @ _mT(dVb[1:])))
    else:
        dAo = jnp.zeros((0, b, b), acc)

    # The factor blocks are a function of the matrix blocks (the chain
    # recurrence), and the Cholesky differential is unique — so the tangent
    # of the MODIFIED factor is the JVP of the chain refactorization at the
    # modified matrix blocks Ad~/Ao~ (recovered O(n·b) from the primal-out
    # factor) in direction (dAd~, dAo~). Blockwise Murray: every operation
    # is b×b along the chain; nothing (n, n) is ever built.
    Adn = _mT(Un) @ Un
    if nb > 1:
        Adn = Adn.at[1:].add(_mT(On) @ On)
        Aon = _mT(Un[:-1]) @ On
    else:
        Aon = jnp.zeros((0, b, b), acc)
    _, (dUn, dOn) = jax.jvp(_chain_factor, (Adn, Aon), (dAd, dAo))
    return S_new, BlockTriDiagStorage(
        dUn.astype(S_new.diag.dtype), dOn.astype(S_new.off.dtype))
