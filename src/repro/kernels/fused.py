"""Single-launch fused rank-k Cholesky up/down-date (DESIGN.md §5).

The paper's central implementation obstacle is that "a complex dependency
pattern must be obeyed, requiring multiple kernels to be launched": diagonal
block p must finish before off-diagonal panel p, which must finish before
diagonal block p+1. The per-panel driver (``repro.kernels.ops``) reproduces
that cost verbatim — one ``pallas_call`` per panel, O(n/panel) dispatches,
with the rotation state ``(c, s)`` / the transform ``T`` and the running
``V^T`` round-tripping through HBM (and Python) between launches.

This module collapses the whole cascade into ONE ``pallas_call`` whose grid
*is* the dependency chain. It is ONE kernel (one chain walk, one set of
value-level math helpers shared with the per-panel kernels) with TWO
lowerings:

* ``lowering='mosaic'`` — the TPU spec: TPU grid steps execute sequentially
  (grid dimensions are "arbitrary", not "parallel", by default), so the
  chain maps onto a 1-D grid over a ``PrefetchScalarGridSpec`` index table,
  with the chain-walk state (running ``V^T``, parked ``T``/``(c, s)``)
  parked in ``pltpu.VMEM`` scratch between grid steps.
* ``lowering='portable'`` — the same chain as a plain ``pl.GridSpec``
  whose single grid step walks the squashed 1-D step table with an
  in-kernel ``fori_loop``; the chain-walk state lives in loop *carries*
  (registers/VREGs) instead of a backend-specific scratch memory space, so
  Triton can compile it and GPU takes the single-launch path too. No
  scalar prefetch, no pltpu scratch, no cross-grid-step state — nothing
  Mosaic-only. (A multi-step grid is NOT portable: Triton grid programs
  run concurrently with no cross-step ordering or persistent scratch, so
  the squash moves the chain INSIDE the one step.)

``backends.resolve_lowering`` picks per device kind ('mosaic' on TPU and
under off-accelerator interpret, 'portable' on gpu/cuda/rocm);
``backends.resolve('auto')`` now routes every Pallas-capable device kind to
this kernel.

The Mosaic chain

    diag block 0 -> panel 0 -> diag block 1 -> panel 1 -> ...

maps onto a 1-D grid walking a ``PrefetchScalarGridSpec`` index table of the
upper-triangular tile pairs ``(p, t)`` in row-major order
(``np.triu_indices``): exactly ``nP(nP+1)/2`` steps, each one real work —

* step with ``t == p`` — the serial diagonal phase on block ``p``: runs the
  hyperbolic recurrence, writes the updated diagonal tile, and parks the
  rotation coefficients ``(c, s)`` and the GEMM transform ``T`` in VMEM
  scratch, where they stay for the rest of the row — never touching HBM.
* step with ``t > p``  — applies the parked transform to column tile ``t``
  of the off-diagonal panel (GEMM on the MXU by default, or the paper's
  element-wise rotation chain with ``panel_apply='paper'``).

The scalar-prefetched tables feed the BlockSpec index maps, so the pipeline
prefetches exactly the tiles the chain visits — the earlier rectangular
``(nP, nP)`` grid (kept as ``grid_mode='rect'`` for comparison) instead
clamped ~nP²/2 out-of-range steps onto the trailing tile as empty kernel
invocations. Same single launch either way; the squash removes the no-op
grid steps themselves.

The running ``V^T`` is the only state carried *across* rows ``p``; it lives
in a ``(k, n)`` VMEM scratch buffer for the entire launch (loaded once at
step 0), so the HBM traffic per panel is exactly one L-tile read + one
L-tile write — the paper's O(n k) per-panel (c, s) upload and V round-trip
disappear entirely.

Correctness of the pipelining: L's row-panels are disjoint across ``p`` (a
step of row ``p`` reads and writes only row-panel ``p``), and all
cross-panel coupling flows through the VMEM-resident ``V^T``; therefore no
grid step ever reads an HBM tile that an earlier step wrote, and Pallas's
input prefetch (fetching step i+1's block during step i) can never observe
stale data.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# The in-kernel hyperbolic recurrence and rotation-chain apply live in ONE
# place, shared with the per-panel kernels (see the note in cholupdate.py).
from repro.core.precision import Precision
from repro.kernels.cholupdate import apply_rotations, diag_recurrence

GRID_MODES = ("indexed", "rect")

# Trace-time instrumentation: pallas_call constructions per lowering. The
# per-lowering analogue of ``repro.kernels.sharded.launches_traced`` — tests
# assert the portable path really traced a portable kernel (and exactly one
# per rank-k update). Since PR 9 the count lives in the ``repro.obs``
# registry (series ``repro.kernels.launches{lowering=...,module=fused}``);
# ``lowerings_traced`` is a thin read-back shim, so the registry snapshot
# and the legacy dict can never disagree.
from repro.obs import metrics as _obs_metrics


def _count_lowering(lowering: str) -> None:
    _obs_metrics.counter("repro.kernels.launches", module="fused",
                         lowering=lowering).inc()


def lowerings_traced() -> dict:
    """Cumulative pallas_call constructions keyed by lowering name."""
    return {name: int(_obs_metrics.value("repro.kernels.launches",
                                         module="fused", lowering=name))
            for name in ("mosaic", "portable")}


def _fused_body(p, t, vt_in, l_ref, l_out, vt_s, t_s, c_s, s_s, *,
                first, diag_pred, apply_pred, sigma, panel, k, panel_apply,
                accum_dtype):
    """Shared kernel body: one chain step on tile (p, t), t >= p.

    Precision split (DESIGN.md §8): ``l_ref``/``l_out`` and the running
    ``V^T`` scratch carry the STORAGE dtype (bf16 under the low-precision
    policy — these are the HBM-traffic-bound operands); the parked rotation
    state ``(c, s)``/``T`` scratch and every computation carry the
    ACCUMULATION dtype (fp32). ``accum_dtype=None`` is the single-dtype
    legacy path, bit-for-bit.
    """

    @pl.when(first)
    def _load_vt():
        # V^T enters VMEM exactly once, at the first grid step, and never
        # returns to HBM: it is dead state once the factor is updated.
        vt_s[...] = vt_in[...]

    @pl.when(diag_pred)
    def _diag():
        D = l_ref[...]
        vtd = vt_s[:, pl.dslice(p * panel, panel)]
        D_new, c, s, T = diag_recurrence(D, vtd, sigma=sigma, rows=panel, k=k,
                                         accum_dtype=accum_dtype)
        l_out[...] = D_new.astype(l_out.dtype)
        # Park the panel transform for the rest of this grid row — in the
        # accumulation dtype (the scratch buffers are allocated fp32).
        c_s[...] = c.astype(c_s.dtype)
        s_s[...] = s.astype(s_s.dtype)
        t_s[...] = T.astype(t_s.dtype)
        # The recurrence annihilates this V^T slab.
        vt_s[:, pl.dslice(p * panel, panel)] = jnp.zeros_like(vtd)

    @pl.when(apply_pred)
    def _apply():
        R = l_ref[...]
        vtt = vt_s[:, pl.dslice(t * panel, panel)]
        if panel_apply == "gemm":
            acc_t = accum_dtype or jnp.float32
            T = t_s[...]
            if R.dtype != T.dtype:
                # bf16 tiles under fp32 transform: upcast in VREGs; the HBM
                # tile and the V^T scratch slab stay narrow.
                R = R.astype(T.dtype)
                vtt = vtt.astype(T.dtype)
            t_rr, t_rv = T[:panel, :panel], T[:panel, panel:]
            t_vr, t_vv = T[panel:, :panel], T[panel:, panel:]
            acc = jnp.dot(t_rr, R, preferred_element_type=acc_t)
            acc += jnp.dot(t_rv, vtt, preferred_element_type=acc_t)
            accv = jnp.dot(t_vr, R, preferred_element_type=acc_t)
            accv += jnp.dot(t_vv, vtt, preferred_element_type=acc_t)
            R_new = acc
            vt_new = accv
        else:
            R_new, vt_new = apply_rotations(
                R, vtt, c_s[...], s_s[...], sigma=sigma, rows=panel, k=k,
                accum_dtype=accum_dtype,
            )
        l_out[...] = R_new.astype(l_out.dtype)
        vt_s[:, pl.dslice(t * panel, panel)] = vt_new.astype(vt_s.dtype)


def _indexed_kernel(p_tab, t_tab, vt_in, l_ref, l_out, vt_s, t_s, c_s, s_s,
                    *, sigma, panel, k, panel_apply, accum_dtype):
    i = pl.program_id(0)
    p, t = p_tab[i], t_tab[i]
    # The table holds only valid chain steps: t == p is a diagonal phase,
    # t > p a panel apply — no clamped no-ops to skip.
    _fused_body(p, t, vt_in, l_ref, l_out, vt_s, t_s, c_s, s_s,
                first=(i == 0), diag_pred=(t == p), apply_pred=(t > p),
                sigma=sigma, panel=panel, k=k, panel_apply=panel_apply,
                accum_dtype=accum_dtype)


def _rect_kernel(vt_in, l_ref, l_out, vt_s, t_s, c_s, s_s, *,
                 sigma, panel, k, n_tiles, panel_apply, accum_dtype):
    p = pl.program_id(0)
    j = pl.program_id(1)
    t = p + j
    # Out-of-range steps (t >= n_tiles) fail both predicates: empty kernel
    # invocations on the clamped trailing tile.
    _fused_body(p, t, vt_in, l_ref, l_out, vt_s, t_s, c_s, s_s,
                first=(p == 0) & (j == 0), diag_pred=(j == 0),
                apply_pred=(j > 0) & (t < n_tiles),
                sigma=sigma, panel=panel, k=k, panel_apply=panel_apply,
                accum_dtype=accum_dtype)


def _portable_kernel(p_tab, t_tab, v_tab, vt_in, l_ref, l_out, *,
                     sigma, panel, k, panel_apply, accum_dtype,
                     has_invalid):
    """Portable lowering: the whole chain in ONE grid step, state in carries.

    Triton grid programs execute concurrently — there is no cross-step
    ordering and no persistent scratch — so the dependency chain cannot
    span grid steps the way the Mosaic lowering's does. Instead the single
    step walks the squashed 1-D step table with an in-kernel ``fori_loop``
    whose carry IS the chain-walk state: the running ``V^T`` plus the
    parked transform ``T`` and rotation ``(c, s)`` of the current grid row.
    The same precision split as the Mosaic body applies: the ``V^T`` carry
    and the L tiles move in the storage dtype, ``T``/``(c, s)`` and all
    computation in the accumulation dtype.

    Tile reads always come from ``l_ref`` (original data — every chain tile
    is written exactly once, by its own step, and read only by that step),
    tile writes go to ``l_out``, which starts as a copy of the input so
    off-chain (strictly-lower / padded) regions pass through unchanged.

    ``has_invalid`` (static) marks tables with clamped no-op entries (the
    'rect' grid mode): those steps skip the store and keep the old carry.
    """
    l_out[...] = l_ref[...]
    state_dtype = accum_dtype or l_ref.dtype
    pk = panel + k
    n_steps = p_tab.shape[0]

    def _diag_step(tile, slab, T, c, s):
        del T, c, s
        D_new, c_new, s_new, T_new = diag_recurrence(
            tile, slab, sigma=sigma, rows=panel, k=k,
            accum_dtype=accum_dtype)
        # The recurrence annihilates this V^T slab.
        return (D_new.astype(l_out.dtype), jnp.zeros_like(slab),
                T_new.astype(state_dtype), c_new.astype(state_dtype),
                s_new.astype(state_dtype))

    def _apply_step(tile, slab, T, c, s):
        R, vtt = tile, slab
        if panel_apply == "gemm":
            acc_t = accum_dtype or jnp.float32
            if R.dtype != T.dtype:
                # bf16 tiles under fp32 transform: upcast in VREGs; the HBM
                # tile and the V^T carry stay narrow.
                R = R.astype(T.dtype)
                vtt = vtt.astype(T.dtype)
            t_rr, t_rv = T[:panel, :panel], T[:panel, panel:]
            t_vr, t_vv = T[panel:, :panel], T[panel:, panel:]
            R_new = jnp.dot(t_rr, R, preferred_element_type=acc_t)
            R_new += jnp.dot(t_rv, vtt, preferred_element_type=acc_t)
            vt_new = jnp.dot(t_vr, R, preferred_element_type=acc_t)
            vt_new += jnp.dot(t_vv, vtt, preferred_element_type=acc_t)
        else:
            R_new, vt_new = apply_rotations(
                R, vtt, c, s, sigma=sigma, rows=panel, k=k,
                accum_dtype=accum_dtype)
        return (R_new.astype(l_out.dtype), vt_new.astype(slab.dtype),
                T, c, s)

    def step(i, carry):
        vt, T, c, s = carry
        p, t = p_tab[i], t_tab[i]
        r0, c0_ = p * panel, t * panel
        tile = l_ref[pl.dslice(r0, panel), pl.dslice(c0_, panel)]
        slab = jax.lax.dynamic_slice_in_dim(vt, c0_, panel, axis=1)
        out_tile, slab_new, T_new, c_new, s_new = jax.lax.cond(
            t == p, _diag_step, _apply_step, tile, slab, T, c, s)
        if has_invalid:
            valid = v_tab[i] > 0

            @pl.when(valid)
            def _store():
                l_out[pl.dslice(r0, panel), pl.dslice(c0_, panel)] = out_tile

            keep = lambda new, old: jnp.where(valid, new, old)
        else:
            l_out[pl.dslice(r0, panel), pl.dslice(c0_, panel)] = out_tile
            keep = lambda new, old: new
        vt = keep(jax.lax.dynamic_update_slice_in_dim(
            vt, slab_new, c0_, axis=1), vt)
        return (vt, keep(T_new, T), keep(c_new, c), keep(s_new, s))

    vt0 = vt_in[...]
    carry0 = (vt0,
              jnp.zeros((pk, pk), state_dtype),
              jnp.zeros((panel, k), state_dtype),
              jnp.zeros((panel, k), state_dtype))
    jax.lax.fori_loop(0, n_steps, step, carry0)


@functools.lru_cache(maxsize=None)
def _pair_tables(n_tiles: int):
    """Static row-major upper-triangular (p, t) index tables — the chain.

    Kept as numpy so the cache holds trace-independent constants (jnp arrays
    created inside a jit trace would leak tracers across calls).
    """
    ps, ts = np.triu_indices(n_tiles)
    return np.asarray(ps, np.int32), np.asarray(ts, np.int32)


@functools.lru_cache(maxsize=None)
def _chain_tables(n_tiles: int, grid_mode: str):
    """(p, t, valid) step tables for the portable in-kernel chain walk.

    'indexed' squashes to exactly the nP(nP+1)/2 chain steps (all valid);
    'rect' keeps the rectangular nP² step count with out-of-range steps
    clamped to the trailing tile and marked invalid — the same no-op
    accounting as the Mosaic rect grid, as loop iterations instead of
    empty kernel invocations.
    """
    if grid_mode == "indexed":
        ps, ts = _pair_tables(n_tiles)
        valid = np.ones_like(ps)
    else:
        ps = np.repeat(np.arange(n_tiles, dtype=np.int32), n_tiles)
        ts = ps + np.tile(np.arange(n_tiles, dtype=np.int32), n_tiles)
        valid = (ts < n_tiles).astype(np.int32)
        ts = np.minimum(ts, n_tiles - 1)
    return ps, ts, np.asarray(valid, np.int32)


@functools.partial(
    jax.jit,
    static_argnames=("sigma", "panel", "panel_apply", "grid_mode", "interpret",
                     "accum_dtype", "lowering"),
)
def _fused_call(L, vt, *, sigma, panel, panel_apply, grid_mode, interpret,
                accum_dtype=None, lowering="mosaic"):
    n_pad = L.shape[0]
    k = vt.shape[0]
    n_tiles = n_pad // panel
    pk = panel + k
    state_dtype = accum_dtype or L.dtype
    if lowering == "portable":
        # ONE grid step; the chain walk is an in-kernel fori_loop over the
        # squashed step table, state in loop carries — nothing Mosaic-only.
        p_tab, t_tab, v_tab = _chain_tables(n_tiles, grid_mode)
        n_steps = int(p_tab.shape[0])
        grid_spec = pl.GridSpec(
            grid=(1,),
            in_specs=[
                pl.BlockSpec((n_steps,), lambda i: (0,)),
                pl.BlockSpec((n_steps,), lambda i: (0,)),
                pl.BlockSpec((n_steps,), lambda i: (0,)),
                pl.BlockSpec((k, n_pad), lambda i: (0, 0)),
                pl.BlockSpec((n_pad, n_pad), lambda i: (0, 0)),
            ],
            out_specs=pl.BlockSpec((n_pad, n_pad), lambda i: (0, 0)),
        )
        _count_lowering("portable")
        out = pl.pallas_call(
            functools.partial(
                _portable_kernel, sigma=sigma, panel=panel, k=k,
                panel_apply=panel_apply, accum_dtype=accum_dtype,
                has_invalid=(grid_mode == "rect")),
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((n_pad, n_pad), L.dtype),
            interpret=interpret,
        )(jnp.asarray(p_tab), jnp.asarray(t_tab), jnp.asarray(v_tab), vt, L)
        return jnp.triu(out)
    if lowering != "mosaic":
        raise ValueError(
            f"lowering must be 'mosaic' or 'portable' here, got {lowering!r}")
    scratch_shapes = [
        # The running V^T carries the STORAGE dtype — it is panel traffic,
        # the bandwidth-bound quantity; the parked rotation state carries
        # the ACCUMULATION dtype (fp32 under the low-precision policy).
        pltpu.VMEM((k, n_pad), L.dtype),      # running V^T (whole launch)
        pltpu.VMEM((pk, pk), state_dtype),    # transform T   (one grid row)
        pltpu.VMEM((panel, k), state_dtype),  # rotations c   (one grid row)
        pltpu.VMEM((panel, k), state_dtype),  # rotations s   (one grid row)
    ]
    kw = dict(sigma=sigma, panel=panel, k=k, panel_apply=panel_apply,
              accum_dtype=accum_dtype)
    if grid_mode == "indexed":
        # 1-D grid over exactly the nP(nP+1)/2 chain steps; the scalar-
        # prefetched tables drive both the body and the BlockSpec index maps.
        p_tab, t_tab = _pair_tables(n_tiles)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(int(p_tab.shape[0]),),
            in_specs=[
                pl.BlockSpec((k, n_pad), lambda i, pt, tt: (0, 0)),
                pl.BlockSpec((panel, panel),
                             lambda i, pt, tt: (pt[i], tt[i])),
            ],
            out_specs=pl.BlockSpec((panel, panel),
                                   lambda i, pt, tt: (pt[i], tt[i])),
            scratch_shapes=scratch_shapes,
        )
        _count_lowering("mosaic")
        out = pl.pallas_call(
            functools.partial(_indexed_kernel, **kw),
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((n_pad, n_pad), L.dtype),
            interpret=interpret,
        )(jnp.asarray(p_tab), jnp.asarray(t_tab), vt, L)
    else:
        last = n_tiles - 1

        def l_index(p, j):
            # Clamp no-op steps (p + j past the trailing edge) onto the last
            # valid tile of the row: same block index -> the pipeline neither
            # refetches nor reflushes, and the kernel body skips them.
            return (p, jnp.minimum(p + j, last))

        _count_lowering("mosaic")
        out = pl.pallas_call(
            functools.partial(_rect_kernel, n_tiles=n_tiles, **kw),
            grid=(n_tiles, n_tiles),
            in_specs=[
                pl.BlockSpec((k, n_pad), lambda p, j: (0, 0)),  # V^T: once
                pl.BlockSpec((panel, panel), l_index),          # L tile
            ],
            out_specs=pl.BlockSpec((panel, panel), l_index),
            out_shape=jax.ShapeDtypeStruct((n_pad, n_pad), L.dtype),
            scratch_shapes=scratch_shapes,
            interpret=interpret,
        )(vt, L)
    # Only the upper block-triangle is ever written; the strictly-lower tiles
    # of the output buffer are untouched garbage by design.
    return jnp.triu(out)


def chol_update_fused(
    L,
    V,
    *,
    sigma: int = 1,
    panel: int = 256,
    panel_apply: str = "gemm",
    grid_mode: str = "indexed",
    lowering: str = "auto",
    interpret=None,
    precision=None,
):
    """Rank-k up/down-date in a single fused ``pallas_call``.

    Args:
      L: (n, n) upper-triangular factor, ``A = L^T L``.
      V: (n, k) or (n,) modification matrix.
      sigma: +1 update, -1 downdate.
      panel: row-panel (= grid tile) size.
      panel_apply: 'gemm' (MXU transform GEMM, default) or 'paper' (the
        paper's element-wise rotation chain, using the parked (c, s)).
      grid_mode: 'indexed' (1-D grid over a scalar-prefetch index table of
        the nP(nP+1)/2 chain steps, default) or 'rect' (the clamped
        rectangular (nP, nP) grid, kept for comparison). Both modes exist
        under both lowerings: the portable lowering walks the same tables
        as loop steps instead of grid steps.
      lowering: 'mosaic' (PrefetchScalarGridSpec + pltpu.VMEM scratch, the
        TPU spec), 'portable' (plain pl.GridSpec, chain state in loop
        carries — compiles under Triton), or 'auto' (default: resolve by
        device kind via ``backends.resolve_lowering`` — 'portable' on
        gpu/cuda/rocm, 'mosaic' elsewhere).
      interpret: force Pallas interpret mode. ``None`` (the default) auto-
        detects per the RESOLVED lowering: the mosaic spec compiles on TPU
        only, the portable spec also on GPU. An explicit value — including
        ``False`` — always wins over the auto-detect.
      precision: storage/accum policy (``Precision``, 'bf16', or None).
        Under 'bf16' the L-tiles and the running V^T (scratch or carry) are
        bfloat16 (halving the per-tile HBM bytes of this bandwidth-bound
        kernel) while the diagonal recurrence, (c, s), and T stay fp32.

    Returns:
      The updated upper-triangular factor, same shape as ``L``, in the
      policy's storage dtype (``L.dtype`` when no policy is given).
    """
    if sigma not in (1, -1):
        raise ValueError(f"sigma must be +1 or -1, got {sigma}")
    if panel_apply not in ("gemm", "paper"):
        raise ValueError(f"panel_apply must be 'gemm' or 'paper', got {panel_apply!r}")
    if grid_mode not in GRID_MODES:
        raise ValueError(f"grid_mode must be one of {GRID_MODES}, got {grid_mode!r}")
    from repro.core.backends import default_interpret, resolve_lowering

    lowering = resolve_lowering(lowering)
    if interpret is None:
        interpret = default_interpret(lowering=lowering)
    precision = Precision.parse(precision)
    accum_dtype = None
    if precision is not None:
        L = precision.cast_storage(L)
        V = precision.cast_storage(V)
        accum_dtype = jnp.dtype(precision.accum)
    squeeze = V.ndim == 1
    if squeeze:
        V = V[:, None]
    from repro.core import blocked  # local import: kernels must not cycle core

    L_pad, V_pad, n = blocked._pad_to_panels(L, V, panel)
    out = _fused_call(
        L_pad,
        V_pad.T,
        sigma=sigma,
        panel=panel,
        panel_apply=panel_apply,
        grid_mode=grid_mode,
        interpret=bool(interpret),
        accum_dtype=accum_dtype,
        lowering=lowering,
    )
    return out[:n, :n]


def launch_count(n: int, panel: int, *, method: str) -> int:
    """Device-kernel launches issued per up/down-date, by method.

    The quantity the paper pays per panel and this module's reason to exist:

    * ``fused``        — 1, always (the grid walks the dependency chain).
    * ``pallas``/``pallas_gemm`` — one panel-apply launch per panel that has a
      trailing block, i.e. ``n_panels - 1`` (0 for a single-panel problem:
      the diagonal phase runs as inlined jnp inside the same jit, so it adds
      traced ops, not launches).
    * ``pallas_2phase`` — the paper's own accounting: a diagonal kernel AND a
      panel kernel per panel (what ``diag_block`` + ``panel_apply_*`` would
      issue if both phases were separate device kernels).
    """
    n_panels = -(-n // panel)
    if method == "fused":
        return 1
    if method in ("pallas", "pallas_gemm"):
        return n_panels - 1
    if method == "pallas_2phase":
        return n_panels + (n_panels - 1)
    raise ValueError(f"unknown method {method!r}")


def bytes_per_update(n: int, panel: int, k: int, *, storage_dtype,
                     grid_mode: str = "indexed") -> int:
    """HBM bytes one fused rank-k update moves, by storage dtype.

    The paper's bandwidth-bound accounting: every chain step reads one
    ``panel x panel`` L-tile and writes it back (the indexed grid visits
    exactly the ``nP(nP+1)/2`` upper-triangular tiles; the rect grid's
    clamped steps move no extra bytes), plus the one-time ``(k, n)`` V^T
    load at step 0. The rotation state never touches HBM (VMEM scratch), so
    it does not appear here — which is exactly why bf16 tiles halve this
    number while fp32 state costs nothing in traffic.
    """
    isize = int(np.dtype(jnp.dtype(storage_dtype)).itemsize)
    n_tiles = -(-n // panel)
    tiles = n_tiles * (n_tiles + 1) // 2
    if grid_mode not in GRID_MODES:
        raise ValueError(f"grid_mode must be one of {GRID_MODES}, got {grid_mode!r}")
    l_traffic = 2 * tiles * panel * panel * isize  # read + write per tile
    vt_traffic = k * (n_tiles * panel) * isize     # V^T: loaded once
    return l_traffic + vt_traffic


def grid_steps(n: int, panel: int, *, grid_mode: str = "indexed") -> int:
    """Grid steps per launch: the squash's win over the rectangular grid.

    'indexed' walks exactly the nP(nP+1)/2 chain steps; 'rect' pays nP² with
    ~half clamped to no-ops (empty kernel invocations, zero HBM traffic).
    """
    n_tiles = -(-n // panel)
    if grid_mode == "indexed":
        return n_tiles * (n_tiles + 1) // 2
    if grid_mode == "rect":
        return n_tiles * n_tiles
    raise ValueError(f"grid_mode must be one of {GRID_MODES}, got {grid_mode!r}")
