"""Single-launch Pallas kernel for the block-tridiagonal rank-k
modification (DESIGN.md §12).

The dense fused kernel (``repro.kernels.fused``) walks L's panel dependency
chain — diag block p, then every trailing tile of row p — inside ONE
``pallas_call``. For a block-bidiagonal factor the chain is radically
shorter: block row j has exactly ONE trailing tile, the coupling block
``off[j] = U[j, j+1]``. A rank-k row hitting block row j therefore touches
only blocks (j, j) and (j, j+1):

    for j = 0 .. nb-1:
        diag[j], T_j   <- hyperbolic recurrence on (diag[j], V^T slab j)
        [off[j]; w_{j+1}] <- T_j @ [off[j]; w_{j+1}]     (one b×b GEMM pair)

The second line is what carries the cascade: rotating the coupling block
feeds block row j's rotations into the ``V^T`` slab of block j+1, which the
next chain step consumes. Work is O(k·b²·nb), memory O(n·b) — n never
appears squared anywhere, which IS the paper's O(n) scaling story realised
(the dense path's O(n²) factor bytes were the cap, not the kernel).

Why skipping the other trailing tiles is exact (the dependency argument):
tiles ``U[j, t]`` with ``t > j+1`` are zero by structure, and the ``V^T``
slabs beyond j+1 hold only columns whose support has not been reached yet
— their rotation coefficients at block j are identities (``v = 0 -> c = 1,
s = 0``), so the dense rule's action on those slabs is the identity map.
This requires every COLUMN of V to be supported inside one adjacent block
pair (``repro.core.structure.assert_blocklocal``); wider support would
generate fill-in no block-bidiagonal factor can represent at all.

Lowering: one portable spec only (plain ``pl.GridSpec``, grid=(1,), the
chain as an in-kernel ``fori_loop`` with the running ``V^T`` in the loop
carry — the same shape as the fused kernel's portable lowering), so it
compiles under both Mosaic and Triton; there is no Mosaic-specific variant
to pick, hence no ``lowering=`` option. Instrumentation mirrors
``fused.lowerings_traced``: ``launches_traced()`` counts pallas_call
constructions, and the conformance suite pins ONE per sign block.

Precision (DESIGN.md §8): the block tiles and the ``V^T`` carry move in the
STORAGE dtype (bf16 under the low-precision policy); the recurrence, the
transform ``T`` and GEMM accumulation run in the ACCUMULATION dtype (fp32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.precision import Precision
from repro.core.structure import BlockTriDiagStorage
# ONE in-kernel copy of the hyperbolic recurrence, shared with the per-panel
# and fused kernels (see the note in repro.kernels.cholupdate).
from repro.kernels.cholupdate import diag_recurrence

# Trace-time instrumentation: pallas_call constructions (each is one device
# launch per execution). Tests pin this to 1 per sign block. Since PR 9 the
# count lives in the ``repro.obs`` registry (series
# ``repro.kernels.launches{module=blocktridiag}``); ``launches_traced`` is a
# thin read-back shim.
from repro.obs import metrics as _obs_metrics


def launches_traced() -> int:
    """Cumulative pallas_call constructions of the block-chain kernel."""
    return int(_obs_metrics.value("repro.kernels.launches",
                                  module="blocktridiag"))


def _btd_kernel(vt_in, d_ref, o_ref, d_out, o_out, *, sigma, block, k,
                nblocks, accum_dtype):
    """The whole block chain in ONE grid step; ``V^T`` in the loop carry.

    Block arrays arrive stacked 2-D — ``d_ref``/``o_ref``: (nb·b, b) with
    block j at rows [j·b, (j+1)·b); ``vt_in``: (k, (nb+1)·b) with a zero
    tail slab. ``o_ref`` row-block nb-1 is a zero pad block, so every chain
    step runs the same diag+apply pair (the last apply is a zero GEMM) —
    no in-loop branching.
    """
    acc_t = accum_dtype or jnp.float32

    def step(j, vt):
        r0 = j * block
        D = d_ref[pl.dslice(r0, block), :]
        slab = jax.lax.dynamic_slice_in_dim(vt, r0, block, axis=1)
        D_new, _c, _s, T = diag_recurrence(
            D, slab, sigma=sigma, rows=block, k=k, accum_dtype=accum_dtype)
        d_out[pl.dslice(r0, block), :] = D_new.astype(d_out.dtype)
        # The recurrence annihilated this slab.
        vt = jax.lax.dynamic_update_slice_in_dim(
            vt, jnp.zeros_like(slab), r0, axis=1)
        # Apply T to the single trailing tile + the next V^T slab: the
        # cascade hand-off to block row j+1.
        R = o_ref[pl.dslice(r0, block), :]
        nxt = jax.lax.dynamic_slice_in_dim(vt, r0 + block, block, axis=1)
        if R.dtype != T.dtype:
            # bf16 tiles under fp32 transform: upcast in VREGs; the HBM
            # tiles and the V^T carry stay narrow.
            R = R.astype(T.dtype)
            nxt = nxt.astype(T.dtype)
        t_rr, t_rv = T[:block, :block], T[:block, block:]
        t_vr, t_vv = T[block:, :block], T[block:, block:]
        R_new = jnp.dot(t_rr, R, preferred_element_type=acc_t)
        R_new += jnp.dot(t_rv, nxt, preferred_element_type=acc_t)
        w_new = jnp.dot(t_vr, R, preferred_element_type=acc_t)
        w_new += jnp.dot(t_vv, nxt, preferred_element_type=acc_t)
        o_out[pl.dslice(r0, block), :] = R_new.astype(o_out.dtype)
        return jax.lax.dynamic_update_slice_in_dim(
            vt, w_new.astype(vt.dtype), r0 + block, axis=1)

    jax.lax.fori_loop(0, nblocks, step, vt_in[...])


@functools.partial(
    jax.jit, static_argnames=("sigma", "block", "interpret", "accum_dtype"))
def _btd_call(d2, o2, vt, *, sigma, block, interpret, accum_dtype=None):
    nb = d2.shape[0] // block
    wv = vt.shape[1]
    k = vt.shape[0]
    grid_spec = pl.GridSpec(
        grid=(1,),
        in_specs=[
            pl.BlockSpec((k, wv), lambda i: (0, 0)),
            pl.BlockSpec(d2.shape, lambda i: (0, 0)),
            pl.BlockSpec(o2.shape, lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec(d2.shape, lambda i: (0, 0)),
            pl.BlockSpec(o2.shape, lambda i: (0, 0)),
        ],
    )
    _obs_metrics.counter("repro.kernels.launches",
                         module="blocktridiag").inc()
    return pl.pallas_call(
        functools.partial(_btd_kernel, sigma=sigma, block=block, k=k,
                          nblocks=nb, accum_dtype=accum_dtype),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(d2.shape, d2.dtype),
            jax.ShapeDtypeStruct(o2.shape, o2.dtype),
        ],
        interpret=interpret,
    )(vt, d2, o2)


def chol_update_blocktridiag(S, V, *, sigma: int = 1, interpret=None,
                             precision=None, **_ignored):
    """Rank-k up/down-date of a block-bidiagonal factor, ONE pallas_call.

    Args:
      S: ``BlockTriDiagStorage`` — (nb, b, b) diag + (nb-1, b, b) off.
      V: (n, k) or (n,) modification; every column must be supported inside
        one adjacent block-row pair (``structure.assert_blocklocal`` — the
        contract cannot be checked on traced values).
      sigma: +1 update, -1 downdate.
      interpret: force Pallas interpret mode; ``None`` auto-detects via
        ``backends.default_interpret()`` (the portable-shape policy: the
        kernel compiles on every Pallas-capable device kind).
      precision: storage/accum policy ('bf16', a ``Precision``, or None).

    Returns:
      The modified ``BlockTriDiagStorage`` (storage dtype of the policy).
    """
    if sigma not in (1, -1):
        raise ValueError(f"sigma must be +1 or -1, got {sigma}")
    from repro.core.backends import default_interpret

    if interpret is None:
        interpret = default_interpret()
    precision = Precision.parse(precision)
    accum_dtype = None
    if precision is not None:
        S = precision.cast_storage(S)
        V = precision.cast_storage(V)
        accum_dtype = jnp.dtype(precision.accum)
    if V.ndim == 1:
        V = V[:, None]
    nb, b = S.nblocks, S.block
    k = V.shape[1]
    # Stack blocks 2-D for the kernel refs; pad one zero off-block and one
    # zero V^T tail slab so the last chain step is a regular (zero) apply.
    d2 = S.diag.reshape(nb * b, b)
    o2 = jnp.concatenate(
        [S.off, jnp.zeros((1, b, b), S.off.dtype)], axis=0).reshape(nb * b, b)
    vt = jnp.pad(V.T, ((0, 0), (0, b)))
    d_new, o_new = _btd_call(d2, o2, vt, sigma=sigma, block=b,
                             interpret=bool(interpret),
                             accum_dtype=accum_dtype)
    return BlockTriDiagStorage(
        d_new.reshape(nb, b, b),
        o_new.reshape(nb, b, b)[:nb - 1])


# ---------------------------------------------------------------------------
# Accounting (the BENCH_blocktridiag.json quantities)
# ---------------------------------------------------------------------------


def launch_count() -> int:
    """Device launches per rank-k modification: always 1 (one sign block)."""
    return 1


def bytes_per_update(nb: int, b: int, k: int, *, storage_dtype) -> int:
    """HBM bytes one structured rank-k update moves — O(n·b), not O(n²).

    Every diag/off block is read once and written once (the padded zero
    off-block included — it rides the same stacked ref), plus the one-time
    ``(k, (nb+1)·b)`` V^T load. Compare ``fused.bytes_per_update(n=nb·b)``:
    the dense kernel's tile traffic is O(n²) at matched n.
    """
    isize = int(np.dtype(jnp.dtype(storage_dtype)).itemsize)
    tile_traffic = 2 * (nb + nb) * b * b * isize  # diag + padded off, r/w
    vt_traffic = k * (nb + 1) * b * isize         # V^T: loaded once
    return tile_traffic + vt_traffic


def factor_bytes(nb: int, b: int, *, storage_dtype) -> int:
    """Resident factor bytes: (2·nb - 1) b² elements — the O(n·b) claim."""
    isize = int(np.dtype(jnp.dtype(storage_dtype)).itemsize)
    return (2 * nb - 1) * b * b * isize
