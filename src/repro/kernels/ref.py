"""Pure-jnp oracles for the Pallas kernels (re-exports from core.blocked).

Kernel tests compare each Pallas kernel in interpret mode against these:

* ``panel_apply_paper(R, vt, c, s, sigma)``  <-> kernels.cholupdate.panel_apply_paper
* ``panel_apply_gemm(R, vt, T)``             <-> kernels.cholupdate.panel_apply_gemm
* ``panel_diag(D, vtd, sigma, with_transform=True)`` <-> kernels.cholupdate.diag_block
"""
from repro.core.blocked import panel_apply_gemm, panel_apply_paper, panel_diag

__all__ = ["panel_apply_paper", "panel_apply_gemm", "panel_diag"]
