"""One-launch-per-shard panel kernel for the column-sharded driver.

The distributed fused composition (DESIGN.md §7) splits a sharded rank-k
up/down-date into:

* a **chain phase** (jnp, in ``repro.core.distributed``): the serial
  diagonal recurrences, replicated from one psum-gathered stacked block per
  panel, producing the per-panel transforms ``T^(p)``, updated diagonal
  blocks ``D~^(p)``, and the running ``V^T`` snapshot entering each panel;

* a **panel phase** (this kernel): every off-diagonal tile update
  ``L~[p, g] = T_rr^(p) L[p, g] + T_rv^(p) V^T_in^(p)[:, g]`` — independent
  across tiles because each row-panel of L is read in its original state
  (row-panels are written exactly once, by their own panel step) and all
  sequential coupling was captured in the chain-phase outputs.

That independence lets ONE ``pallas_call`` per shard cover the entire
update — one launch per shard per rank-k update, against the per-panel
driver's launch-per-panel dispatch pattern. The grid is ``(n_panels,
local_tiles)``; which branch a step takes (transform / diagonal writeback /
zero fill of the strictly-lower tiles) depends on the device's global tile
offset, fed in through ``PrefetchScalarGridSpec`` (the Mosaic lowering) so
the comparison against the scalar-prefetched offset is available to every
grid step without an HBM round-trip — or, under ``lowering='portable'``,
as a plain ``(1,)`` operand in a ``pl.GridSpec`` Triton can compile; the
tiles are independent, so the multi-step grid is parallel-safe and GPU
keeps the same one launch per shard. The chain-phase products ride as VMEM
operands indexed by the grid's panel coordinate.

**Batched fleets (DESIGN.md §10).** A ``(B, n, w_loc)`` shard of a stacked
fleet folds the batch into the SAME launch: the grid becomes
``(B, n_panels, local_tiles)`` and every block spec gains a leading batch
coordinate — B fleet members' whole updates still cost one ``pallas_call``
per shard, so launch count scales with shards (and sign blocks), never
with B. This is the composition the serving fleet needs for per-user
factors that outgrow one device.

``launches_traced()`` exposes the instrumentation counter benchmarks and
tests assert the one-launch claim with (the sharded analogue of
``repro.kernels.fused.launch_count``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Trace-time instrumentation: how many pallas_call sites this module has
# built. Under SPMD shard_map one traced call == one launch on every shard,
# so the per-update delta IS the launches-per-shard-per-update count. Since
# PR 9 the count lives in the ``repro.obs`` registry (series
# ``repro.kernels.launches{lowering=...,module=sharded}``);
# ``launches_traced`` is a thin read-back shim summing both lowerings.
from repro.obs import metrics as _obs_metrics


def launches_traced() -> int:
    """Cumulative pallas_call constructions (see module docstring)."""
    return sum(int(_obs_metrics.value("repro.kernels.launches",
                                      module="sharded", lowering=lo))
               for lo in ("mosaic", "portable"))


def _panel_kernel(off_ref, t_ref, d_ref, vt_ref, l_ref, l_out, *, panel,
                  accum_dtype=None, batched=False):
    # Grid: (n_panels, local_tiles), with a leading batch coordinate when
    # a stacked fleet shard rides the same launch. The batch member is
    # fully selected by the block specs, so the kernel body only has to
    # skip the leading singleton block axis.
    base = 1 if batched else 0
    p = pl.program_id(base)
    t = pl.program_id(base + 1)
    g = off_ref[0] + t  # global tile index of local tile t

    def _blk(ref):
        return ref[0, 0] if batched else ref[0]

    def _tile(ref):
        return ref[0] if batched else ref[...]

    def _store(val):
        if batched:
            l_out[0] = val
        else:
            l_out[...] = val

    @pl.when(p < g)
    def _apply():
        acc_t = accum_dtype or jnp.float32
        T = _blk(t_ref)
        R = _tile(l_ref)
        vtt = _blk(vt_ref)
        if R.dtype != T.dtype:
            # Low-precision storage policy: bf16 shard tiles / V^T snapshots
            # under fp32 chain-phase transforms — upcast in VREGs, accumulate
            # in the policy's accum dtype, store back narrow (DESIGN.md §8).
            R = R.astype(T.dtype)
            vtt = vtt.astype(T.dtype)
        acc = jnp.dot(T[:panel, :panel], R, preferred_element_type=acc_t)
        acc += jnp.dot(T[:panel, panel:], vtt, preferred_element_type=acc_t)
        _store(acc.astype(l_out.dtype))

    @pl.when(p == g)
    def _diag():
        # The chain phase already ran the recurrence (in the accumulation
        # dtype); write its result back in the shard's storage dtype.
        _store(_blk(d_ref).astype(l_out.dtype))

    @pl.when(p > g)
    def _zero():
        # Strictly-lower tiles of the column shard hold zeros by convention.
        _store(jnp.zeros(_tile(l_ref).shape, l_out.dtype))


def panel_apply_sharded(L_loc, T_stack, D_stack, vt_stack, *, tile_off,
                        panel: int, interpret: bool, accum_dtype=None,
                        lowering: str = "mosaic"):
    """Apply a whole update's panel phase to one column shard, one launch.

    Args:
      L_loc: (n, w_loc) the device's column shard of the ORIGINAL factor —
        or (B, n, w_loc) for a stacked fleet shard, which folds B into the
        grid of the SAME single launch.
      T_stack: (n_panels, P+k, P+k) chain-phase transforms (replicated) —
        (B, n_panels, P+k, P+k) batched.
      D_stack: (n_panels, P, P) chain-phase updated diagonal blocks —
        (B, n_panels, P, P) batched.
      vt_stack: (n_panels, k, w_loc) running V^T entering each panel —
        (B, n_panels, k, w_loc) batched.
      tile_off: scalar int32 — this device's global tile offset (traced,
        per-device under shard_map; shared by every fleet member).
      panel: tile size P.
      interpret: Pallas interpret mode.
      accum_dtype: GEMM accumulation dtype (None = fp32) — the precision
        policy's accum, honored here exactly as in the chain phase.
      lowering: 'mosaic' (scalar-prefetched tile offset via
        PrefetchScalarGridSpec) or 'portable' (plain pl.GridSpec; the
        offset rides as a regular (1,) operand). Unlike the fused chain,
        the panel-phase tiles are INDEPENDENT — all sequential coupling is
        in the chain-phase operands — so the multi-step grid is safe under
        Triton's concurrent program execution and the portable variant
        keeps the same grid shape and the same ONE launch per shard.

    Returns:
      The fully updated column shard, same shape as ``L_loc``.
    """
    if lowering not in ("mosaic", "portable"):
        raise ValueError(
            f"lowering must be 'mosaic' or 'portable', got {lowering!r}")
    batched = L_loc.ndim == 3
    n, w_loc = L_loc.shape[-2], L_loc.shape[-1]
    n_panels, pk = T_stack.shape[-3], T_stack.shape[-1]
    k = vt_stack.shape[-2]
    nt_loc = w_loc // panel
    portable = lowering == "portable"
    if batched:
        B = L_loc.shape[0]
        grid = (B, n_panels, nt_loc)
        in_specs = [
            pl.BlockSpec((1, 1, pk, pk), lambda b, p, t: (b, p, 0, 0)),
            pl.BlockSpec((1, 1, panel, panel), lambda b, p, t: (b, p, 0, 0)),
            pl.BlockSpec((1, 1, k, panel), lambda b, p, t: (b, p, 0, t)),
            pl.BlockSpec((1, panel, panel), lambda b, p, t: (b, p, t)),
        ]
        out_specs = pl.BlockSpec((1, panel, panel), lambda b, p, t: (b, p, t))
        out_shape = jax.ShapeDtypeStruct((B, n, w_loc), L_loc.dtype)
    else:
        grid = (n_panels, nt_loc)
        in_specs = [
            pl.BlockSpec((1, pk, pk), lambda p, t: (p, 0, 0)),
            pl.BlockSpec((1, panel, panel), lambda p, t: (p, 0, 0)),
            pl.BlockSpec((1, k, panel), lambda p, t: (p, 0, t)),
            pl.BlockSpec((panel, panel), lambda p, t: (p, t)),
        ]
        out_specs = pl.BlockSpec((panel, panel), lambda p, t: (p, t))
        out_shape = jax.ShapeDtypeStruct((n, w_loc), L_loc.dtype)
    if portable:
        # The tile offset becomes a plain leading operand; its block spec
        # pins the whole (1,) array into every grid step.
        off_spec = pl.BlockSpec((1,), (lambda b, p, t: (0,)) if batched
                                else (lambda p, t: (0,)))
        grid_spec = pl.GridSpec(grid=grid, in_specs=[off_spec] + in_specs,
                                out_specs=out_specs)
    else:
        # Mosaic: scalar-prefetch the offset; index maps gain the trailing
        # prefetched-ref argument (ignored — no tile indexing depends on it).
        def _drop_off(fn):
            return lambda *args: fn(*args[:-1])

        in_specs = [pl.BlockSpec(s.block_shape, _drop_off(s.index_map))
                    for s in in_specs]
        out_specs = pl.BlockSpec(out_specs.block_shape,
                                 _drop_off(out_specs.index_map))
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=out_specs,
        )
    _obs_metrics.counter("repro.kernels.launches", module="sharded",
                         lowering=lowering).inc()
    return pl.pallas_call(
        functools.partial(_panel_kernel, panel=panel,
                          accum_dtype=accum_dtype, batched=batched),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(jnp.reshape(tile_off, (1,)).astype(jnp.int32),
      T_stack, D_stack, vt_stack, L_loc)


def launch_count_sharded(n: int, panel: int, *, strategy: str) -> int:
    """Pallas launches per shard per rank-k update, by sharded strategy.

    Independent of the fleet size: a stacked ``(B, n, n)`` fleet folds B
    into the grid of the same launches (DESIGN.md §10).

    * ``fused`` — 1: the whole panel phase is one kernel (this module).
    * ``gemm``/``paper`` — 0: the per-panel jnp driver issues no kernels
      (XLA ops only) — but pays one collective + one traced panel pass per
      panel; the per-panel *kernel* analogue of that dispatch pattern is
      ``n // panel`` launches, which is what the fusion removes.
    """
    if strategy == "fused":
        return 1
    if strategy in ("gemm", "paper"):
        return 0
    raise ValueError(f"unknown strategy {strategy!r}")
