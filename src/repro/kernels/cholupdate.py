"""Pallas TPU kernels for the rank-k Cholesky panel update (paper §4.4).

Three kernels, mirroring the paper's CUDA kernels but re-tiled for the TPU
memory hierarchy (HBM -> VMEM -> VREG) per DESIGN.md §2:

* ``panel_apply_paper``  — faithful port of the paper's off-diagonal kernel:
  one VMEM column-tile per grid step (the CUDA block), rows streamed
  sequentially (the dotted sub-squares), the k rotations chained per element
  (``ElementsPerThread``). The (c, s) panel plays the role of the shared-
  memory staging buffer; the V tile stays resident in VMEM across the row
  loop like the paper keeps V in registers. Bandwidth-bound by construction.

* ``panel_apply_gemm``   — TPU-native adaptation: the P·k rotations of a
  panel are one linear map T ∈ R^{(P+k)×(P+k)}, so the panel update is a
  dense ``T @ [R; V^T]`` on the MXU (arithmetic intensity ~(P+k)/2 instead
  of ~k). The faithful kernel remains the paper baseline; this one is the
  beyond-paper optimization measured in EXPERIMENTS.md §Perf.

* ``diag_block``         — the paper's *CPU phase* moved on-device: the
  serial hyperbolic recurrence over one diagonal block, augmented with an
  identity to emit the transform T. Single grid step, scalar-unit heavy;
  removes the host round-trip the paper pays between panels.

All kernels are validated in ``interpret=True`` mode against the pure-jnp
oracles in ``repro.core.blocked`` (see tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# Value-level kernel math, shared by the per-panel kernels below and the
# fused single-launch kernel (repro.kernels.fused). Hand-rolled with
# fori_loop/dynamic_slice instead of calling repro.core.blocked's scan/.at[]
# versions because Mosaic lowers the former reliably inside kernel bodies;
# this is the ONE in-kernel copy of the hyperbolic recurrence.
# ---------------------------------------------------------------------------


def diag_recurrence(D, vtd, *, sigma: int, rows: int, k: int,
                    accum_dtype=None):
    """Serial diagonal-block recurrence on values, emitting the transform T.

    Same math as ``repro.core.blocked.panel_diag(..., with_transform=True)``:
    the stacked block [D; vtd] is augmented with an identity so the row sweep
    also produces T with ``[R_new; vt_new] = T @ [R; vt]``.
    Returns (D_new, c, s, T).

    ``accum_dtype`` (DESIGN.md §8): the recurrence divides by the running
    diagonal every row, so under a low-precision storage policy the inputs
    are upcast here and the outputs — including the rotation state ``(c, s)``
    and the transform ``T`` — stay in the accumulation dtype; callers
    downcast only what they store back to HBM.
    """
    if accum_dtype is not None:
        D = D.astype(accum_dtype)
        vtd = vtd.astype(accum_dtype)
    pk = rows + k
    S = jnp.concatenate([D, vtd], axis=0)
    S = jnp.concatenate([S, jnp.eye(pk, dtype=S.dtype)], axis=1)

    def row_body(i, carry):
        def m_body(m, inner):
            S, c_acc, s_acc = inner
            row_i = jax.lax.dynamic_slice_in_dim(S, i, 1, axis=0)
            row_v = jax.lax.dynamic_slice_in_dim(S, rows + m, 1, axis=0)
            lii = jax.lax.dynamic_slice_in_dim(row_i, i, 1, axis=1)[0, 0]
            vim = jax.lax.dynamic_slice_in_dim(row_v, i, 1, axis=1)[0, 0]
            w = jnp.sqrt(lii * lii + sigma * vim * vim)
            c = w / lii
            s = vim / lii
            row_i_new = (row_i + sigma * s * row_v) / c
            row_v_new = c * row_v - s * row_i_new
            S = jax.lax.dynamic_update_slice_in_dim(S, row_i_new, i, axis=0)
            S = jax.lax.dynamic_update_slice_in_dim(S, row_v_new, rows + m, axis=0)
            c_acc = jax.lax.dynamic_update_slice(c_acc, c[None, None], (i, m))
            s_acc = jax.lax.dynamic_update_slice(s_acc, s[None, None], (i, m))
            return S, c_acc, s_acc

        return jax.lax.fori_loop(0, k, m_body, carry)

    c0 = jnp.zeros((rows, k), dtype=S.dtype)
    s0 = jnp.zeros((rows, k), dtype=S.dtype)
    S, c_acc, s_acc = jax.lax.fori_loop(0, rows, row_body, (S, c0, s0))
    return jnp.triu(S[:rows, :rows]), c_acc, s_acc, S[:, rows:]


def apply_rotations(R, vt, c, s, *, sigma: int, rows: int, k: int,
                    accum_dtype=None):
    """Element-wise rotation-chain panel apply on values (paper ``Apply``).

    Streams the rows of R, chaining the k rotations per row; the V tile
    stays live across the whole loop (the paper keeps V in registers).
    Returns (R_new, vt_new) — in ``accum_dtype`` when one is given (the
    rotation chain computes there; callers downcast on store).
    """
    if accum_dtype is not None:
        R = R.astype(accum_dtype)
        vt = vt.astype(accum_dtype)
        c = c.astype(accum_dtype)
        s = s.astype(accum_dtype)

    def row_body(i, carry):
        R, vt = carry
        t = jax.lax.dynamic_slice_in_dim(R, i, 1, axis=0)  # one L row

        def m_body(m, inner):
            t, vt = inner
            c_im = jax.lax.dynamic_slice(c, (i, m), (1, 1))
            s_im = jax.lax.dynamic_slice(s, (i, m), (1, 1))
            v_m = jax.lax.dynamic_slice_in_dim(vt, m, 1, axis=0)
            t = (t + sigma * s_im * v_m) / c_im       # paper Apply, line 1
            v_m = c_im * v_m - s_im * t               # paper Apply, line 2
            vt = jax.lax.dynamic_update_slice_in_dim(vt, v_m, m, axis=0)
            return t, vt

        t, vt = jax.lax.fori_loop(0, k, m_body, (t, vt))
        R = jax.lax.dynamic_update_slice_in_dim(R, t, i, axis=0)
        return R, vt

    return jax.lax.fori_loop(0, rows, row_body, (R, vt))


# ---------------------------------------------------------------------------
# Faithful element-wise panel kernel (the paper's GPU kernel).
# ---------------------------------------------------------------------------


def _paper_kernel(c_ref, s_ref, r_ref, vt_ref, r_out, vt_out, *, sigma: int,
                  rows: int, k: int, accum_dtype=None):
    R_new, vt_new = apply_rotations(
        r_ref[...], vt_ref[...], c_ref[...], s_ref[...],
        sigma=sigma, rows=rows, k=k, accum_dtype=accum_dtype,
    )
    # Downcast on store: HBM tiles carry the storage dtype, the chain the
    # accumulation dtype (no-op when the policy is single-dtype).
    r_out[...] = R_new.astype(r_out.dtype)
    vt_out[...] = vt_new.astype(vt_out.dtype)


@functools.partial(
    jax.jit, static_argnames=("sigma", "block_w", "interpret", "accum_dtype")
)
def panel_apply_paper(R, vt, c, s, *, sigma: int, block_w: int = 512,
                      interpret: bool = False, accum_dtype=None):
    """Off-diagonal panel apply, paper-style. R: (P, w); vt: (k, w); c,s: (P, k).

    ``c``/``s`` may be wider than ``R`` (fp32 rotation state over bf16
    tiles); the chain then computes in ``accum_dtype`` and the outputs keep
    ``R``/``vt``'s storage dtype.
    """
    P, w = R.shape
    k = vt.shape[0]
    pad_w = (-w) % block_w
    if pad_w:
        # Zero columns are fixed points of Apply (t = (0 + s·0)/c = 0).
        R = jnp.pad(R, ((0, 0), (0, pad_w)))
        vt = jnp.pad(vt, ((0, 0), (0, pad_w)))
    wp = R.shape[1]
    grid = (wp // block_w,)
    kernel = functools.partial(_paper_kernel, sigma=sigma, rows=P, k=k,
                               accum_dtype=accum_dtype)
    R_new, vt_new = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((P, k), lambda j: (0, 0)),        # c: resident
            pl.BlockSpec((P, k), lambda j: (0, 0)),        # s: resident
            pl.BlockSpec((P, block_w), lambda j: (0, j)),  # L panel tile
            pl.BlockSpec((k, block_w), lambda j: (0, j)),  # V^T tile
        ],
        out_specs=[
            pl.BlockSpec((P, block_w), lambda j: (0, j)),
            pl.BlockSpec((k, block_w), lambda j: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((P, wp), R.dtype),
            jax.ShapeDtypeStruct((k, wp), vt.dtype),
        ],
        interpret=interpret,
    )(c, s, R, vt)
    return R_new[:, :w], vt_new[:, :w]


# ---------------------------------------------------------------------------
# GEMM panel kernel (TPU-native adaptation).
# ---------------------------------------------------------------------------


def _gemm_kernel(t_ref, r_ref, vt_ref, r_out, vt_out, *, rows: int,
                 accum_dtype=None):
    acc_t = accum_dtype or jnp.float32
    T = t_ref[...]          # (P+k, P+k), fully VMEM-resident
    R = r_ref[...]          # (P, bw)
    vt = vt_ref[...]        # (k, bw)
    if R.dtype != T.dtype:
        # Mixed-width operands (fp32 T over bf16 tiles): upcast in VREGs —
        # the HBM tiles stay narrow, which is where the bandwidth win lives.
        R = R.astype(T.dtype)
        vt = vt.astype(T.dtype)
    t_rr = T[:rows, :rows]
    t_rv = T[:rows, rows:]
    t_vr = T[rows:, :rows]
    t_vv = T[rows:, rows:]
    # Two MXU matmuls per output block; accumulation in the accum dtype
    # (fp32 by default — bf16 tiles feed the MXU natively, the products
    # never round below fp32).
    acc = jnp.dot(t_rr, R, preferred_element_type=acc_t)
    acc += jnp.dot(t_rv, vt, preferred_element_type=acc_t)
    r_out[...] = acc.astype(r_out.dtype)
    accv = jnp.dot(t_vr, R, preferred_element_type=acc_t)
    accv += jnp.dot(t_vv, vt, preferred_element_type=acc_t)
    vt_out[...] = accv.astype(vt_out.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_w", "interpret", "accum_dtype")
)
def panel_apply_gemm(R, vt, T, *, block_w: int = 512, interpret: bool = False,
                     accum_dtype=None):
    """Off-diagonal panel apply as one transform GEMM. T: (P+k, P+k).

    ``T`` may be wider than ``R`` (fp32 transform over bf16 tiles); the
    matmuls accumulate in ``accum_dtype`` (fp32 default) either way.
    """
    P, w = R.shape
    k = vt.shape[0]
    pad_w = (-w) % block_w
    if pad_w:
        R = jnp.pad(R, ((0, 0), (0, pad_w)))
        vt = jnp.pad(vt, ((0, 0), (0, pad_w)))
    wp = R.shape[1]
    grid = (wp // block_w,)
    pk = P + k
    kernel = functools.partial(_gemm_kernel, rows=P, accum_dtype=accum_dtype)
    R_new, vt_new = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((pk, pk), lambda j: (0, 0)),      # T: resident
            pl.BlockSpec((P, block_w), lambda j: (0, j)),
            pl.BlockSpec((k, block_w), lambda j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((P, block_w), lambda j: (0, j)),
            pl.BlockSpec((k, block_w), lambda j: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((P, wp), R.dtype),
            jax.ShapeDtypeStruct((k, wp), vt.dtype),
        ],
        interpret=interpret,
    )(T, R, vt)
    return R_new[:, :w], vt_new[:, :w]


# ---------------------------------------------------------------------------
# On-device diagonal-block kernel (the paper's CPU phase, without the host).
# ---------------------------------------------------------------------------


def _diag_kernel(d_ref, vtd_ref, d_out, c_out, s_out, t_out, *, sigma: int,
                 rows: int, k: int, accum_dtype=None):
    D_new, c, s, T = diag_recurrence(
        d_ref[...], vtd_ref[...], sigma=sigma, rows=rows, k=k,
        accum_dtype=accum_dtype,
    )
    d_out[...] = D_new.astype(d_out.dtype)
    c_out[...] = c.astype(c_out.dtype)
    s_out[...] = s.astype(s_out.dtype)
    t_out[...] = T.astype(t_out.dtype)


@functools.partial(
    jax.jit, static_argnames=("sigma", "interpret", "accum_dtype")
)
def diag_block(D, vtd, *, sigma: int, interpret: bool = False,
               accum_dtype=None):
    """Serial diagonal-block pass on-device. D: (P, P); vtd: (k, P).

    Returns (D_new, c, s, T) exactly like ``repro.core.blocked.panel_diag``
    with ``with_transform=True``. When ``accum_dtype`` is given, the
    recurrence runs there and the rotation state outputs (c, s, T) KEEP the
    accumulation dtype — only the stored diagonal tile is downcast.
    """
    P = D.shape[0]
    k = vtd.shape[0]
    pk = P + k
    state_dtype = accum_dtype or D.dtype
    kernel = functools.partial(_diag_kernel, sigma=sigma, rows=P, k=k,
                               accum_dtype=accum_dtype)
    D_new, c, s, T = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((P, P), lambda j: (0, 0)),
            pl.BlockSpec((k, P), lambda j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((P, P), lambda j: (0, 0)),
            pl.BlockSpec((P, k), lambda j: (0, 0)),
            pl.BlockSpec((P, k), lambda j: (0, 0)),
            pl.BlockSpec((pk, pk), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((P, P), D.dtype),
            jax.ShapeDtypeStruct((P, k), state_dtype),
            jax.ShapeDtypeStruct((P, k), state_dtype),
            jax.ShapeDtypeStruct((pk, pk), state_dtype),
        ],
        interpret=interpret,
    )(D, vtd)
    return D_new, c, s, T
