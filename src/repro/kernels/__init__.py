# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# This paper's hot spot IS a custom-kernel cascade (§4.4):
#   cholupdate.py — per-panel Pallas kernels (the paper's dispatch pattern)
#   fused.py      — single-launch pipelined kernel (DESIGN.md §5)
#   sharded.py    — one-launch-per-shard panel kernel for the distributed
#                   fused composition (DESIGN.md §7)
#   ops.py        — jit'd wrappers wiring the per-panel kernels to the driver
