"""Jit'd wrappers wiring the Pallas panel kernels into the blocked driver."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import blocked
from repro.kernels import cholupdate as _k


def _default_interpret() -> bool:
    # Interpret mode everywhere except a real TPU backend.
    return jax.default_backend() != "tpu"


def chol_update_pallas(
    L,
    V,
    *,
    sigma: int = 1,
    panel: int = 256,
    strategy: str = "paper",
    block_w: int = 512,
    interpret: Optional[bool] = None,
):
    """Panelled rank-k up/down-date with Pallas panel kernels.

    ``strategy='paper'`` uses the faithful element-wise kernel,
    ``strategy='gemm'`` the transform-GEMM kernel. The panel orchestration
    (diagonal pass -> panel kernel -> next panel) reuses the blocked driver.
    """
    if interpret is None:
        interpret = _default_interpret()

    if strategy == "paper":

        def apply_fn(R, vt, c, s, T, sig):
            return _k.panel_apply_paper(
                R, vt, c, s, sigma=sig, block_w=block_w, interpret=interpret
            )

    elif strategy == "gemm":

        def apply_fn(R, vt, c, s, T, sig):
            return _k.panel_apply_gemm(
                R, vt, T, block_w=block_w, interpret=interpret
            )

    else:
        raise ValueError(f"unknown strategy {strategy!r}")

    return blocked.chol_update_blocked(
        L, V, sigma=sigma, panel=panel, strategy="gemm", apply_fn=apply_fn
    )


def diag_block_pallas(D, vtd, *, sigma: int = 1, interpret: Optional[bool] = None):
    """On-device serial diagonal-block pass (paper CPU phase)."""
    if interpret is None:
        interpret = _default_interpret()
    return _k.diag_block(D, vtd, sigma=sigma, interpret=interpret)
