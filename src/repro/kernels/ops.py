"""Jit'd wrappers wiring the Pallas panel kernels into the blocked driver."""
from __future__ import annotations

from typing import Optional

from repro.core import blocked
from repro.core.backends import default_interpret
from repro.core.precision import Precision
from repro.kernels import cholupdate as _k


def _default_interpret() -> bool:
    # One shared policy (repro.core.backends): the per-panel kernels lower
    # on both TPU (Mosaic) and GPU (Triton), so compile on either.
    return default_interpret()


def chol_update_pallas(
    L,
    V,
    *,
    sigma: int = 1,
    panel: int = 256,
    strategy: str = "paper",
    block_w: int = 512,
    interpret: Optional[bool] = None,
    precision: Optional[Precision] = None,
):
    """Panelled rank-k up/down-date with Pallas panel kernels.

    ``strategy='paper'`` uses the faithful element-wise kernel,
    ``strategy='gemm'`` the transform-GEMM kernel. The panel orchestration
    (diagonal pass -> panel kernel -> next panel) reuses the blocked driver.

    ``precision`` (DESIGN.md §8): the blocked driver stores L/V^T in the
    storage dtype between panels while ``panel_diag`` and the rotation
    state run in the accumulation dtype; the kernels here receive bf16
    tiles with fp32 ``(c, s)``/``T`` and accumulate in fp32.
    """
    if interpret is None:
        interpret = _default_interpret()
    precision = Precision.parse(precision)
    accum_dtype = None if precision is None else precision.accum

    if strategy == "paper":

        def apply_fn(R, vt, c, s, T, sig):
            return _k.panel_apply_paper(
                R, vt, c, s, sigma=sig, block_w=block_w, interpret=interpret,
                accum_dtype=accum_dtype,
            )

    elif strategy == "gemm":

        def apply_fn(R, vt, c, s, T, sig):
            return _k.panel_apply_gemm(
                R, vt, T, block_w=block_w, interpret=interpret,
                accum_dtype=accum_dtype,
            )

    else:
        raise ValueError(f"unknown strategy {strategy!r}")

    return blocked.chol_update_blocked(
        L, V, sigma=sigma, panel=panel, strategy="gemm", apply_fn=apply_fn,
        precision=precision,
    )


def diag_block_pallas(D, vtd, *, sigma: int = 1,
                      interpret: Optional[bool] = None, accum_dtype=None):
    """On-device serial diagonal-block pass (paper CPU phase)."""
    if interpret is None:
        interpret = _default_interpret()
    return _k.diag_block(D, vtd, sigma=sigma, interpret=interpret,
                         accum_dtype=accum_dtype)
