"""mixtral-8x22b [moe]: 8 experts top-2, SWA.

56L d_model=6144 48H (GQA kv=8, head_dim=128) expert d_ff=16384 vocab=32768.
[arXiv:2401.04088; hf]
8 experts do not divide the 16-way TP axis -> the rules shard expert_mlp
(TP-within-expert) instead of the expert axis.
"""
from repro.configs.base import AttnConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    d_ff=16384,
    vocab_size=32768,
    attn=AttnConfig(num_heads=48, num_kv_heads=8, head_dim=128, window=4096),
    moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=16384),
    activation="swiglu",
    norm="rmsnorm",
    tie_embeddings=False,
    fsdp=True,
    pin_batch=False,  # §Perf cell D: scatter dispatch prefers XLA's layout
)
