"""Model / run configuration dataclasses.

One ``ModelConfig`` describes any of the ten assigned architectures; family-
specific knobs live in optional sub-configs. ``reduced()`` returns the scaled-
down smoke variant each architecture's CPU test instantiates.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

VOCAB_PAD_MULTIPLE = 256


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    window: Optional[int] = None          # sliding-window attention (SWA)
    softcap: Optional[float] = None       # attention logit soft-capping
    local_global_period: int = 0          # >0: alternate local/global layers
    rope_theta: float = 10_000.0
    qk_norm: bool = False

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int = 2
    expert_d_ff: int = 0                  # 0 -> use model d_ff
    dense_residual: bool = False          # arctic: parallel dense FFN
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    aux_loss_coef: float = 1e-2


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block parameters."""
    state_dim: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    """RWKV6 (Finch) time-mix parameters."""
    head_dim: int = 64
    decay_lora: int = 64
    mix_lora: int = 32


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                           # dense | moe | rwkv | mamba_hybrid | encdec | vlm
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attn: Optional[AttnConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    enc_layers: int = 0                   # encoder-decoder only
    shared_attn_every: int = 0            # zamba2: shared attn block period
    activation: str = "swiglu"            # swiglu | geglu | gelu
    norm: str = "rmsnorm"                 # rmsnorm | layernorm
    post_norm: bool = False               # gemma2 sandwich norms
    logit_softcap: Optional[float] = None
    embed_scale: bool = False             # multiply embeddings by sqrt(d_model)
    tie_embeddings: bool = True
    max_seq_len: int = 8192
    # Modality frontend stubs (DESIGN.md §6): fraction of the sequence whose
    # embeddings are supplied pre-computed by input_specs().
    frontend: Optional[str] = None        # None | 'vision' | 'audio'
    frontend_frac: float = 0.125
    # Numerics / distribution knobs.
    loss_chunk: int = 1024                # tokens per vocab-projection chunk
    param_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"
    # scan_layers=False fully unrolls the layer loop: bigger HLO, but XLA's
    # cost_analysis does not multiply while-loop bodies by trip count, so
    # the roofline extraction lowers an unrolled variant.
    scan_layers: bool = True
    # Pin the residual stream to the batch axes at layer boundaries
    # (EXPERIMENTS.md §Perf A3). Off for mixtral: its 8-expert scatter
    # dispatch prefers XLA's own layout (cell D iterations).
    pin_batch: bool = True
    fsdp: bool = False                    # shard params over the data axes too
    remat: bool = True

    @property
    def vocab_padded(self) -> int:
        return -(-self.vocab_size // VOCAB_PAD_MULTIPLE) * VOCAB_PAD_MULTIPLE

    @property
    def sub_quadratic(self) -> bool:
        """True iff a 500k-token decode state is bounded (SSM or windowed)."""
        if self.family in ("rwkv", "mamba_hybrid"):
            return True
        return bool(self.attn and self.attn.window and self.attn.local_global_period == 0)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        attn = self.attn
        if attn is not None:
            attn = dataclasses.replace(
                attn,
                num_heads=max(2, min(4, attn.num_heads)),
                num_kv_heads=max(1, min(2, attn.num_kv_heads)),
                head_dim=16,
                window=64 if attn.window else None,
                local_global_period=attn.local_global_period and 2,
            )
        moe = self.moe
        if moe is not None:
            moe = dataclasses.replace(moe, num_experts=4, expert_d_ff=64)
        ssm = self.ssm
        if ssm is not None:
            ssm = dataclasses.replace(ssm, state_dim=8, head_dim=8)
        rwkv = self.rwkv
        if rwkv is not None:
            rwkv = dataclasses.replace(rwkv, head_dim=8, decay_lora=8, mix_lora=8)
        return dataclasses.replace(
            self,
            num_layers=min(self.num_layers, 4),
            enc_layers=min(self.enc_layers, 2),
            d_model=64,
            d_ff=128,
            vocab_size=512,
            attn=attn,
            moe=moe,
            ssm=ssm,
            rwkv=rwkv,
            shared_attn_every=2 if self.shared_attn_every else 0,
            max_seq_len=128,
            param_dtype="float32",
            remat=False,
        )


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (arch x input-shape) cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}
