"""arctic-480b [moe]: 128 experts top-2 + dense residual.

35L d_model=7168 56H (GQA kv=8, head_dim=128) expert d_ff=4864 vocab=32000.
[hf:Snowflake/snowflake-arctic-base; hf]
56 q-heads do not divide the 16-way TP axis -> head axes auto-replicate
(DESIGN.md §7); experts shard 128/16 = 8 per device (EP). FSDP + bf16
optimizer state keep the 480B configuration within per-device HBM.
"""
from repro.configs.base import AttnConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    d_ff=4864,
    vocab_size=32000,
    attn=AttnConfig(num_heads=56, num_kv_heads=8, head_dim=128),
    moe=MoEConfig(num_experts=128, top_k=2, expert_d_ff=4864,
                  dense_residual=True),
    activation="swiglu",
    norm="rmsnorm",
    tie_embeddings=False,
    fsdp=True,
    opt_state_dtype="bfloat16",
)
