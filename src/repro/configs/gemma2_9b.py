"""gemma2-9b [dense]: local/global alternating attention, logit softcaps.

42L d_model=3584 16H (GQA kv=8, head_dim=256) d_ff=14336 vocab=256000.
Local window 4096 on every other layer, attn softcap 50, final softcap 30,
GeGLU, sandwich (pre+post) norms. [arXiv:2408.00118; hf]
"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    d_ff=14336,
    vocab_size=256000,
    attn=AttnConfig(num_heads=16, num_kv_heads=8, head_dim=256,
                    window=4096, softcap=50.0, local_global_period=2),
    activation="geglu",
    norm="rmsnorm",
    post_norm=True,
    logit_softcap=30.0,
    embed_scale=True,
    tie_embeddings=True,
)
