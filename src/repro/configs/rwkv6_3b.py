"""rwkv6-3b [ssm]: Finch — attention-free, data-dependent decay.

32L d_model=2560 (attn-free) d_ff=8960 vocab=65536. [arXiv:2404.05892; hf]
"""
from repro.configs.base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="rwkv",
    num_layers=32,
    d_model=2560,
    d_ff=8960,
    vocab_size=65536,
    attn=None,
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, mix_lora=32),
    norm="layernorm",
    tie_embeddings=False,
)
