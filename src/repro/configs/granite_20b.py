"""granite-20b [dense]: llama-arch code model, MQA.

52L d_model=6144 48H (GQA kv=1 = MQA) d_ff=24576 vocab=49152.
[arXiv:2405.04324; hf]
"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    d_ff=24576,
    vocab_size=49152,
    attn=AttnConfig(num_heads=48, num_kv_heads=1, head_dim=128),
    activation="gelu",
    norm="layernorm",
    tie_embeddings=True,
    fsdp=True,
)
