"""Architecture registry: the ten assigned configs + shape cells."""
from repro.configs.base import (
    SHAPES,
    SHAPES_BY_NAME,
    AttnConfig,
    ModelConfig,
    MoEConfig,
    RWKVConfig,
    ShapeCell,
    SSMConfig,
)

from repro.configs.pixtral_12b import CONFIG as _pixtral
from repro.configs.seamless_m4t_medium import CONFIG as _seamless
from repro.configs.rwkv6_3b import CONFIG as _rwkv6
from repro.configs.granite_20b import CONFIG as _granite
from repro.configs.h2o_danube_1_8b import CONFIG as _h2o
from repro.configs.gemma2_9b import CONFIG as _gemma2
from repro.configs.llama3_2_3b import CONFIG as _llama32
from repro.configs.mixtral_8x22b import CONFIG as _mixtral
from repro.configs.arctic_480b import CONFIG as _arctic
from repro.configs.zamba2_7b import CONFIG as _zamba2

ARCHS = {
    c.name: c
    for c in [
        _pixtral,
        _seamless,
        _rwkv6,
        _granite,
        _h2o,
        _gemma2,
        _llama32,
        _mixtral,
        _arctic,
        _zamba2,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise ValueError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def cells():
    """All runnable (arch, shape) cells; long_500k only for sub-quadratic."""
    out = []
    for name, cfg in ARCHS.items():
        for shape in SHAPES:
            if shape.name == "long_500k" and not cfg.sub_quadratic:
                continue
            out.append((name, shape.name))
    return out


__all__ = [
    "ARCHS",
    "get_config",
    "cells",
    "SHAPES",
    "SHAPES_BY_NAME",
    "ModelConfig",
    "AttnConfig",
    "MoEConfig",
    "SSMConfig",
    "RWKVConfig",
    "ShapeCell",
]
