"""seamless-m4t-medium [audio]: enc-dec multimodal backbone.

12L enc + 12L dec, d_model=1024 16H (GQA kv=16 = MHA) d_ff=4096
vocab=256206 (padded to 256256 for TP divisibility). [arXiv:2308.11596; hf]
Audio frontend is a STUB: input_specs() supplies pre-computed frame
embeddings to the encoder.
"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    num_layers=12,
    enc_layers=12,
    d_model=1024,
    d_ff=4096,
    vocab_size=256206,
    attn=AttnConfig(num_heads=16, num_kv_heads=16, head_dim=64),
    activation="gelu",       # classic (ungated) transformer FFN
    norm="layernorm",
    tie_embeddings=True,
    embed_scale=True,
    frontend="audio",
)
