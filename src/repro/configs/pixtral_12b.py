"""pixtral-12b [vlm]: Pixtral-ViT frontend (STUB) + mistral-nemo backbone.

40L d_model=5120 32H (GQA kv=8, head_dim=128 per hf config) d_ff=14336
vocab=131072. [hf:mistralai/Pixtral-12B-2409; unverified]
The vision tower is a stub: input_specs() supplies pre-computed patch
embeddings for the leading ``frontend_frac`` of the sequence.
"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    d_ff=14336,
    vocab_size=131072,
    attn=AttnConfig(num_heads=32, num_kv_heads=8, head_dim=128,
                    rope_theta=1_000_000.0),
    activation="swiglu",
    norm="rmsnorm",
    tie_embeddings=False,
    frontend="vision",
    frontend_frac=0.125,
    fsdp=True,
)
