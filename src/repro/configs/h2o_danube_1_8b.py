"""h2o-danube-1.8b [dense]: llama+mistral mix with sliding-window attention.

24L d_model=2560 32H (GQA kv=8, head_dim=80) d_ff=6912 vocab=32000, SWA 4096.
[arXiv:2401.16818; hf]
"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    d_ff=6912,
    vocab_size=32000,
    attn=AttnConfig(num_heads=32, num_kv_heads=8, head_dim=80, window=4096),
    activation="swiglu",
    norm="rmsnorm",
    tie_embeddings=False,
)
