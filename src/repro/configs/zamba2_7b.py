"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention block.

81L d_model=3584 (Mamba2, ssm_state=64) with one *shared* attention+MLP
block (32H MHA kv=32, head_dim=112, d_ff=14336) applied every 6 layers.
[arXiv:2411.15242; unverified]
Deviation noted (DESIGN.md): the shared attention carries a 4096 SWA window
so the 500k-token decode state stays O(window) — serving-oriented choice.
"""
from repro.configs.base import AttnConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="mamba_hybrid",
    num_layers=81,
    d_model=3584,
    d_ff=14336,
    vocab_size=32000,
    attn=AttnConfig(num_heads=32, num_kv_heads=32, head_dim=112, window=4096),
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4),
    shared_attn_every=6,
    activation="geglu",
    norm="rmsnorm",
    tie_embeddings=True,
)
