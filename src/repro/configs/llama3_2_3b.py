"""llama3.2-3b [dense]: small llama3.

28L d_model=3072 24H (GQA kv=8, head_dim=128) d_ff=8192 vocab=128256.
[hf:meta-llama/Llama-3.2-1B; unverified]
Note: 24 q-heads do not divide the 16-way TP axis; the sharding rules
auto-replicate the head axes (DESIGN.md §7) — a recorded hillclimb target.
"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    family="dense",
    num_layers=28,
    d_model=3072,
    d_ff=8192,
    vocab_size=128256,
    attn=AttnConfig(num_heads=24, num_kv_heads=8, head_dim=128,
                    rope_theta=500_000.0),
    activation="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
)
