"""Deterministic synthetic token pipeline, per-host sharded.

Production posture: each host generates only its own shard of the global
batch (shard = f(step, host_index)), so the pipeline is

* deterministic — restarts resume mid-stream from the step counter alone
  (no data-state checkpointing needed),
* elastic — a re-mesh only changes (host_index, num_hosts); step k's global
  batch is identical for any host count that divides the batch,
* infinite — no epoch bookkeeping.

Tokens follow a Zipf-like marginal with a Markov backbone so losses have
non-trivial structure (a pure-uniform stream makes every model converge to
the same constant loss instantly, hiding optimizer bugs).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2


class SyntheticTokens:
    """Iterable over per-host batches: dict(tokens, labels)."""

    def __init__(self, cfg: DataConfig, *, host_index: int = 0, num_hosts: int = 1):
        if cfg.global_batch % num_hosts:
            raise ValueError("global_batch must divide over hosts")
        self.cfg = cfg
        self.host_index = host_index
        self.num_hosts = num_hosts
        self.local_batch = cfg.global_batch // num_hosts
        # Zipf-ish unigram over the vocab, fixed by seed.
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        self._probs = 1.0 / ranks**cfg.zipf_a
        self._probs /= self._probs.sum()

    def batch_at(self, step: int) -> dict:
        """The deterministic global-step batch, local shard only."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4096 + self.host_index
        )
        b, s = self.local_batch, cfg.seq_len
        base = rng.choice(cfg.vocab_size, size=(b, s + 1), p=self._probs)
        # Markov backbone: with p=0.25 copy the previous token + 1 (mod V),
        # giving learnable local structure.
        copy = rng.random((b, s)) < 0.25
        base[:, 1:][copy] = (base[:, :-1][copy] + 1) % cfg.vocab_size
        toks = base
        return {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
        }

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def frontend_stub_embeds(cfg, batch: int, length: int, *, step: int = 0,
                         kind: str = "vision", dtype=jnp.bfloat16):
    """Pre-computed modality embeddings for the vlm/audio frontend stubs."""
    key = jax.random.fold_in(jax.random.PRNGKey(17), step)
    key = jax.random.fold_in(key, 0 if kind == "vision" else 1)
    return (
        jax.random.normal(key, (batch, length, cfg.d_model), jnp.float32)
        / np.sqrt(cfg.d_model)
    ).astype(dtype)
