from repro.data.pipeline import DataConfig, SyntheticTokens, frontend_stub_embeds

__all__ = ["DataConfig", "SyntheticTokens", "frontend_stub_embeds"]
