from repro.runtime.fault_tolerance import (
    ResilientLoop,
    StragglerMonitor,
    elastic_reshard,
)

__all__ = ["ResilientLoop", "StragglerMonitor", "elastic_reshard"]
