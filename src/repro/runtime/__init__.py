from repro.runtime.compat import (
    AXIS_TYPE_AUTO,
    HAS_AXIS_TYPE,
    make_mesh_compat,
    mesh_axis_types_kwargs,
)
from repro.runtime.fault_tolerance import (
    ResilientLoop,
    StragglerMonitor,
    elastic_reshard,
)

__all__ = [
    "ResilientLoop",
    "StragglerMonitor",
    "elastic_reshard",
    "AXIS_TYPE_AUTO",
    "HAS_AXIS_TYPE",
    "make_mesh_compat",
    "mesh_axis_types_kwargs",
]
