"""Fault tolerance / straggler mitigation / elastic scaling scaffolding.

At 1000+-node scale the failure model is: a host dies (or its TPU slice
wedges), the job scheduler restarts the affected workers, and the run must
resume from the last committed checkpoint with possibly *fewer or more*
slices. The pieces implemented here, each exercised by tests:

* ``ResilientLoop`` — step loop with periodic atomic checkpoints, resume
  from the newest committed step, bounded retry on transient step failures,
  and NaN/inf guards (a poisoned step is retried from the last checkpoint
  rather than committed).
* ``StragglerMonitor`` — per-step duration tracking with a robust (median +
  k*MAD) threshold; at scale this feeds preemptive restarts of slow hosts.
  Here it flags and records. (On CPU we cannot restart peers; the decision
  logic is what is tested.)
* ``elastic_reshard`` — restore a checkpoint onto a *different* mesh: the
  checkpoint layer stores host arrays, so a job that lost a pod restarts
  with ``make_mesh((8,16))`` and keeps training; tested by round-tripping
  params across mesh shapes in tests/test_runtime.py.

Design notes for real clusters (documented, not simulatable here):
multi-controller jax.distributed initialisation, health heartbeats through
the coordinator, and checkpoint writes fanned out per-host with a rendezvous
barrier before commit.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint import checkpoint as ckpt


@dataclasses.dataclass
class StragglerMonitor:
    """Flags steps whose duration exceeds median + k * MAD."""
    k: float = 5.0
    window: int = 50
    _durations: list = dataclasses.field(default_factory=list)
    flagged: list = dataclasses.field(default_factory=list)

    def record(self, step: int, seconds: float) -> bool:
        hist = self._durations[-self.window:]
        is_straggler = False
        if len(hist) >= 8:
            med = float(np.median(hist))
            mad = float(np.median(np.abs(np.asarray(hist) - med))) + 1e-9
            if seconds > med + self.k * mad:
                is_straggler = True
                self.flagged.append((step, seconds, med))
        self._durations.append(seconds)
        return is_straggler


class ResilientLoop:
    """Checkpointed train loop with retry-from-checkpoint on bad steps."""

    def __init__(
        self,
        step_fn: Callable,            # (state, batch) -> (state, metrics)
        batch_fn: Callable,           # step -> batch
        ckpt_dir,
        *,
        ckpt_every: int = 100,
        keep: int = 3,
        max_retries: int = 2,
        is_bad: Optional[Callable] = None,  # metrics -> bool
        monitor: Optional[StragglerMonitor] = None,
    ):
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.keep = keep
        self.max_retries = max_retries
        self.is_bad = is_bad or (lambda m: not bool(np.isfinite(m.get("loss", 0.0))))
        self.monitor = monitor or StragglerMonitor()

    def resume_or_init(self, init_state):
        last = ckpt.latest_step(self.ckpt_dir)
        if last is None:
            return init_state, 0
        state = ckpt.restore(self.ckpt_dir, last, init_state)
        return state, last

    def run(self, init_state, num_steps: int, *, on_metrics=None):
        state, start = self.resume_or_init(init_state)
        step = start
        retries = 0
        while step < num_steps:
            batch = self.batch_fn(step)
            t0 = time.time()
            new_state, metrics = self.step_fn(state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.time() - t0
            self.monitor.record(step, dt)
            if self.is_bad(metrics):
                # Poisoned step: drop it, reload last good checkpoint.
                retries += 1
                if retries > self.max_retries:
                    raise RuntimeError(
                        f"step {step}: bad metrics {metrics} after "
                        f"{self.max_retries} retries"
                    )
                last = ckpt.latest_step(self.ckpt_dir)
                if last is not None:
                    state = ckpt.restore(self.ckpt_dir, last, state)
                    step = last
                continue
            retries = 0
            state = new_state
            step += 1
            if on_metrics:
                on_metrics(step, metrics)
            if step % self.ckpt_every == 0 or step == num_steps:
                ckpt.save(self.ckpt_dir, step, state, keep=self.keep)
        return state, step


def elastic_reshard(tree, new_shardings):
    """Re-place a (host or device) pytree onto a new mesh's shardings."""
    return jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(jax.device_get(x)), s),
        tree,
        new_shardings,
    )
