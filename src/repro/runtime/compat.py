"""JAX version-compatibility helpers (policy: DESIGN.md §6).

The repo tracks a moving JAX API surface. ``jax.sharding.AxisType`` (and the
matching ``axis_types=`` kwarg of ``jax.make_mesh``) exist only in newer JAX
releases; the pinned container ships JAX 0.4.37, which has neither. Policy:

* **feature-detect, never version-parse** — probe the attribute at import
  time instead of comparing version strings, so pre-release and vendor
  builds behave correctly;
* **degrade to the old default** — on old JAX a mesh without axis types is
  exactly what ``AxisType.Auto`` means on new JAX, so the fallback is
  semantics-preserving, not a stub;
* **one choke point** — every mesh construction in the repo (production
  meshes, tests' virtual-device meshes, elastic restarts) goes through
  ``make_mesh_compat`` so the probe lives in exactly one place.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax

try:  # JAX >= 0.5: explicit/auto sharding axis types exist.
    from jax.sharding import AxisType as _AxisType

    HAS_AXIS_TYPE = True
except ImportError:  # JAX <= 0.4.x: implicit (auto) sharding only.
    _AxisType = None
    HAS_AXIS_TYPE = False

#: ``jax.sharding.AxisType.Auto`` where it exists, else None.
AXIS_TYPE_AUTO = _AxisType.Auto if HAS_AXIS_TYPE else None

# ``shard_map`` moved to the top-level jax namespace after 0.4.x; the pinned
# container only has the jax.experimental spelling.
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # JAX <= 0.4.x
    from jax.experimental.shard_map import shard_map  # noqa: F401


def shard_map_norep(f, *, mesh, in_specs, out_specs):
    """``shard_map`` with the replication checker disabled.

    ``pallas_call`` has no replication rule, so any shard_map body that
    launches a kernel (the distributed fused path, DESIGN.md §7) must turn
    the checker off. The kwarg was renamed ``check_rep`` -> ``check_vma``
    across JAX versions; per the compat policy we feature-detect by calling,
    never by version-parsing, and fall back to the bare call on versions
    where the checker does not exist at all.
    """
    for kw in ({"check_rep": False}, {"check_vma": False}, {}):
        try:
            return shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
        except TypeError:
            continue
    raise TypeError("shard_map rejected both check_rep and check_vma")


def mesh_axis_types_kwargs(n_axes: int) -> dict:
    """kwargs marking all ``n_axes`` mesh axes as Auto, where supported.

    Returns ``{}`` on JAX versions without ``AxisType`` — implicit sharding
    is the only (and therefore the default) behaviour there.
    """
    if HAS_AXIS_TYPE:
        return {"axis_types": (_AxisType.Auto,) * n_axes}
    return {}


def make_mesh_compat(
    shape: Sequence[int],
    axes: Sequence[str],
    *,
    devices: Optional[Sequence] = None,
):
    """``jax.make_mesh`` with Auto axis types on JAX versions that have them."""
    kwargs = mesh_axis_types_kwargs(len(tuple(axes)))
    if devices is not None:
        kwargs["devices"] = devices
    return jax.make_mesh(tuple(shape), tuple(axes), **kwargs)


_REEXEC_SENTINEL = "_REPRO_ENSURE_DEVICES_REEXEC"


def ensure_host_devices(n: int) -> None:
    """Re-exec the current script with ``n`` emulated host devices.

    jax's platform (and device count) freezes at import time, so a CLI
    flag like the examples' ``--sharded`` can only be honored on a
    single-device host by restarting the interpreter with ``XLA_FLAGS``
    set first. Safety rails:

    * the device-count flag is APPENDED — XLA takes the last occurrence
      of a repeated flag, so an inherited lower count cannot win;
    * a sentinel env var guards against an exec loop: if the re-exec'd
      process STILL lacks ``n`` devices (e.g. a non-CPU platform ignores
      host-device emulation), it raises instead of exec'ing forever.
    """
    import os
    import sys

    if jax.device_count() >= n:
        return
    if os.environ.get(_REEXEC_SENTINEL):
        raise RuntimeError(
            f"re-exec with --xla_force_host_platform_device_count={n} "
            f"still sees {jax.device_count()} device(s) — platform "
            f"{jax.default_backend()!r} does not support host-device "
            "emulation; run on a CPU backend (JAX_PLATFORMS=cpu) or a "
            f"host with >= {n} devices"
        )
    env = dict(os.environ)
    env[_REEXEC_SENTINEL] = "1"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n}"
                        ).strip()
    os.execve(sys.executable, [sys.executable] + sys.argv, env)
